"""AOT export: manifest integrity + HLO text loadability."""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PY_DIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out-dir", str(out),
            "--feature-dim", "64", "--hidden-dim", "32", "--latent-dim", "8",
            "--encode-batches", "1", "4",
            "--train-batch", "4",
            "--featurize-batches", "1",
            "--mof-candidates", "32", "--mof-dim", "16",
        ],
        cwd=PY_DIR,
        check=True,
        capture_output=True,
    )
    return str(out)


def _parse_manifest(path):
    models, params, geometry = {}, {}, {}
    cur = None
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts or parts[0].startswith("#"):
                continue
            if parts[0] == "geometry":
                geometry[parts[1]] = int(parts[2])
            elif parts[0] == "model":
                cur = {"hlo": parts[2], "inputs": [], "outputs": []}
                models[parts[1]] = cur
            elif parts[0] in ("input", "output"):
                cur[parts[0] + "s"].append((parts[1], parts[2], parts[3]))
            elif parts[0] == "end":
                cur = None
            elif parts[0] == "param":
                params[parts[1]] = (parts[2], parts[3], int(parts[4]), int(parts[5]))
    return geometry, models, params


def test_manifest_lists_all_models(export_dir):
    geometry, models, params = _parse_manifest(
        os.path.join(export_dir, "manifest.txt")
    )
    assert set(models) == {
        "encode_b1", "encode_b4", "autoencoder_b4", "train_step_b4",
        "featurize_b1", "mof_score_c32",
    }
    assert geometry["feature_dim"] == 64
    assert set(params) == {f"w{i}" for i in range(1, 5)} | {
        f"b{i}" for i in range(1, 5)
    }


def test_hlo_files_exist_and_are_text(export_dir):
    _, models, _ = _parse_manifest(os.path.join(export_dir, "manifest.txt"))
    for name, m in models.items():
        path = os.path.join(export_dir, m["hlo"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, f"{name}: not HLO text"


def test_manifest_shapes(export_dir):
    _, models, _ = _parse_manifest(os.path.join(export_dir, "manifest.txt"))
    enc = models["encode_b4"]
    assert enc["inputs"][0] == ("w1", "float32", "64x32")
    assert enc["inputs"][-1] == ("x", "float32", "4x64")
    assert enc["outputs"] == [("z", "float32", "4x8")]
    ts = models["train_step_b4"]
    assert ts["inputs"][-1] == ("lr", "float32", "scalar")
    assert ts["outputs"][-1] == ("loss", "float32", "scalar")


def test_params_bin_matches_index(export_dir):
    _, _, params = _parse_manifest(os.path.join(export_dir, "manifest.txt"))
    size = os.path.getsize(os.path.join(export_dir, "params.bin"))
    end = max(off + n for (_, _, off, n) in params.values())
    assert end == size
    # w1 is 64x32 f32
    dtype, shape, off, nbytes = params["w1"]
    assert (dtype, shape) == ("float32", "64x32")
    assert nbytes == 64 * 32 * 4
    data = np.fromfile(
        os.path.join(export_dir, "params.bin"), dtype="<f4",
        count=nbytes // 4, offset=off,
    )
    assert np.abs(data).sum() > 0  # He init, not zeros


def test_params_bin_values_match_model(export_dir):
    from compile import model

    _, _, params = _parse_manifest(os.path.join(export_dir, "manifest.txt"))
    want = model.init_params(seed=0, feature_dim=64, hidden_dim=32, latent_dim=8)
    path = os.path.join(export_dir, "params.bin")
    for key in model.PARAM_KEYS:
        dtype, shape, off, nbytes = params[key]
        got = np.fromfile(path, dtype="<f4", count=nbytes // 4, offset=off)
        np.testing.assert_allclose(
            got, np.asarray(want[key]).reshape(-1), rtol=1e-6, err_msg=key
        )


def test_repo_artifacts_fresh_if_present():
    """If the repo-level artifacts/ exists, it must parse and be complete."""
    adir = os.path.join(REPO, "artifacts")
    manifest = os.path.join(adir, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("repo artifacts not built")
    _, models, params = _parse_manifest(manifest)
    for m in models.values():
        assert os.path.exists(os.path.join(adir, m["hlo"]))
    assert os.path.exists(os.path.join(adir, "params.bin"))
