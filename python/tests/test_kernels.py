"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_dense, contact_map, mof_score
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ACTIVATIONS = ["relu", "gelu", "tanh", "none"]


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(
        dtype
    )


# ---------------------------------------------------------------------------
# fused_dense
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**16),
)
def test_fused_dense_matches_ref_f32(m, k, n, act, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)
    got = fused_dense(x, w, b, activation=act)
    want = ref.fused_dense_ref(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 32, 64]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**16),
)
def test_fused_dense_matches_ref_bf16(m, k, n, seed):
    x = _rand(seed, (m, k), jnp.bfloat16)
    w = _rand(seed + 1, (k, n), jnp.bfloat16)
    b = _rand(seed + 2, (n,), jnp.bfloat16)
    got = fused_dense(x, w, b, activation="relu").astype(jnp.float32)
    want = ref.fused_dense_ref(x, w, b, activation="relu").astype(jnp.float32)
    # bf16 storage, f32 accumulation in both paths.
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("block", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_fused_dense_block_shape_invariance(block):
    """Output must not depend on the chosen tiling."""
    bm, bn, bk = block
    x = _rand(7, (64, 96), jnp.float32)
    w = _rand(8, (96, 48), jnp.float32)
    b = _rand(9, (48,), jnp.float32)
    got = fused_dense(x, w, b, block_m=bm, block_n=bn, block_k=bk)
    want = ref.fused_dense_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_fused_dense_shape_errors():
    x = jnp.zeros((4, 8))
    w = jnp.zeros((9, 2))
    b = jnp.zeros((2,))
    with pytest.raises(ValueError, match="contraction mismatch"):
        fused_dense(x, w, b)
    with pytest.raises(ValueError, match="bias shape"):
        fused_dense(jnp.zeros((4, 9)), w, jnp.zeros((3,)))


def test_fused_dense_bad_activation():
    with pytest.raises(ValueError, match="unknown activation"):
        fused_dense(jnp.zeros((2, 2)), jnp.zeros((2, 2)), jnp.zeros((2,)),
                    activation="swish")


# ---------------------------------------------------------------------------
# contact_map
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 80),
    cutoff=st.floats(0.5, 16.0),
    soft=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_contact_map_matches_ref(n, cutoff, soft, seed):
    coords = _rand(seed, (n, 3), jnp.float32, scale=5.0)
    got = contact_map(coords, cutoff=cutoff, soft=soft)
    want = ref.contact_map_ref(coords, cutoff=cutoff, soft=soft)
    if soft:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    else:
        # Hard threshold: tolerate disagreement only where d^2 is within fp
        # noise of the cutoff shell.
        c = np.asarray(coords)
        d2 = ((c[:, None, :] - c[None, :, :]) ** 2).sum(-1)
        boundary = np.abs(d2 - cutoff * cutoff) < 1e-3
        np.testing.assert_array_equal(
            np.asarray(got)[~boundary], np.asarray(want)[~boundary]
        )


def test_contact_map_diagonal_is_self_contact():
    coords = _rand(3, (32, 3), jnp.float32, scale=10.0)
    m = contact_map(coords, cutoff=1.0, soft=False)
    np.testing.assert_array_equal(np.diag(np.asarray(m)), np.ones(32))


def test_contact_map_symmetry():
    coords = _rand(4, (48, 3), jnp.float32, scale=5.0)
    m = np.asarray(contact_map(coords, cutoff=4.0, soft=True))
    np.testing.assert_allclose(m, m.T, rtol=1e-5, atol=1e-6)


def test_contact_map_rejects_non3d():
    with pytest.raises(ValueError, match=r"\(N, 3\)"):
        contact_map(jnp.zeros((8, 2)))


# ---------------------------------------------------------------------------
# mof_score
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 300),
    d=st.integers(1, 128),
    penalty=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_mof_score_matches_ref(c, d, penalty, seed):
    f = _rand(seed, (c, d), jnp.float32)
    w = _rand(seed + 1, (d,), jnp.float32)
    got = mof_score(f, w, penalty=penalty)
    want = ref.mof_score_ref(f, w, penalty=penalty)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_mof_score_zero_features_scores_zero():
    f = jnp.zeros((16, 32))
    w = jnp.ones((32,))
    np.testing.assert_allclose(mof_score(f, w), np.zeros(16), atol=1e-7)


def test_mof_score_weight_shape_error():
    with pytest.raises(ValueError, match="weights shape"):
        mof_score(jnp.zeros((4, 8)), jnp.zeros((9,)))
