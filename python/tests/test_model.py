"""L2 correctness: autoencoder graphs, custom-VJP gradients, featurization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.fused_mlp import apply_activation

jax.config.update("jax_platform_name", "cpu")

D, H, L = 64, 32, 8  # tiny geometry for tests


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=3, feature_dim=D, hidden_dim=H, latent_dim=L)


def _ref_loss(params, x):
    """Loss built purely from jnp ops (no Pallas, no custom VJP)."""
    h = apply_activation(x @ params["w1"] + params["b1"], "relu")
    z = h @ params["w2"] + params["b2"]
    h2 = apply_activation(z @ params["w3"] + params["b3"], "relu")
    recon = h2 @ params["w4"] + params["b4"]
    return jnp.mean((recon - x) ** 2)


def test_encode_shapes(params):
    x = jnp.ones((8, D))
    z = model.encode(params, x)
    assert z.shape == (8, L)
    recon = model.autoencoder_fwd(params, x)
    assert recon.shape == (8, D)


def test_forward_matches_pure_jnp(params):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, D))
    got = model.loss_fn(params, x)
    want = _ref_loss(params, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_custom_vjp_matches_autodiff_of_ref(params):
    """The hand-written Pallas backward must equal jax.grad of the pure
    jnp graph -- the strongest end-to-end L1/L2 correctness signal."""
    x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
    got = jax.grad(model.loss_fn)(params, x)
    want = jax.grad(_ref_loss)(params, x)
    for k in model.PARAM_KEYS:
        np.testing.assert_allclose(
            got[k], want[k], rtol=5e-4, atol=5e-6, err_msg=f"grad {k}"
        )


@settings(max_examples=8, deadline=None)
@given(
    act=st.sampled_from(["relu", "gelu", "tanh", "none"]),
    seed=st.integers(0, 2**16),
)
def test_dense_vjp_all_activations(act, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 16))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, 8)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(seed + 2), (8,)) * 0.1

    def f_kernel(x, w, b):
        return jnp.sum(model.dense(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(apply_activation(x @ w + b, act) ** 2)

    got = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for g, wv, nm in zip(got, want, "xwb"):
        np.testing.assert_allclose(g, wv, rtol=1e-3, atol=1e-5,
                                   err_msg=f"d{nm} ({act})")


def test_train_step_reduces_loss(params):
    x = jax.random.normal(jax.random.PRNGKey(2), (16, D))
    p, losses = params, []
    for _ in range(5):
        p, loss = model.train_step(p, x, jnp.float32(5e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_step_flat_roundtrip(params):
    x = jax.random.normal(jax.random.PRNGKey(4), (16, D))
    flat = model.params_to_flat(params)
    out = model.train_step_flat(*flat, x, jnp.float32(1e-2))
    assert len(out) == 9
    p2, loss = model.train_step(params, x, jnp.float32(1e-2))
    np.testing.assert_allclose(out[-1], loss, rtol=1e-6)
    for k, arr in zip(model.PARAM_KEYS, out[:8]):
        np.testing.assert_allclose(arr, p2[k], rtol=1e-6, err_msg=k)


def test_featurize_matches_ref():
    coords = jax.random.normal(jax.random.PRNGKey(5), (4, 16, 3)) * 4.0
    feats = model.featurize(coords, cutoff=6.0)
    assert feats.shape == (4, 256)
    for i in range(4):
        want = ref.contact_map_ref(coords[i], cutoff=6.0, soft=True).reshape(-1)
        np.testing.assert_allclose(feats[i], want, rtol=1e-4, atol=1e-5)


def test_init_params_shapes():
    p = model.init_params(feature_dim=D, hidden_dim=H, latent_dim=L)
    shapes = model.param_shapes(D, H, L)
    for k in model.PARAM_KEYS:
        assert tuple(p[k].shape) == tuple(shapes[k]), k
    # He init: nonzero weights, zero biases.
    assert float(jnp.abs(p["w1"]).sum()) > 0
    assert float(jnp.abs(p["b1"]).sum()) == 0


def test_init_params_deterministic():
    a = model.init_params(seed=7, feature_dim=D, hidden_dim=H, latent_dim=L)
    b = model.init_params(seed=7, feature_dim=D, hidden_dim=H, latent_dim=L)
    for k in model.PARAM_KEYS:
        np.testing.assert_array_equal(a[k], b[k])
