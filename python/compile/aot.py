"""AOT export: lower the L2 JAX graphs to HLO text for the Rust runtime.

Run once at build time (``make artifacts``); Python never appears on the
request path. For each exported entry point we write

  * ``artifacts/<name>.hlo.txt``  -- HLO **text** (NOT a serialized
    HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids
    which xla_extension 0.5.1 rejects; the text parser reassigns ids and
    round-trips cleanly -- see /opt/xla-example/README.md),
  * an entry in ``artifacts/manifest.txt`` -- a deliberately trivial
    line-oriented format the Rust side parses without a JSON dependency,
  * ``artifacts/manifest.json``   -- the same metadata for humans/tools.

Initial autoencoder parameters are materialized to ``artifacts/params.bin``
(raw little-endian f32) with an index in the manifest so the Rust
coordinator can seed training/inference without Python.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt_shape(shape: Sequence[int]) -> str:
    return "x".join(str(d) for d in shape) if shape else "scalar"


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn: Callable, in_specs, in_names, out_names):
        """Lower ``fn`` at ``in_specs`` and record manifest metadata."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *in_specs)
        self.entries.append(
            {
                "name": name,
                "hlo": f"{name}.hlo.txt",
                "inputs": [
                    {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)}
                    for n, s in zip(in_names, in_specs)
                ],
                "outputs": [
                    {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)}
                    for n, s in zip(out_names, out_shapes)
                ],
            }
        )
        print(f"  exported {name}: {len(text) // 1024} KiB HLO")

    def write_params(self, params):
        """Raw little-endian f32 param bank + index entries."""
        path = os.path.join(self.out_dir, "params.bin")
        index = []
        offset = 0
        with open(path, "wb") as f:
            for key in model.PARAM_KEYS:
                arr = np.asarray(params[key], dtype="<f4")
                data = arr.tobytes()
                f.write(data)
                index.append(
                    {
                        "name": key,
                        "dtype": "float32",
                        "shape": list(arr.shape),
                        "offset": offset,
                        "nbytes": len(data),
                    }
                )
                offset += len(data)
        self.params_index = index
        print(f"  wrote params.bin ({offset // 1024} KiB)")

    def write_manifests(self, geometry):
        jpath = os.path.join(self.out_dir, "manifest.json")
        with open(jpath, "w") as f:
            json.dump(
                {
                    "geometry": geometry,
                    "models": self.entries,
                    "params": self.params_index,
                },
                f,
                indent=2,
            )
        tpath = os.path.join(self.out_dir, "manifest.txt")
        with open(tpath, "w") as f:
            f.write("# proxystore AOT manifest (line-oriented)\n")
            for k, v in geometry.items():
                f.write(f"geometry {k} {v}\n")
            for e in self.entries:
                f.write(f"model {e['name']} {e['hlo']}\n")
                for io in e["inputs"]:
                    f.write(
                        f"input {io['name']} {io['dtype']} "
                        f"{_fmt_shape(io['shape'])}\n"
                    )
                for io in e["outputs"]:
                    f.write(
                        f"output {io['name']} {io['dtype']} "
                        f"{_fmt_shape(io['shape'])}\n"
                    )
                f.write("end\n")
            for p in self.params_index:
                f.write(
                    f"param {p['name']} {p['dtype']} {_fmt_shape(p['shape'])} "
                    f"{p['offset']} {p['nbytes']}\n"
                )
        print(f"  wrote manifest.txt / manifest.json ({len(self.entries)} models)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--feature-dim", type=int, default=model.FEATURE_DIM)
    ap.add_argument("--hidden-dim", type=int, default=model.HIDDEN_DIM)
    ap.add_argument("--latent-dim", type=int, default=model.LATENT_DIM)
    ap.add_argument(
        "--encode-batches", type=int, nargs="+", default=[1, 8, 32]
    )
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--featurize-batches", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--mof-candidates", type=int, default=256)
    ap.add_argument("--mof-dim", type=int, default=64)
    args = ap.parse_args()

    D, H, L = args.feature_dim, args.hidden_dim, args.latent_dim
    n_res = int(round(D ** 0.5))
    assert n_res * n_res == D, "feature dim must be a square (contact map)"

    ex = Exporter(args.out_dir)
    pshapes = model.param_shapes(D, H, L)
    pspecs = [spec(*pshapes[k]) for k in model.PARAM_KEYS]
    pnames = list(model.PARAM_KEYS)

    enc_specs = [spec(*pshapes[k]) for k in model.ENCODER_KEYS]
    enc_names = list(model.ENCODER_KEYS)

    print("lowering L2 graphs (Pallas kernels, interpret=True):")
    for b in args.encode_batches:
        ex.export(
            f"encode_b{b}",
            model.encode_flat,
            enc_specs + [spec(b, D)],
            enc_names + ["x"],
            ["z"],
        )
    ex.export(
        f"autoencoder_b{args.train_batch}",
        model.autoencoder_flat,
        pspecs + [spec(args.train_batch, D)],
        pnames + ["x"],
        ["recon"],
    )
    ex.export(
        f"train_step_b{args.train_batch}",
        model.train_step_flat,
        pspecs + [spec(args.train_batch, D), spec()],
        pnames + ["x", "lr"],
        [f"new_{k}" for k in model.PARAM_KEYS] + ["loss"],
    )
    for b in args.featurize_batches:
        ex.export(
            f"featurize_b{b}",
            model.featurize_flat,
            [spec(b, n_res, 3)],
            ["coords"],
            ["features"],
        )
    ex.export(
        f"mof_score_c{args.mof_candidates}",
        model.mof_score_flat,
        [spec(args.mof_candidates, args.mof_dim), spec(args.mof_dim)],
        ["features", "weights"],
        ["scores"],
    )

    params = model.init_params(
        seed=0, feature_dim=D, hidden_dim=H, latent_dim=L
    )
    ex.write_params(params)
    ex.write_manifests(
        {
            "feature_dim": D,
            "hidden_dim": H,
            "latent_dim": L,
            "n_residues": n_res,
            "train_batch": args.train_batch,
            "mof_candidates": args.mof_candidates,
            "mof_dim": args.mof_dim,
        }
    )
    print("AOT export complete.")


if __name__ == "__main__":
    main()
