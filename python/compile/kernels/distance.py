"""Pairwise-distance / contact-map featurizer as a tiled Pallas kernel.

DeepDriveMD featurizes each MD frame into a residue-residue contact map
(1.0 where the pairwise distance is under a cutoff) that feeds the
autoencoder. For an ``(N, 3)`` coordinate frame the naive jnp version
materializes the full ``(N, N, 3)`` difference tensor; this kernel instead
tiles the output map so only an ``(bi, 3)`` row tile and ``(bj, 3)`` column
tile of coordinates are resident per grid step.

TPU adaptation: on GPU this is a classic "one threadblock per output tile"
kernel with coordinate staging in shared memory; here the BlockSpec grid
plays the threadblock role and VMEM the staging role. The distance math is
pure VPU (elementwise + small reduction) -- no MXU involvement -- so block
shapes are chosen for the (8, 128) vector lanes rather than the systolic
array: row blocks of 128 x column blocks of 128 keep the output tile at
64 KiB and the coordinate tiles under 2 KiB each.

Lowered with ``interpret=True``; validated against ``ref.contact_map_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.fused_mlp import pick_block


def _contact_map_kernel(xi_ref, xj_ref, o_ref, *, cutoff: float,
                        soft: bool):
    """One (i, j) output tile: pairwise distances between row/col tiles."""
    xi = xi_ref[...].astype(jnp.float32)  # (bi, 3)
    xj = xj_ref[...].astype(jnp.float32)  # (bj, 3)
    # |xi - xj|^2 = |xi|^2 + |xj|^2 - 2 xi.xj -- the dot form maps onto the
    # MXU for large tiles and avoids the (bi, bj, 3) broadcast intermediate.
    sq_i = jnp.sum(xi * xi, axis=-1, keepdims=True)       # (bi, 1)
    sq_j = jnp.sum(xj * xj, axis=-1, keepdims=True).T     # (1, bj)
    cross = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(sq_i + sq_j - 2.0 * cross, 0.0)
    if soft:
        # Smooth contact: sigmoid((cutoff^2 - d^2) / cutoff^2); keeps the
        # featurizer differentiable for the train path.
        o_ref[...] = jax.nn.sigmoid((cutoff * cutoff - d2) / (cutoff * cutoff))
    else:
        o_ref[...] = (d2 < cutoff * cutoff).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("cutoff", "soft", "block_i", "block_j")
)
def contact_map(
    coords: jax.Array,
    *,
    cutoff: float = 8.0,
    soft: bool = True,
    block_i: int = 128,
    block_j: int = 128,
) -> jax.Array:
    """Compute the ``(N, N)`` contact map of an ``(N, 3)`` coordinate frame.

    Args:
      coords: ``(N, 3)`` atom/residue positions.
      cutoff: contact distance threshold (angstroms in the MD application).
      soft: if true, emit a smooth sigmoid contact value instead of a 0/1
        indicator (differentiable; used on the training path).
      block_i/block_j: output tile shape.

    Returns:
      ``(N, N)`` float32 contact map.
    """
    n, d = coords.shape
    if d != 3:
        raise ValueError(f"coords must be (N, 3), got {coords.shape}")

    bi = pick_block(n, block_i)
    bj = pick_block(n, block_j)
    grid = (n // bi, n // bj)

    kernel = functools.partial(
        _contact_map_kernel, cutoff=float(cutoff), soft=soft
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, 3), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(coords, coords)
