"""Layer-1 Pallas kernels (build-time only).

Every kernel here is authored with the TPU mental model (VMEM tiles feeding
the MXU, grids expressing the HBM<->VMEM schedule) but lowered with
``interpret=True`` so the resulting HLO is executable by any PJRT backend,
including the Rust CPU client that serves the request path.

Kernels:
  - ``fused_mlp.fused_dense`` -- tiled matmul + bias + activation, the
    autoencoder's hot spot (DeepDriveMD inference, Fig 9).
  - ``distance.contact_map`` -- pairwise-distance / thresholded contact
    map over MD frames (DeepDriveMD simulation featurization).
  - ``score.mof_score`` -- weighted reduction scorer for MOF candidates
    (MOF Generation application, Fig 10).

Correctness oracle: ``compile.kernels.ref`` (pure jax.numpy), checked by
``python/tests`` with hypothesis sweeps.
"""

from compile.kernels.fused_mlp import fused_dense
from compile.kernels.distance import contact_map
from compile.kernels.score import mof_score

__all__ = ["fused_dense", "contact_map", "mof_score"]
