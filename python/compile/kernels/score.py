"""MOF candidate scorer as a Pallas kernel.

The MOF Generation application (Fig 10) scores assembled MOF candidates
with a physics surrogate before deciding which to simulate. We model the
surrogate as a banded energy score over per-candidate feature vectors:

    score_c = tanh( (f_c . w) / sqrt(D) ) - lambda * ||f_c||^2 / D

i.e. an affinity term (how well the candidate's features align with the
learned CO2-uptake direction ``w``) minus a strain penalty. One grid step
scores a block of candidates; features stream HBM->VMEM one block at a
time so arbitrarily many candidates can be scored with a fixed VMEM
footprint (block 128 x D=256 f32 = 128 KiB).

Lowered with ``interpret=True``; validated against ``ref.mof_score_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.fused_mlp import pick_block


def _mof_score_kernel(f_ref, w_ref, o_ref, *, penalty: float):
    f = f_ref[...].astype(jnp.float32)          # (bc, D)
    w = w_ref[...].astype(jnp.float32)          # (D,)
    d = f.shape[-1]
    affinity = jnp.tanh(f @ w / jnp.sqrt(jnp.float32(d)))
    strain = jnp.sum(f * f, axis=-1) / jnp.float32(d)
    o_ref[...] = affinity - penalty * strain


@functools.partial(jax.jit, static_argnames=("penalty", "block_c"))
def mof_score(
    features: jax.Array,
    weights: jax.Array,
    *,
    penalty: float = 0.1,
    block_c: int = 128,
) -> jax.Array:
    """Score ``(C, D)`` candidate features against a ``(D,)`` direction.

    Args:
      features: ``(C, D)`` per-candidate feature vectors.
      weights: ``(D,)`` learned uptake direction.
      penalty: strain penalty coefficient lambda.
      block_c: candidates per grid step.

    Returns:
      ``(C,)`` float32 scores in ``(-inf, 1]`` (practically ``[-pen*max, 1]``).
    """
    c, d = features.shape
    if weights.shape != (d,):
        raise ValueError(f"weights shape {weights.shape} != ({d},)")

    bc = pick_block(c, block_c)
    kernel = functools.partial(_mof_score_kernel, penalty=float(penalty))
    return pl.pallas_call(
        kernel,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((bc, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(features, weights)
