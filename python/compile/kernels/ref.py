"""Pure jax.numpy oracles for the Pallas kernels.

These are the correctness ground truth: small, obviously-correct
implementations with no tiling, no grids, no control flow. ``python/tests``
sweeps shapes/dtypes with hypothesis and asserts allclose between each
kernel and its oracle here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.fused_mlp import Activation, apply_activation


def fused_dense_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: Activation = "relu"
) -> jax.Array:
    """Oracle for :func:`fused_mlp.fused_dense`."""
    out = (
        jnp.dot(
            x.astype(jnp.float32),
            w.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        + b.astype(jnp.float32)
    )
    return apply_activation(out, activation).astype(x.dtype)


def contact_map_ref(
    coords: jax.Array, *, cutoff: float = 8.0, soft: bool = True
) -> jax.Array:
    """Oracle for :func:`distance.contact_map` (materializes (N, N, 3))."""
    c = coords.astype(jnp.float32)
    diff = c[:, None, :] - c[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    if soft:
        return jax.nn.sigmoid((cutoff * cutoff - d2) / (cutoff * cutoff))
    return (d2 < cutoff * cutoff).astype(jnp.float32)


def mof_score_ref(
    features: jax.Array, weights: jax.Array, *, penalty: float = 0.1
) -> jax.Array:
    """Oracle for :func:`score.mof_score`."""
    f = features.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    d = f.shape[-1]
    affinity = jnp.tanh(f @ w / jnp.sqrt(jnp.float32(d)))
    strain = jnp.sum(f * f, axis=-1) / jnp.float32(d)
    return affinity - penalty * strain
