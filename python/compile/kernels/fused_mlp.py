"""Fused dense layer (matmul + bias + activation) as a tiled Pallas kernel.

This is the compute hot spot of the DeepDriveMD autoencoder (Fig 9): every
inference round-trip is a stack of dense layers, and fusing the bias add and
activation into the matmul epilogue removes two extra HBM round trips per
layer.

TPU adaptation (paper ran on A100 GPUs):
  * CUDA threadblock tiles in shared memory  ->  ``BlockSpec`` tiles in VMEM.
  * Tensor-core WMMA fragments               ->  MXU-shaped inner matmul
    (block shapes kept to multiples of the (8, 128) register lanes; the
    default 128x128x128 blocking matches the 128x128 systolic array).
  * ``cp.async`` double buffering            ->  expressed by the grid: the
    K axis is the innermost grid dimension, so Mosaic pipelines the next
    (x, w) tiles into VMEM while the current block multiplies.
  * Epilogue fusion (bias+act) happens on the last K step while the
    accumulator tile is still resident in VMEM.

The accumulator is the output tile itself: its BlockSpec index map is
invariant along the K grid axis, so Pallas keeps the tile resident in VMEM
across all K steps and writes it back to HBM exactly once.

VMEM budget per grid step with the default 128-blocks (f32):
  x tile 128x128 (64 KiB) + w tile 128x128 (64 KiB) + out/acc tile 128x128
  (64 KiB) + bias slice (0.5 KiB) ~= 192 KiB, far under the ~16 MiB/core
  budget; even 512-wide N blocks stay < 2 MiB.

Lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); numerics are validated against ``ref.fused_dense_ref``.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Activation = Literal["relu", "gelu", "tanh", "none"]


def apply_activation(x: jax.Array, activation: Activation) -> jax.Array:
    """Epilogue nonlinearity; shared with the reference oracle."""
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        # tanh-approximated GELU: cheap on the VPU, matches ref oracle.
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "none":
        return x
    raise ValueError(f"unknown activation: {activation!r}")


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *,
                        k_steps: int, activation: Activation):
    """One (m, n, k) grid step: o += x_tile @ w_tile, epilogue on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped block matmul; accumulate in f32.
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...].astype(o_ref.dtype)
        o_ref[...] = apply_activation(out, activation)


def pick_block(dim: int, preferred: int) -> int:
    """Largest block <= preferred that divides dim (dims here are powers of
    two or small multiples, so this terminates at 1 in the worst case)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def fused_dense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: Activation = "relu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Compute ``activation(x @ w + b)`` with a tiled Pallas kernel.

    Args:
      x: ``(M, K)`` input batch.
      w: ``(K, N)`` weight matrix.
      b: ``(N,)`` bias.
      activation: epilogue nonlinearity fused into the last K step.
      block_m/block_n/block_k: VMEM tile shape; defaults match the MXU.

    Returns:
      ``(M, N)`` activations with ``x``'s dtype.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x{x.shape} @ w{w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    bm = pick_block(m, block_m)
    bn = pick_block(n, block_n)
    bk = pick_block(k, block_k)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)

    kernel = functools.partial(
        _fused_dense_kernel, k_steps=k_steps, activation=activation
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
