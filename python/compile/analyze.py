"""L1/L2 performance analysis (build-time).

Interpret-mode Pallas gives CPU-numpy wallclock, which is *not* a TPU
proxy, so the optimization signal for L1 is structural:

  * VMEM footprint per grid step (must fit the ~16 MiB/core budget, with
    2x headroom for Mosaic's double buffering);
  * MXU alignment (block dims as multiples of the 128x128 systolic array
    and the (8, 128) vector registers);
  * arithmetic intensity (FLOPs per HBM byte) against the TPU roofline.

For L2 the signal is the lowered HLO itself: counts of fusion ops vs
total, and the absence of duplicated expensive ops (each `dot` in the
graph should appear exactly as many times as the math requires).

Run: ``cd python && python -m compile.analyze``; the table feeds
DESIGN.md §8 and EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import math
import os
import re
from dataclasses import dataclass

# TPU v4-ish reference numbers (per core) used for roofline estimates.
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
HBM_BW = 1.2e12        # bytes/s
PEAK_F32_FLOPS = 137e12 / 2  # bf16 peak halved for f32 accumulate


@dataclass
class KernelEstimate:
    name: str
    block: tuple
    vmem_bytes: int
    mxu_aligned: bool
    flops_per_step: float
    hbm_bytes_per_step: float

    @property
    def intensity(self) -> float:
        return self.flops_per_step / max(self.hbm_bytes_per_step, 1.0)

    @property
    def roofline_bound(self) -> str:
        knee = PEAK_F32_FLOPS / HBM_BW
        return "compute" if self.intensity >= knee else "memory"

    @property
    def mxu_util_estimate(self) -> float:
        """Fraction of MXU issue slots doing useful work for the block."""
        bm, bn, bk = (self.block + (1, 1, 1))[:3]
        pad = lambda d: math.ceil(d / MXU_DIM) * MXU_DIM
        useful = bm * bn * bk
        issued = pad(bm) * pad(bn) * pad(bk)
        return useful / issued


def fused_dense_estimate(bm=128, bn=128, bk=128, dtype_bytes=4) -> KernelEstimate:
    vmem = (bm * bk + bk * bn + bm * bn + bn) * dtype_bytes
    return KernelEstimate(
        name=f"fused_dense {bm}x{bn}x{bk}",
        block=(bm, bn, bk),
        vmem_bytes=vmem,
        mxu_aligned=(bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0),
        flops_per_step=2.0 * bm * bn * bk,
        hbm_bytes_per_step=(bm * bk + bk * bn) * dtype_bytes,
    )


def contact_map_estimate(bi=128, bj=128, dtype_bytes=4) -> KernelEstimate:
    vmem = (bi * 3 + bj * 3 + bi * bj) * dtype_bytes
    return KernelEstimate(
        name=f"contact_map {bi}x{bj}",
        block=(bi, bj, 3),
        vmem_bytes=vmem,
        mxu_aligned=(bi % 8 == 0 and bj % 128 == 0),
        flops_per_step=bi * bj * (2 * 3 + 6),  # dot + norm + sigmoid-ish
        hbm_bytes_per_step=(bi * 3 + bj * 3 + bi * bj) * dtype_bytes,
    )


def mof_score_estimate(bc=128, d=64, dtype_bytes=4) -> KernelEstimate:
    vmem = (bc * d + d + bc) * dtype_bytes
    return KernelEstimate(
        name=f"mof_score {bc}x{d}",
        block=(bc, d),
        vmem_bytes=vmem,
        mxu_aligned=(bc % 8 == 0),
        flops_per_step=bc * (4 * d + 10),
        hbm_bytes_per_step=(bc * d) * dtype_bytes,
    )


def analyze_hlo(path: str) -> dict:
    """Structural stats of a lowered HLO module."""
    text = open(path).read()
    ops = re.findall(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{}, ]+?\s(\w+)\(",
                     text, re.MULTILINE)
    counts: dict = {}
    for op in ops:
        counts[op] = counts.get(op, 0) + 1
    return {
        "total_ops": len(ops),
        "dots": counts.get("dot", 0),
        "fusions": counts.get("fusion", 0),
        "while_loops": counts.get("while", 0),
        "custom_calls": counts.get("custom-call", 0),
        "broadcasts": counts.get("broadcast", 0),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()

    print("== L1 kernel estimates (TPU v4 reference numbers) ==")
    estimates = [
        fused_dense_estimate(),               # default blocking
        fused_dense_estimate(256, 256, 128),  # larger-N variant
        fused_dense_estimate(8, 128, 128),    # small-batch inference shape
        contact_map_estimate(),
        contact_map_estimate(32, 32),         # our N=32 geometry
        mof_score_estimate(),
    ]
    for e in estimates:
        budget = "OK" if e.vmem_bytes * 2 <= VMEM_BYTES else "OVER"
        print(
            f"  {e.name:28s} vmem/step {e.vmem_bytes/1024:8.1f} KiB "
            f"(x2 buf: {budget}) mxu-aligned={str(e.mxu_aligned):5s} "
            f"intensity {e.intensity:7.1f} flop/B -> {e.roofline_bound}-bound "
            f"mxu-util {e.mxu_util_estimate:.2f}"
        )

    manifest = os.path.join(args.artifacts, "manifest.txt")
    if os.path.exists(manifest):
        print("\n== L2 lowered HLO structure ==")
        for line in open(manifest):
            parts = line.split()
            if parts and parts[0] == "model":
                stats = analyze_hlo(os.path.join(args.artifacts, parts[2]))
                print(f"  {parts[1]:20s} {stats}")
    else:
        print(f"\n(no artifacts at {args.artifacts}; run make artifacts)")


if __name__ == "__main__":
    main()
