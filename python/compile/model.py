"""Layer-2 JAX compute graphs for the three applications.

Everything here is build-time: `aot.py` lowers these jitted functions to
HLO text which the Rust runtime loads and executes via PJRT. The compute
hot spots call the Layer-1 Pallas kernels (``compile.kernels``); the
backward pass is a hand-written custom VJP whose matmuls also run through
the Pallas kernel (flash-attention style: kernel fwd + kernel bwd with
rematerialized pre-activations), so both training and inference exercise L1.

Model: the DeepDriveMD convolutional-variational-autoencoder stand-in -- a
4-layer dense autoencoder over flattened contact maps:

    encode:  x (B, D) --relu--> h (B, H) --none--> z (B, L)
    decode:  z (B, L) --relu--> h (B, H) --none--> x' (B, D)

with D = N*N contact-map pixels (N residues). ``featurize`` turns raw MD
coordinates into contact-map features with the L1 distance kernel, and
``mof_score`` scores MOF candidates with the L1 scorer.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from compile.kernels import fused_dense, contact_map, mof_score
from compile.kernels.fused_mlp import Activation, apply_activation

# Default model geometry (kept modest so CPU-PJRT latencies are sub-second;
# DESIGN.md records the real-TPU projection for the paper-scale model).
N_RESIDUES = 32
FEATURE_DIM = N_RESIDUES * N_RESIDUES  # 1024
HIDDEN_DIM = 256
LATENT_DIM = 32

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# Differentiable fused dense: Pallas forward, Pallas backward.
# --------------------------------------------------------------------------

def _matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain matmul through the L1 kernel (zero bias, identity epilogue)."""
    zero_bias = jnp.zeros((b.shape[1],), dtype=a.dtype)
    return fused_dense(a, b, zero_bias, activation="none")


def _act_grad(pre: jax.Array, activation: Activation) -> jax.Array:
    """d activation(pre) / d pre, elementwise."""
    if activation == "relu":
        return (pre > 0).astype(pre.dtype)
    if activation == "tanh":
        t = jnp.tanh(pre)
        return 1.0 - t * t
    if activation == "gelu":
        # Derivative of the tanh-approximated GELU used by the kernel.
        c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
        inner = c * (pre + 0.044715 * pre**3)
        t = jnp.tanh(inner)
        dinner = c * (1.0 + 3 * 0.044715 * pre * pre)
        return 0.5 * (1.0 + t) + 0.5 * pre * (1.0 - t * t) * dinner
    if activation == "none":
        return jnp.ones_like(pre)
    raise ValueError(f"unknown activation: {activation!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jax.Array, w: jax.Array, b: jax.Array,
          activation: Activation = "relu") -> jax.Array:
    """Differentiable ``activation(x @ w + b)`` backed by the Pallas kernel."""
    return fused_dense(x, w, b, activation=activation)


def _dense_fwd(x, w, b, activation):
    y = fused_dense(x, w, b, activation=activation)
    # Rematerialize pre-activations in bwd instead of saving them: trades
    # one extra kernel launch for (B, N) less residual memory.
    return y, (x, w, b)


def _dense_bwd(activation, res, g):
    x, w, b = res
    pre = fused_dense(x, w, b, activation="none")
    gpre = g * _act_grad(pre, activation)
    dx = _matmul(gpre, w.T)
    dw = _matmul(x.T, gpre)
    db = jnp.sum(gpre, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


# --------------------------------------------------------------------------
# Autoencoder
# --------------------------------------------------------------------------

def init_params(
    seed: int = 0,
    feature_dim: int = FEATURE_DIM,
    hidden_dim: int = HIDDEN_DIM,
    latent_dim: int = LATENT_DIM,
) -> Params:
    """He-initialized parameters for the 4-layer autoencoder."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)

    def he(key, fan_in, shape):
        return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(
            2.0 / fan_in
        )

    return {
        "w1": he(keys[0], feature_dim, (feature_dim, hidden_dim)),
        "b1": jnp.zeros((hidden_dim,), jnp.float32),
        "w2": he(keys[1], hidden_dim, (hidden_dim, latent_dim)),
        "b2": jnp.zeros((latent_dim,), jnp.float32),
        "w3": he(keys[2], latent_dim, (latent_dim, hidden_dim)),
        "b3": jnp.zeros((hidden_dim,), jnp.float32),
        "w4": he(keys[3], hidden_dim, (hidden_dim, feature_dim)),
        "b4": jnp.zeros((feature_dim,), jnp.float32),
    }


def encode(params: Params, x: jax.Array) -> jax.Array:
    """Contact-map batch (B, D) -> latent (B, L). The Fig 9 hot path."""
    h = dense(x, params["w1"], params["b1"], "relu")
    return dense(h, params["w2"], params["b2"], "none")


def decode(params: Params, z: jax.Array) -> jax.Array:
    """Latent (B, L) -> reconstructed contact map (B, D)."""
    h = dense(z, params["w3"], params["b3"], "relu")
    return dense(h, params["w4"], params["b4"], "none")


def autoencoder_fwd(params: Params, x: jax.Array) -> jax.Array:
    return decode(params, encode(params, x))


def loss_fn(params: Params, x: jax.Array) -> jax.Array:
    """Mean-squared reconstruction error."""
    recon = autoencoder_fwd(params, x)
    return jnp.mean((recon - x) ** 2)


def train_step(params: Params, x: jax.Array, lr: jax.Array):
    """One SGD step; returns (new_params, loss). Exercises the kernel bwd."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_params, loss


# --------------------------------------------------------------------------
# Featurization + MOF scoring entry points
# --------------------------------------------------------------------------

def featurize(coords: jax.Array, cutoff: float = 8.0) -> jax.Array:
    """MD frames (B, N, 3) -> flattened contact-map features (B, N*N)."""
    maps = jax.vmap(lambda c: contact_map(c, cutoff=cutoff, soft=True))(coords)
    b, n, _ = coords.shape
    return maps.reshape(b, n * n)


def score_candidates(features: jax.Array, weights: jax.Array,
                     penalty: float = 0.1) -> jax.Array:
    """MOF candidates (C, D) + direction (D,) -> scores (C,)."""
    return mof_score(features, weights, penalty=penalty)


# --------------------------------------------------------------------------
# Flat-argument wrappers for AOT export (PJRT executables take positional
# buffers, so the params pytree is flattened in a canonical key order).
# --------------------------------------------------------------------------

PARAM_KEYS = ("w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4")


def params_to_flat(params: Params):
    return tuple(params[k] for k in PARAM_KEYS)


def flat_to_params(flat) -> Params:
    return dict(zip(PARAM_KEYS, flat))


ENCODER_KEYS = ("w1", "b1", "w2", "b2")


def encode_flat(w1, b1, w2, b2, x):
    """Encoder-only signature: the inference hot path ships just the
    encoder weights (jax.jit would DCE unused decoder args anyway, which
    changes the compiled signature -- so we make the contract explicit)."""
    params = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
    h = dense(x, params["w1"], params["b1"], "relu")
    return (dense(h, params["w2"], params["b2"], "none"),)


def autoencoder_flat(*args):
    """args = (*params, x) -> (recon,)"""
    params = flat_to_params(args[:8])
    return (autoencoder_fwd(params, args[8]),)


def train_step_flat(*args):
    """args = (*params, x, lr) -> (*new_params, loss)"""
    params = flat_to_params(args[:8])
    new_params, loss = train_step(params, args[8], args[9])
    return params_to_flat(new_params) + (loss,)


def featurize_flat(coords):
    """coords (B, N, 3) -> (features (B, N*N),)"""
    return (featurize(coords),)


def mof_score_flat(features, weights):
    """(C, D), (D,) -> (scores (C,),)"""
    return (score_candidates(features, weights),)


def param_shapes(feature_dim=FEATURE_DIM, hidden_dim=HIDDEN_DIM,
                 latent_dim=LATENT_DIM) -> Dict[str, Any]:
    """Shape table used by aot.py's manifest."""
    return {
        "w1": (feature_dim, hidden_dim),
        "b1": (hidden_dim,),
        "w2": (hidden_dim, latent_dim),
        "b2": (latent_dim,),
        "w3": (latent_dim, hidden_dim),
        "b3": (hidden_dim,),
        "w4": (hidden_dim, feature_dim),
        "b4": (feature_dim,),
    }
