//! Distributed futures over a sharded store (paper Sec IV-A), on the
//! event-driven watch plane.
//!
//! Run with: `cargo run --release --example distributed_futures`
//!
//! A future is a key that does not exist yet. Consumers used to wait on
//! it by polling (`wait_get` with backoff) or by parking a dedicated
//! server connection; both scale badly — N parked consumers cost N poll
//! loops or N connections. The watch plane replaces that: arming a watch
//! registers a waiter with the owning backend, and the producer's write
//! wakes it in one push. `result_async` hands you the armed handle so
//! the wait overlaps with compute; `when_all` fans a whole task graph's
//! joins in, parking once per key.
//!
//! Watch vs `wait_get`, in one rule: `wait_get` is watch-and-park (use
//! it when you need the value right now); `result_async`/`watch_async`
//! is watch-and-keep-working (use it whenever there is compute to
//! overlap). Both ride the same plane — nothing polls either way, on any
//! channel: the sharded router arms the key's replica set, and the
//! elastic fabric re-arms live watches when the membership changes.

use std::sync::Arc;
use std::time::Duration;

use proxystore::error::Result;
use proxystore::futures::{when_all, when_any, ProxyFuture};
use proxystore::prelude::{MemoryConnector, Store};
use proxystore::shard::ShardedConnector;
use proxystore::store::Connector;

fn main() -> Result<()> {
    // A store over a 4-shard fabric: future keys scatter across shards,
    // and each watch arms on the shard that owns its key.
    let backends: Vec<Arc<dyn Connector>> =
        (0..4).map(|_| MemoryConnector::new()).collect();
    let store = Store::new(
        "futures",
        Arc::new(ShardedConnector::new(backends, 1, 64)?),
    );

    // ----------------------------------------------------------------
    // Produce/consume: mint futures before any value exists, ship the
    // producer half to worker threads, arm the consumer side up front.
    // ----------------------------------------------------------------
    let futs: Vec<ProxyFuture<u64>> = (0..8).map(|_| store.future()).collect();

    // result_async: the watch is armed NOW, so the consumer overlaps the
    // producers' work instead of blocking at each take.
    let pending: Vec<_> = futs
        .iter()
        .map(|f| f.result_async())
        .collect::<Result<Vec<_>>>()?;

    let producers: Vec<_> = futs
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let f = f.clone();
            std::thread::spawn(move || {
                // Simulated work: later tasks finish later.
                std::thread::sleep(Duration::from_millis(10 * i as u64));
                f.set_result(&(i as u64 * 100)).expect("single assignment");
            })
        })
        .collect();

    // when_any: react to the first finisher (speculative execution,
    // hedged requests) without polling anybody.
    let (first, value) = when_any(&futs, Some(Duration::from_secs(10)))?;
    println!("first resolved: task {first} -> {value}");

    // when_all: the fan-in join parks once per key; the slowest producer
    // bounds wall time.
    let all = when_all(&futs, Some(Duration::from_secs(10)))?;
    println!("when_all joined {} results: {:?}", all.len(), all);

    // The armed handles resolve from the same pushes.
    for (i, p) in pending.iter().enumerate() {
        assert_eq!(p.wait()?, i as u64 * 100);
    }
    for p in producers {
        p.join().expect("producer");
    }

    // ----------------------------------------------------------------
    // Single assignment is atomic: racing producers get one winner.
    // ----------------------------------------------------------------
    let contested: ProxyFuture<String> = store.future();
    let wins: usize = std::thread::scope(|s| {
        let hs: Vec<_> = (0..4)
            .map(|i| {
                let f = contested.clone();
                s.spawn(move || f.set_result(&format!("producer-{i}")).is_ok())
            })
            .collect();
        hs.into_iter()
            .map(|h| h.join().expect("producer"))
            .filter(|&won| won)
            .count()
    });
    assert_eq!(wins, 1, "put_nx admits exactly one producer");
    println!("racing producers: one winner, {} losers errored", 4 - wins);
    let winner = contested.result(Some(Duration::from_secs(5)))?;
    println!("contested future settled once, by {winner}");
    Ok(())
}
