//! Quickstart: the three proxy patterns in ~80 lines.
//!
//! Run with: `cargo run --release --example quickstart`
//!
//! Everything here uses the blocking `Store` surface for clarity. Each of
//! these calls also has a nonblocking twin — `put_async`, `get_async`,
//! `proxy_async` — that *submits* the op and hands back a completion
//! handle, so resolution overlaps with compute; on TCP channels submitted
//! ops pipeline on one shared connection. See
//! `examples/pipelined_ops.rs` for that side of the API.
//!
//! Waiting on not-yet-existing values (the future resolution below, and
//! `Store::wait_get`) rides the event-driven watch plane: the consumer
//! arms a watch and the producer's write wakes it in one push — no
//! polling, no dedicated connection, on every channel. When there is
//! compute to overlap, prefer the armed-handle forms (`result_async`,
//! `Store::watch_async`, and the `when_all`/`when_any` joins) over the
//! park-in-place `wait_get`; see `examples/distributed_futures.rs`.

use std::sync::Arc;
use std::time::Duration;

use proxystore::codec::Encode;
use proxystore::error::Result;
use proxystore::net::ServerBuilder;
use proxystore::ownership::{borrow, StoreOwnedExt};
use proxystore::prelude::{Proxy, ProxyFuture, Store};
use proxystore::store::TcpKvConnector;

fn main() -> Result<()> {
    // A Store wraps a mediated channel. Here: a real in-process redis-sim
    // server (event-driven epoll ingress on Linux, threaded elsewhere —
    // see `ServerBuilder::ingress`) behind a pipelined TCP connector.
    // `Store::memory("quickstart")` is the zero-socket alternative.
    let server = ServerBuilder::new().spawn_kv()?;
    let store =
        Store::new("quickstart", Arc::new(TcpKvConnector::connect(server.addr)?));

    // ----------------------------------------------------------------
    // 1. Transparent lazy proxies: pass-by-reference that resolves
    //    just-in-time and is self-contained.
    // ----------------------------------------------------------------
    let big = "x".repeat(1 << 20);
    let proxy: Proxy<String> = store.proxy(&big)?;
    println!(
        "proxy of a {} byte string serializes to {} bytes",
        big.len(),
        proxy.to_bytes().len()
    );
    // Any &str consumer accepts &Proxy<String> via Deref (transparency).
    let len = proxy.len();
    println!("resolved transparently: len = {len}");

    // ----------------------------------------------------------------
    // 2. ProxyFutures: mint proxies of values that don't exist yet.
    // ----------------------------------------------------------------
    let future: ProxyFuture<String> = store.future();
    let consumer_proxy = future.proxy();
    let consumer = std::thread::spawn(move || {
        // Blocks inside resolve() until the producer calls set_result.
        format!("consumer got: {}", *consumer_proxy)
    });
    std::thread::sleep(Duration::from_millis(100));
    future.set_result(&"data, eventually".to_string())?;
    println!("{}", consumer.join().expect("consumer"));

    // ----------------------------------------------------------------
    // 3. Ownership: Rust semantics for distributed objects.
    // ----------------------------------------------------------------
    let owned = store.owned_proxy(&vec![1u64, 2, 3])?;
    let key = owned.key().to_string();
    {
        let r1 = borrow(&owned)?;
        let r2 = borrow(&owned)?;
        println!(
            "two immutable borrows read {:?} / {:?}",
            r1.resolve()?,
            r2.resolve()?
        );
        // While borrows are live, mutable access is a runtime error:
        assert!(owned.mut_borrow().is_err());
    }
    // Borrows dropped: mutation is fine now.
    let mut owned = owned;
    proxystore::ownership::update(&mut owned, &vec![4u64, 5])?;
    println!("owner updated target to {:?}", owned.resolve()?);
    drop(owned);
    println!(
        "owner dropped → target evicted from store: {}",
        !store.exists(&key)?
    );

    // ----------------------------------------------------------------
    // 4. Observability: everything above already reported into the
    //    process-wide telemetry registry — one snapshot shows it.
    // ----------------------------------------------------------------
    let snap = proxystore::metrics::telemetry::snapshot();
    println!(
        "\ntelemetry: {} puts, {} gets, {} evicts recorded across {:?}",
        snap.counter("store.puts"),
        snap.counter("store.gets"),
        snap.counter("store.evicts"),
        snap.active_subsystems(),
    );
    Ok(())
}
