//! Elastic shard fabric: grow and shrink a live store with zero lost
//! reads.
//!
//! Run with: `cargo run --release --example elastic_shards`
//!
//! Demonstrates the control plane end to end:
//! 1. an elastic fabric over three real redis-sim servers;
//! 2. scale-out onto a fourth server — the migration daemon moves only
//!    the ~1/4 remapped keys, reads keep hitting throughout;
//! 3. scale-in retiring the first server, draining it onto the rest;
//! 4. a proxy minted before any rebalance still resolves afterwards (its
//!    stale descriptor re-attaches to the live control plane).

use std::sync::Arc;

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::kv::{KvClient, KvServer};
use proxystore::net::ServerBuilder;
use proxystore::prelude::{Proxy, Store};
use proxystore::shard::{ElasticShards, ShardMembers};
use proxystore::store::ConnectorDesc;

fn main() -> proxystore::Result<()> {
    // ----------------------------------------------------------------
    // 1. An elastic fabric over three real redis-sim servers.
    // ----------------------------------------------------------------
    let servers: Vec<KvServer> =
        (0..3).map(|_| ServerBuilder::new().spawn_kv().expect("kv server")).collect();
    let mut members: ShardMembers = Vec::new();
    for (id, s) in servers.iter().enumerate() {
        members.push((
            id,
            ConnectorDesc::TcpKv { addr: s.addr.to_string() }.connect()?,
        ));
    }
    let elastic = ElasticShards::new("example-elastic", members, 1, 0)?;
    let store = Store::new("elastic", Arc::new(elastic.clone()));

    let objs: Vec<Bytes> =
        (0..48).map(|i| Bytes(vec![i as u8; 32 * 1024])).collect();
    let keys = store.put_many(&objs)?;
    println!(
        "stored {} objects across {} shards (generation {})",
        keys.len(),
        elastic.shard_ids().len(),
        elastic.generation()
    );

    // A proxy minted NOW, at generation 0 — it must survive what follows.
    let early: Proxy<Bytes> = store.proxy(&objs[0])?;
    let early_wire = early.to_bytes();

    // ----------------------------------------------------------------
    // 2. Scale out: add a fourth server; only ~1/4 of the keys move.
    // ----------------------------------------------------------------
    let extra = ServerBuilder::new().spawn_kv().expect("kv server");
    elastic.add_shard(
        3,
        ConnectorDesc::TcpKv { addr: extra.addr.to_string() }.connect()?,
    )?;
    elastic.wait_quiescent(None);
    let m = elastic.metrics();
    let probe = KvClient::connect(extra.addr)?;
    println!(
        "scale-out: migrated {}/{} keys onto the new server (holds {}), \
         {} bytes moved",
        m.keys_migrated,
        keys.len(),
        probe.stats()?.0,
        m.bytes_moved
    );

    // ----------------------------------------------------------------
    // 3. Scale in: retire server 0, draining its keys onto the rest.
    // ----------------------------------------------------------------
    elastic.remove_shard(0)?;
    elastic.wait_quiescent(None);
    println!(
        "scale-in: fabric is now shards {:?} at generation {}",
        elastic.shard_ids(),
        elastic.generation()
    );

    // Every key still resolves through the final membership.
    let got: Vec<Option<Bytes>> = store.get_many(&keys)?;
    assert!(got.iter().all(|b| b.is_some()));
    println!("all {} objects survived both rebalances", keys.len());

    // ----------------------------------------------------------------
    // 4. The generation-0 proxy resolves against the live membership.
    // ----------------------------------------------------------------
    let shipped: Proxy<Bytes> = Proxy::from_bytes(&early_wire)?;
    shipped.factory().invalidate_cache();
    assert_eq!(shipped.resolve()?.0.len(), 32 * 1024);
    println!(
        "pre-rebalance proxy ({} wire bytes) resolved after 2 membership \
         changes",
        early_wire.len()
    );
    Ok(())
}
