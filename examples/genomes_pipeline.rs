//! End-to-end driver: the 1000 Genomes mutational-overlap workflow on a
//! synthetic genotype dataset, exercising the full stack — engine,
//! store, ProxyFutures, workflow DAG — and reporting the paper's headline
//! metric (Fig 8: makespan reduction from ProxyFutures pipelining).
//!
//! Run with: `cargo run --release --example genomes_pipeline`
//! The run is recorded in EXPERIMENTS.md.

use std::time::Duration;

use proxystore::apps::genomes::{run, run_reference, GenomesConfig};
use proxystore::benchlib::fmt_secs;
use proxystore::error::Result;
use proxystore::workflow::DataMode;

fn main() -> Result<()> {
    let cfg = GenomesConfig {
        individuals: 64,
        snps_per_chunk: 2000,
        chunks: 8,
        groups: 4,
        task_overhead: Duration::from_millis(60),
        compute_floor: Duration::from_millis(40),
        seed: 1000,
    };
    println!("1000 Genomes (synthetic) — {cfg:?}\n");

    // Ground truth from the single-process reference implementation.
    let want = run_reference(&cfg);
    println!(
        "reference: {} overlapping variants across {} individuals",
        want.len(),
        cfg.individuals
    );

    let mut baseline = None;
    for mode in [DataMode::NoProxy, DataMode::Proxy, DataMode::ProxyFuture] {
        let (report, freq) = run(&cfg, mode)?;
        assert_eq!(freq, want, "distributed result must match reference");
        println!(
            "\n[{}] makespan = {} (output verified ✓)",
            mode.label(),
            fmt_secs(report.makespan)
        );
        // Per-stage envelopes (the Fig 8 view).
        for stage in
            ["1-individuals", "2-merge", "3-sifting", "4-overlap", "5-frequency"]
        {
            let recs: Vec<_> = report
                .timeline
                .records()
                .into_iter()
                .filter(|r| {
                    r.stage == "compute"
                        && r.task.starts_with(stage.split_once('-').unwrap().1)
                })
                .collect();
            if let (Some(start), Some(end)) = (
                recs.iter().map(|r| r.start).fold(None, |a: Option<f64>, x| {
                    Some(a.map_or(x, |a| a.min(x)))
                }),
                recs.iter().map(|r| r.end).fold(None, |a: Option<f64>, x| {
                    Some(a.map_or(x, |a| a.max(x)))
                }),
            ) {
                println!("  {stage:<15} {:>8} → {:>8}", fmt_secs(start), fmt_secs(end));
            }
        }
        if mode == DataMode::NoProxy {
            baseline = Some(report.makespan);
        } else if mode == DataMode::ProxyFuture {
            let base = baseline.expect("baseline ran first");
            println!(
                "\nheadline: ProxyFutures reduces makespan by {:.1}% \
                 (paper reports 36% on Chameleon)",
                100.0 * (1.0 - report.makespan / base)
            );
        }
    }
    Ok(())
}
