//! Partitioned broker fabric: scale the ProxyStream event channel across
//! broker instances.
//!
//! Run with: `cargo run --release --example partitioned_stream`
//!
//! Demonstrates the fabric properties end to end:
//! 1. topic partitions spread over N real TCP broker servers via the
//!    consistent-hash ring (one logical event channel, N endpoints);
//! 2. per-key ordering: events routed by key stay in production order;
//! 3. consumer-group fan-in: members own disjoint partition slices and
//!    together drain the whole stream, each closing on end-of-stream.

use std::time::Duration;

use proxystore::broker::{BrokerFabric, BrokerServer};
use proxystore::net::ServerBuilder;
use proxystore::prelude::{Store, StreamConsumer, StreamProducer};
use proxystore::stream::{
    Metadata, PartitionedLogPublisher, PartitionedLogSubscriber,
};

fn main() -> proxystore::Result<()> {
    // ----------------------------------------------------------------
    // 1. A fabric over three real broker servers, eight partitions.
    // ----------------------------------------------------------------
    let servers: Vec<BrokerServer> = (0..3)
        .map(|_| ServerBuilder::new().spawn_broker().expect("broker server"))
        .collect();
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let fabric = BrokerFabric::connect(&addrs, 8)?;
    println!(
        "fabric: {} partitions over {} broker instances",
        fabric.partitions(),
        fabric.instance_count()
    );

    // ----------------------------------------------------------------
    // 2. Keyed production: each sensor's readings stay ordered because
    //    one key maps to one partition on one instance.
    // ----------------------------------------------------------------
    let store = Store::memory("sensors");
    let mut producer = StreamProducer::new(
        PartitionedLogPublisher::by_metadata_key(fabric.clone(), "sensor"),
        Some(store),
    );
    for i in 0..24u64 {
        let mut md = Metadata::new();
        md.insert("sensor".into(), format!("s{}", i % 3));
        md.insert("reading".into(), i.to_string());
        producer.send("telemetry", &i, md)?;
    }
    producer.close_topic("telemetry")?;

    // ----------------------------------------------------------------
    // 3. Two group members split the partition space and drain it.
    // ----------------------------------------------------------------
    let handles: Vec<_> = (0..2)
        .map(|member| {
            let fabric = fabric.clone();
            std::thread::spawn(move || -> proxystore::Result<Vec<u64>> {
                let sub = PartitionedLogSubscriber::with_group(
                    fabric,
                    "telemetry",
                    "dashboard",
                    member,
                    2,
                )?;
                println!(
                    "member {member} owns partitions {:?}",
                    sub.assigned()
                );
                let mut consumer = StreamConsumer::new(sub);
                let mut got = Vec::new();
                while let Some((proxy, md)) = consumer
                    .next_proxy::<u64>(Some(Duration::from_secs(5)))?
                {
                    let v = *proxy.resolve()?;
                    assert_eq!(md["reading"], v.to_string());
                    got.push(v);
                }
                Ok(got)
            })
        })
        .collect();

    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("member thread")?);
    }
    all.sort_unstable();
    assert_eq!(all, (0..24).collect::<Vec<_>>());
    println!(
        "both members closed on end-of-stream; {} events consumed exactly \
         once across the group",
        all.len()
    );
    Ok(())
}
