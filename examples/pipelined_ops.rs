//! Nonblocking op submission in ~60 lines: pipelined wire ops, async
//! store calls, and proxies minted while their writes are in flight.
//!
//! Run with: `cargo run --release --example pipelined_ops`

use std::time::Instant;

use proxystore::kv::KvClient;
use proxystore::net::ServerBuilder;
use proxystore::ops::Op;
use proxystore::prelude::Store;
use proxystore::store::TcpKvConnector;

fn main() -> proxystore::Result<()> {
    let server = ServerBuilder::new().spawn_kv()?;

    // ----------------------------------------------------------------
    // 1. Raw pipelining: submit a window, then wait. Every op is on the
    //    wire before the first response is consumed, so the whole window
    //    shares one round-trip stream.
    // ----------------------------------------------------------------
    let client = KvClient::connect(server.addr)?;
    let t0 = Instant::now();
    let window: Vec<_> = (0..64)
        .map(|i| {
            client.submit_op(Op::Put {
                key: format!("obj-{i}"),
                data: vec![i as u8; 256],
            })
        })
        .collect();
    println!(
        "64 ops submitted in {:?} ({} still in flight)",
        t0.elapsed(),
        client.in_flight()
    );
    for handle in window {
        handle.wait()?.into_unit()?;
    }
    println!("64 ops completed in {:?}", t0.elapsed());

    // ----------------------------------------------------------------
    // 2. The async store surface: issue work early, settle where the
    //    value is needed — resolution overlaps with compute.
    // ----------------------------------------------------------------
    let conn = std::sync::Arc::new(TcpKvConnector::connect(server.addr)?);
    let store = Store::new("pipe", conn);
    let write = store.put_async(&"computed elsewhere".to_string());
    let read = store.get_async::<String>("obj-that-does-not-exist");
    // ... compute here while both ops cross the wire ...
    write.wait()?;
    assert_eq!(read.wait()?, None);
    println!("async put landed under key {}", write.key());

    // ----------------------------------------------------------------
    // 3. proxy_async: mint the reference while the target's write is
    //    still in flight. The proxy has wait semantics (like a future),
    //    so resolving it simply parks until the write lands; wait on the
    //    handle where the write could fail (it surfaces the error).
    // ----------------------------------------------------------------
    let (proxy, write) = store.proxy_async(&vec![1.0f64, 2.0, 3.0]);
    println!("proxy target resolved: {:?}", *proxy.resolve()?);
    write.wait()?;
    Ok(())
}
