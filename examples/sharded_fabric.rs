//! Sharded store fabric: route one logical store across N backends.
//!
//! Run with: `cargo run --release --example sharded_fabric`
//!
//! Demonstrates the three fabric properties end to end:
//! 1. consistent-hash routing + batched MGET/MPUT over real TCP KV
//!    servers (one logical store, N endpoints);
//! 2. self-contained sharded proxies — the factory embeds the whole
//!    shard layout, so any process rebuilds the identical ring;
//! 3. replication with transparent read-fallback when a backend dies.

use std::sync::Arc;

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::kv::KvServer;
use proxystore::net::ServerBuilder;
use proxystore::prelude::{prefetch, Proxy, Store};
use proxystore::shard::{ShardedConnector, ShardedDesc};
use proxystore::store::{Connector, ConnectorDesc};

fn main() -> proxystore::Result<()> {
    // ----------------------------------------------------------------
    // 1. A fabric over four real redis-sim servers.
    // ----------------------------------------------------------------
    let servers: Vec<KvServer> =
        (0..4).map(|_| ServerBuilder::new().spawn_kv().expect("kv server")).collect();
    let desc = ShardedDesc::new(
        servers
            .iter()
            .map(|s| ConnectorDesc::TcpKv { addr: s.addr.to_string() })
            .collect(),
    )
    .with_replicas(2);
    let store = Store::new("fabric", desc.connect()?);

    let objs: Vec<Bytes> =
        (0..32).map(|i| Bytes(vec![i as u8; 64 * 1024])).collect();
    let keys = store.put_many(&objs)?; // one pipelined MPUT per shard
    let got: Vec<Option<Bytes>> = store.get_many(&keys)?; // parallel MGETs
    assert!(got.iter().all(|b| b.is_some()));
    println!(
        "stored {} objects across {} shards ({} resident overall, R=2)",
        keys.len(),
        servers.len(),
        store.connector().len()?
    );

    // ----------------------------------------------------------------
    // 2. Sharded proxies are self-contained: the wire bytes embed the
    //    full shard layout, and a batch prefetch amortizes round trips.
    // ----------------------------------------------------------------
    let proxies = store.proxy_many(&objs)?;
    let shipped: Vec<Proxy<Bytes>> = proxies
        .iter()
        .map(|p| Proxy::from_bytes(&p.to_bytes()))
        .collect::<proxystore::Result<_>>()?;
    let fetched = prefetch(&shipped)?;
    println!(
        "prefetched {fetched} targets in one batched sweep; proxy wire size \
         {} bytes",
        proxies[0].to_bytes().len()
    );
    assert_eq!(shipped[7].resolve()?.0, objs[7].0);

    // ----------------------------------------------------------------
    // 3. Kill one backend: replicated reads keep working.
    // ----------------------------------------------------------------
    let router = ShardedConnector::new(
        servers
            .iter()
            .map(|s| ConnectorDesc::TcpKv { addr: s.addr.to_string() }.connect())
            .collect::<proxystore::Result<Vec<_>>>()?,
        2,
        0,
    )?;
    let fabric_store = Store::new("fabric", Arc::new(router));
    let key = fabric_store.put(&Bytes(vec![42; 1024]))?;
    let mut servers = servers;
    drop(servers.remove(0)); // shut down shard 0's server
    let back: Option<Bytes> = fabric_store.get(&key)?;
    println!(
        "after killing a backend the object is {} (replica fallback)",
        if back.is_some() { "still readable" } else { "lost" }
    );
    Ok(())
}
