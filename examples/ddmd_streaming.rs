//! End-to-end driver: DeepDriveMD-style ML-in-the-loop molecular
//! dynamics, proving all three layers compose on a real workload:
//!
//!   L1 Pallas kernels (contact-map featurizer, fused dense layers)
//!   → L2 JAX autoencoder, AOT-lowered to HLO text
//!   → L3 Rust coordinator executing the artifacts via PJRT, moving
//!     batches with ProxyStream and model updates with ProxyFutures.
//!
//! Python never runs here — only `artifacts/*.hlo.txt` produced by
//! `make artifacts`. Reports the paper's Fig 9 headline (inference RTT).
//!
//! Run with: `cargo run --release --example ddmd_streaming`

use proxystore::apps::ddmd::{run_baseline, run_proxystream, DdmdConfig};
use proxystore::benchlib::fmt_secs;
use proxystore::error::Result;
use proxystore::runtime::{default_artifacts_dir, ModelRegistry};

fn main() -> Result<()> {
    let reg = ModelRegistry::load(default_artifacts_dir())?;
    println!(
        "loaded {} compiled models from {:?}",
        reg.manifest().models.len(),
        default_artifacts_dir()
    );
    println!(
        "autoencoder geometry: D={} H={} L={}\n",
        reg.geometry("feature_dim").unwrap_or(0),
        reg.geometry("hidden_dim").unwrap_or(0),
        reg.geometry("latent_dim").unwrap_or(0)
    );

    let cfg = DdmdConfig {
        rounds: 12,
        initial_batch: 2,
        batch_growth: 2,
        train: true,
        ..Default::default()
    };

    println!("== baseline: one engine task per inference batch ==");
    let base = run_baseline(&cfg, &reg)?;
    for r in &base.rounds {
        println!("  round {:>2}  batch {:>2}  rtt {}", r.round, r.batch, fmt_secs(r.rtt));
    }
    println!("  mean RTT = {}", fmt_secs(base.mean_rtt));

    println!("\n== ProxyStream: persistent inference actor ==");
    let ps = run_proxystream(&cfg, &reg)?;
    for r in &ps.rounds {
        println!("  round {:>2}  batch {:>2}  rtt {}", r.round, r.batch, fmt_secs(r.rtt));
    }
    println!(
        "  mean RTT = {} ({} model updates applied by the trainer)",
        fmt_secs(ps.mean_rtt),
        ps.model_updates
    );

    println!(
        "\nheadline: ProxyStream reduces inference RTT by {:.1}% \
         (paper reports 32% on Polaris)",
        100.0 * (1.0 - ps.mean_rtt / base.mean_rtt)
    );
    Ok(())
}
