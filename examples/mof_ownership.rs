//! End-to-end driver: MOF Generation campaign with automatic distributed
//! memory management (paper Fig 10).
//!
//! A thinker steers generate → assemble → score rounds; candidate blocks
//! travel as proxies and the physics surrogate runs as the compiled
//! `mof_score_c256` PJRT artifact (L1 Pallas scorer). Compares the number
//! of active proxied objects under default vs ownership management.
//!
//! Run with: `cargo run --release --example mof_ownership`

use proxystore::apps::mof::{run, MemoryMode, MofConfig};
use proxystore::error::Result;
use proxystore::runtime::{default_artifacts_dir, ModelRegistry};

fn main() -> Result<()> {
    let reg = ModelRegistry::load(default_artifacts_dir())?;
    let cfg = MofConfig {
        rounds: 8,
        generators: 3,
        top_k: 4,
        ..Default::default()
    };
    println!("MOF Generation — {cfg:?}\n");

    for mode in [MemoryMode::Default, MemoryMode::Ownership] {
        let report = run(&cfg, &reg, mode)?;
        println!("[{}]", mode.label());
        println!("  best candidate score: {:.4}", report.best_score);
        println!(
            "  active proxies: peak {} → final {}",
            report.series.peak_active(),
            report.series.final_active()
        );
        // A low-fi sparkline of the active-proxies series.
        let max = report.series.peak_active().max(1);
        let spark: String = report
            .series
            .samples
            .iter()
            .map(|(_, a, _)| {
                const RAMP: [char; 5] = [' ', '.', ':', '*', '#'];
                RAMP[((a * 4) / max).clamp(0, 4) as usize]
            })
            .collect();
        println!("  |{spark}|\n");
    }
    println!(
        "paper's Fig 10: ownership evicts proxies when lifetimes end while \
         default management accumulates them for the whole campaign."
    );
    Ok(())
}
