//! Hand-rolled CLI argument parsing (clap is not in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! subcommands — the subset the `proxystore` launcher needs.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand plus options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked");
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(arg);
            } else {
                return Err(Error::Config(format!(
                    "unexpected positional argument: {arg}"
                )));
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{name}: cannot parse {v:?}"))
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig5 --tasks 8 --size=10000000 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig5"));
        assert_eq!(a.get("tasks"), Some("8"));
        assert_eq!(a.get("size"), Some("10000000"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_parse("tasks", 0usize).unwrap(), 8);
        assert_eq!(a.get_parse("missing", 42u32).unwrap(), 42);
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse("x --n abc");
        assert!(a.get_parse::<u32>("n", 0).is_err());
    }

    #[test]
    fn extra_positional_rejected() {
        assert!(
            Args::parse(["a".to_string(), "b".to_string()]).is_err()
        );
    }

    #[test]
    fn trailing_flag() {
        let a = parse("serve --port 9000 --quiet");
        assert_eq!(a.get("port"), Some("9000"));
        assert!(a.flag("quiet"));
    }
}
