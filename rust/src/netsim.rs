//! Network simulation substrate.
//!
//! The paper's evaluation ran across Polaris nodes on a Slingshot-11
//! fabric; this reproduction runs on one machine, so transfer *cost* is
//! emulated instead of incurred. A [`Link`] models a point-to-point channel
//! with latency, bandwidth, and (optionally) a contention-free serialization
//! constraint: each transfer of `n` bytes occupies the link for
//! `latency + n / bandwidth`, and concurrent transfers queue behind each
//! other exactly as they would on a shared NIC.
//!
//! Connectors wrap themselves in [`Link::transfer`] calls so that the
//! benchmark shapes (dispatcher saturation in Fig 6, transfer overlap in
//! Fig 5) emerge from the same mechanism the paper's testbed exhibited.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A shared network link with latency/bandwidth and FIFO contention.
#[derive(Debug)]
pub struct Link {
    /// One-way latency applied to every transfer.
    pub latency: Duration,
    /// Bytes per second; `None` = infinite (latency-only link).
    pub bandwidth: Option<f64>,
    /// When the link frees up next (monotonic deadline), for contention.
    busy_until: Mutex<Option<Instant>>,
    /// Whether concurrent transfers contend (true = shared NIC semantics).
    contended: bool,
}

impl Link {
    /// A link with latency and bandwidth, with shared-NIC contention.
    pub fn new(latency: Duration, bandwidth_bytes_per_sec: f64) -> Self {
        Link {
            latency,
            bandwidth: Some(bandwidth_bytes_per_sec),
            busy_until: Mutex::new(None),
            contended: true,
        }
    }

    /// An ideal link: no latency, no bandwidth limit, no contention.
    pub fn ideal() -> Self {
        Link {
            latency: Duration::ZERO,
            bandwidth: None,
            busy_until: Mutex::new(None),
            contended: false,
        }
    }

    /// Latency-only link (e.g. a metadata channel).
    pub fn latency_only(latency: Duration) -> Self {
        Link {
            latency,
            bandwidth: None,
            busy_until: Mutex::new(None),
            contended: false,
        }
    }

    /// Disable contention: transfers overlap freely (full-duplex fabric).
    pub fn uncontended(mut self) -> Self {
        self.contended = false;
        self
    }

    /// Pure wire time for `n` bytes (no queueing).
    pub fn wire_time(&self, n: usize) -> Duration {
        let bw = match self.bandwidth {
            Some(b) if b > 0.0 => Duration::from_secs_f64(n as f64 / b),
            _ => Duration::ZERO,
        };
        self.latency + bw
    }

    /// Block the calling thread for the simulated duration of transferring
    /// `n` bytes, including queueing behind concurrent transfers.
    pub fn transfer(&self, n: usize) {
        let wire = self.wire_time(n);
        if wire.is_zero() {
            return;
        }
        if !self.contended {
            spin_sleep(wire);
            return;
        }
        // Reserve a slot on the link: start when the link frees up.
        let end = {
            let mut busy = self.busy_until.lock().unwrap();
            let now = Instant::now();
            let start = match *busy {
                Some(t) if t > now => t,
                _ => now,
            };
            let end = start + wire;
            *busy = Some(end);
            end
        };
        let now = Instant::now();
        if end > now {
            spin_sleep(end - now);
        }
    }

    /// Estimate queue depth in time units (for metrics / backpressure).
    pub fn backlog(&self) -> Duration {
        let busy = self.busy_until.lock().unwrap();
        match *busy {
            Some(t) => t.saturating_duration_since(Instant::now()),
            None => Duration::ZERO,
        }
    }
}

/// Sleep that stays accurate for sub-millisecond durations: OS sleep for
/// the bulk, spin for the tail. The benches depend on fine-grained waits.
pub fn spin_sleep(d: Duration) {
    let deadline = Instant::now() + d;
    if d > Duration::from_millis(2) {
        std::thread::sleep(d - Duration::from_millis(1));
    }
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}

/// Common testbed profiles, scaled for a single-node reproduction.
pub mod profiles {
    use super::*;

    /// Datacenter-ish link used by default in the benches: 50 us latency,
    /// 2 GB/s (scaled-down Slingshot share per endpoint pair).
    pub fn cluster() -> Link {
        Link::new(Duration::from_micros(50), 2.0e9)
    }

    /// The dispatcher's client NIC in Fig 6: the paper observed the
    /// dispatcher processing stream data at ~100 MB/s (including
    /// deserialize/reserialize); we model the wire share at 1 GB/s and let
    /// the serialization cost come from actually copying bytes.
    pub fn client_nic() -> Link {
        Link::new(Duration::from_micros(100), 1.0e9)
    }

    /// Wide-area-ish link for cross-site scenarios.
    pub fn wan() -> Link {
        Link::new(Duration::from_millis(20), 1.0e8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_scales_with_size() {
        let l = Link::new(Duration::from_millis(1), 1_000_000.0);
        assert_eq!(l.wire_time(0), Duration::from_millis(1));
        let t = l.wire_time(1_000_000);
        assert!(t >= Duration::from_millis(1000));
    }

    #[test]
    fn ideal_link_is_free() {
        let l = Link::ideal();
        let t0 = Instant::now();
        l.transfer(100_000_000);
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn transfer_blocks_for_wire_time() {
        let l = Link::new(Duration::from_millis(5), 1.0e9);
        let t0 = Instant::now();
        l.transfer(1_000_000); // 5ms + 1ms
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(6), "{dt:?}");
        assert!(dt < Duration::from_millis(60), "{dt:?}");
    }

    #[test]
    fn contended_transfers_serialize() {
        use std::sync::Arc;
        let l = Arc::new(Link::new(Duration::from_millis(4), 1.0e12));
        let t0 = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || l.transfer(1))
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // 4 transfers x 4ms each must serialize: >= ~16ms.
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(14), "{dt:?}");
    }

    #[test]
    fn uncontended_transfers_overlap() {
        use std::sync::Arc;
        let l = Arc::new(
            Link::new(Duration::from_millis(10), 1.0e12).uncontended(),
        );
        let t0 = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || l.transfer(1))
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let dt = t0.elapsed();
        assert!(dt < Duration::from_millis(35), "{dt:?}");
    }

    #[test]
    fn spin_sleep_accuracy() {
        let t0 = Instant::now();
        spin_sleep(Duration::from_micros(300));
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_micros(300));
        assert!(dt < Duration::from_millis(5), "{dt:?}");
    }
}
