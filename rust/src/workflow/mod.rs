//! Workflow layer: DAG pipelines with pluggable data-flow modes.
//!
//! This is the machinery behind the Fig 5 synthetic pipeline and the
//! Fig 8 1000 Genomes reproduction. A [`Pipeline`] is a DAG of
//! [`PipelineTask`]s; each task has a *startup overhead* span (library
//! loading, model init — the fraction `f` in the paper), then needs its
//! input data, then computes. The pipeline can execute under three
//! [`DataMode`]s:
//!
//! * [`DataMode::NoProxy`] — results return to the client, successors are
//!   submitted only after parents complete, and full payloads traverse the
//!   engine's client→worker link (the workflow-engine baseline);
//! * [`DataMode::Proxy`] — same control flow, but payloads are proxies and
//!   bulk bytes move store↔worker (offloading the engine);
//! * [`DataMode::ProxyFuture`] — every task is submitted immediately with
//!   proxies of its parents' *futures*; tasks overlap their startup
//!   overhead with their parents' compute (Fig 3's pipelining).
//!
//! Every lifecycle span (`submit`, `overhead`, `resolve`, `compute`,
//! `generate`, `receive`) is recorded on a [`Timeline`], which the benches
//! render as Fig 5a-style Gantt charts.

use std::sync::Arc;
use std::time::Duration;

use crate::codec::{Bytes, Decode, Encode};
use crate::engine::{ClusterConfig, LocalCluster, TaskFuture, WorkerCtx};
use crate::error::{Error, Result};
use crate::futures::ProxyFuture;
use crate::metrics::Timeline;
use crate::netsim::spin_sleep;
use crate::proxy::Proxy;
use crate::store::Store;

/// How intermediate data moves between tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataMode {
    NoProxy,
    Proxy,
    ProxyFuture,
}

impl DataMode {
    pub fn label(&self) -> &'static str {
        match self {
            DataMode::NoProxy => "no-proxy",
            DataMode::Proxy => "proxy",
            DataMode::ProxyFuture => "proxyfuture",
        }
    }
}

/// The actual computation a task performs on its inputs (dep outputs, in
/// dependency order). `None` tasks synthesize `output_bytes` of data.
pub type WorkFn = Arc<
    dyn Fn(&WorkerCtx, Vec<Vec<u8>>) -> Result<Vec<u8>> + Send + Sync + 'static,
>;

/// One node of the pipeline DAG.
pub struct PipelineTask {
    pub name: String,
    /// Stage label (aggregated in Fig 8's per-stage rendering).
    pub stage: String,
    /// Indices of dependency tasks (must be < this task's index).
    pub deps: Vec<usize>,
    /// Startup overhead before input data is needed (`f × s`).
    pub overhead: Duration,
    /// Compute time after inputs are available (`(1-f) × s`).
    pub compute: Duration,
    /// Real work over inputs; `None` = synthesize `output_bytes`.
    pub work: Option<WorkFn>,
    /// Synthetic output size when `work` is `None`.
    pub output_bytes: usize,
}

impl PipelineTask {
    /// A synthetic sleep-and-produce task (the Fig 5 micro-benchmark).
    pub fn synthetic(
        name: &str,
        stage: &str,
        deps: Vec<usize>,
        overhead: Duration,
        compute: Duration,
        output_bytes: usize,
    ) -> PipelineTask {
        PipelineTask {
            name: name.into(),
            stage: stage.into(),
            deps,
            overhead,
            compute,
            work: None,
            output_bytes,
        }
    }
}

/// Pipeline run report.
pub struct RunReport {
    pub timeline: Arc<Timeline>,
    pub makespan: f64,
    /// Final task outputs (by task index) for correctness checks;
    /// populated only for sink tasks (no dependents) to bound memory.
    pub sink_outputs: Vec<(usize, Vec<u8>)>,
}

/// A DAG of tasks executed on a [`LocalCluster`] under a [`DataMode`].
pub struct Pipeline {
    pub tasks: Vec<PipelineTask>,
}

impl Pipeline {
    pub fn new(tasks: Vec<PipelineTask>) -> Result<Pipeline> {
        for (i, t) in tasks.iter().enumerate() {
            for &d in &t.deps {
                if d >= i {
                    return Err(Error::Config(format!(
                        "task {i} ({}) depends on later task {d}",
                        t.name
                    )));
                }
            }
        }
        Ok(Pipeline { tasks })
    }

    fn sinks(&self) -> Vec<usize> {
        let mut has_dependent = vec![false; self.tasks.len()];
        for t in &self.tasks {
            for &d in &t.deps {
                has_dependent[d] = true;
            }
        }
        (0..self.tasks.len()).filter(|&i| !has_dependent[i]).collect()
    }

    /// Execute and record a timeline.
    pub fn run(
        &self,
        cluster: &Arc<LocalCluster>,
        store: &Store,
        mode: DataMode,
    ) -> Result<RunReport> {
        let timeline = Arc::new(Timeline::new());
        match mode {
            DataMode::ProxyFuture => {
                self.run_proxyfuture(cluster, store, &timeline)
            }
            _ => self.run_sequential(cluster, store, mode, &timeline),
        }
        .map(|sink_outputs| {
            let makespan = timeline.makespan();
            RunReport { timeline, makespan, sink_outputs }
        })
    }

    /// NoProxy / Proxy: submit a task only when its parents are done.
    fn run_sequential(
        &self,
        cluster: &Arc<LocalCluster>,
        store: &Store,
        mode: DataMode,
        timeline: &Arc<Timeline>,
    ) -> Result<Vec<(usize, Vec<u8>)>> {
        let mut futures: Vec<Option<TaskFuture>> = Vec::new();
        let mut outputs: Vec<Option<Vec<u8>>> = vec![None; self.tasks.len()];
        for task in self.tasks.iter() {
            // Client-side wait for parents (control-flow sync).
            let mut inputs: Vec<Vec<u8>> = Vec::with_capacity(task.deps.len());
            for &d in &task.deps {
                if outputs[d].is_none() {
                    let fut = futures[d].as_ref().expect("dep submitted");
                    let bytes = timeline.timed(
                        &self.tasks[d].name,
                        "receive",
                        || fut.wait(),
                    )?;
                    outputs[d] = Some(bytes);
                }
                inputs.push(outputs[d].clone().expect("filled"));
            }

            // Build the payload: full data (NoProxy) or proxies (Proxy).
            let payload = match mode {
                DataMode::NoProxy => inputs.to_bytes(),
                DataMode::Proxy => {
                    let proxies: Vec<Proxy<Bytes>> = inputs
                        .iter()
                        .map(|raw| store.proxy(&Bytes(raw.clone())))
                        .collect::<Result<_>>()?;
                    proxies.to_bytes()
                }
                DataMode::ProxyFuture => unreachable!(),
            };

            let name = task.name.clone();
            let stage = task.stage.clone();
            let overhead = task.overhead;
            let compute = task.compute;
            let output_bytes = task.output_bytes;
            let work = task.work.clone();
            let tl = timeline.clone();
            let mode_inner = mode;
            let fut = timeline.timed(&task.name, "submit", || {
                cluster.submit(
                    Box::new(move |ctx, payload| {
                        tl.timed(&name, "overhead", || spin_sleep(overhead));
                        let inputs: Vec<Vec<u8>> =
                            tl.timed(&name, "resolve", || match mode_inner {
                                DataMode::NoProxy => {
                                    Vec::<Vec<u8>>::from_bytes(&payload)
                                }
                                _ => {
                                    let proxies: Vec<Proxy<Bytes>> =
                                        Vec::from_bytes(&payload)?;
                                    proxies
                                        .into_iter()
                                        .map(|p| p.into_inner().map(|b| b.0))
                                        .collect()
                                }
                            })?;
                        tl.timed(&name, "compute", || spin_sleep(compute));
                        let _ = &stage;
                        tl.timed(&name, "generate", || match &work {
                            Some(f) => f(ctx, inputs),
                            None => Ok(vec![0u8; output_bytes]),
                        })
                    }),
                    payload,
                )
            });
            futures.push(Some(fut));
        }

        // Drain sinks through the client.
        let mut sink_outputs = Vec::new();
        for s in self.sinks() {
            let bytes = match outputs[s].take() {
                Some(b) => b,
                None => timeline.timed(&self.tasks[s].name, "receive", || {
                    futures[s].as_ref().expect("submitted").wait()
                })?,
            };
            sink_outputs.push((s, bytes));
        }
        Ok(sink_outputs)
    }

    /// ProxyFuture: everything submitted up front; data deps ride futures.
    fn run_proxyfuture(
        &self,
        cluster: &Arc<LocalCluster>,
        store: &Store,
        timeline: &Arc<Timeline>,
    ) -> Result<Vec<(usize, Vec<u8>)>> {
        // One future per task output, minted before anything runs.
        let futs: Vec<ProxyFuture<Bytes>> =
            self.tasks.iter().map(|_| store.future()).collect();
        let mut task_futs: Vec<TaskFuture> =
            Vec::with_capacity(self.tasks.len());

        for (i, task) in self.tasks.iter().enumerate() {
            let _ = i;
            let dep_proxies: Vec<Proxy<Bytes>> =
                task.deps.iter().map(|&d| futs[d].proxy()).collect();
            let payload = dep_proxies.to_bytes();
            let own_future = futs[i].clone();
            let name = task.name.clone();
            let overhead = task.overhead;
            let compute = task.compute;
            let output_bytes = task.output_bytes;
            let work = task.work.clone();
            let tl = timeline.clone();
            let fut = timeline.timed(&task.name, "submit", || {
                cluster.submit(
                    Box::new(move |ctx, payload| {
                        tl.timed(&name, "overhead", || spin_sleep(overhead));
                        let proxies: Vec<Proxy<Bytes>> =
                            Vec::from_bytes(&payload)?;
                        // Blocks until parents set their futures.
                        let inputs: Vec<Vec<u8>> =
                            tl.timed(&name, "resolve", || {
                                proxies
                                    .into_iter()
                                    .map(|p| p.into_inner().map(|b| b.0))
                                    .collect::<Result<_>>()
                            })?;
                        tl.timed(&name, "compute", || spin_sleep(compute));
                        let out = tl.timed(&name, "generate", || {
                            let bytes = match &work {
                                Some(f) => f(ctx, inputs)?,
                                None => vec![0u8; output_bytes],
                            };
                            own_future.set_result(&Bytes(bytes.clone()))?;
                            Ok::<_, Error>(bytes)
                        })?;
                        let _ = out;
                        Ok(Vec::new())
                    }),
                    payload,
                )
            });
            task_futs.push(fut);
        }

        // Client waits on sink futures (cheap: proxies of results). Task
        // futures are drained first so worker-side errors propagate
        // instead of hanging the value future.
        let mut sink_outputs = Vec::new();
        for s in self.sinks() {
            let bytes = timeline.timed(&self.tasks[s].name, "receive", || {
                task_futs[s].wait()?;
                futs[s].result(Some(Duration::from_secs(30)))
            })?;
            sink_outputs.push((s, bytes.0));
        }
        Ok(sink_outputs)
    }
}

/// Build the Fig 5 synthetic chain: `n` tasks in sequence, each with
/// overhead `f*s`, compute `(1-f)*s`, producing `d` bytes for its
/// successor.
pub fn synthetic_chain(
    n: usize,
    s: Duration,
    f: f64,
    d: usize,
) -> Pipeline {
    let overhead = Duration::from_secs_f64(s.as_secs_f64() * f);
    let compute = Duration::from_secs_f64(s.as_secs_f64() * (1.0 - f));
    let tasks = (0..n)
        .map(|i| {
            PipelineTask::synthetic(
                &format!("t{i}"),
                "chain",
                if i == 0 { vec![] } else { vec![i - 1] },
                overhead,
                compute,
                d,
            )
        })
        .collect();
    Pipeline::new(tasks).expect("chain is a valid DAG")
}

/// Cluster sized for a pipeline under ProxyFuture (every task may occupy a
/// worker while blocked on its parent).
pub fn cluster_for(n_tasks: usize, config: ClusterConfig) -> Arc<LocalCluster> {
    Arc::new(LocalCluster::new(ClusterConfig {
        workers: n_tasks.max(config.workers),
        ..config
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cluster(workers: usize) -> Arc<LocalCluster> {
        Arc::new(LocalCluster::new(ClusterConfig {
            workers,
            ..Default::default()
        }))
    }

    #[test]
    fn invalid_dag_rejected() {
        let t = PipelineTask::synthetic(
            "a",
            "s",
            vec![0],
            Duration::ZERO,
            Duration::ZERO,
            0,
        );
        assert!(Pipeline::new(vec![t]).is_err());
    }

    #[test]
    fn all_modes_produce_same_outputs() {
        // A diamond: a → (b, c) → d, with real work functions.
        let work_double: WorkFn = Arc::new(|_, inputs| {
            Ok(inputs[0].iter().map(|b| b.wrapping_mul(2)).collect())
        });
        let work_concat: WorkFn = Arc::new(|_, inputs| {
            Ok(inputs.concat())
        });
        let make = || {
            Pipeline::new(vec![
                PipelineTask {
                    name: "a".into(),
                    stage: "s1".into(),
                    deps: vec![],
                    overhead: Duration::from_millis(5),
                    compute: Duration::from_millis(5),
                    work: Some(Arc::new(|_, _| Ok(vec![1, 2, 3]))),
                    output_bytes: 0,
                },
                PipelineTask {
                    name: "b".into(),
                    stage: "s2".into(),
                    deps: vec![0],
                    overhead: Duration::from_millis(5),
                    compute: Duration::from_millis(5),
                    work: Some(work_double.clone()),
                    output_bytes: 0,
                },
                PipelineTask {
                    name: "c".into(),
                    stage: "s2".into(),
                    deps: vec![0],
                    overhead: Duration::from_millis(5),
                    compute: Duration::from_millis(5),
                    work: Some(work_double.clone()),
                    output_bytes: 0,
                },
                PipelineTask {
                    name: "d".into(),
                    stage: "s3".into(),
                    deps: vec![1, 2],
                    overhead: Duration::from_millis(5),
                    compute: Duration::from_millis(5),
                    work: Some(work_concat.clone()),
                    output_bytes: 0,
                },
            ])
            .unwrap()
        };
        for mode in
            [DataMode::NoProxy, DataMode::Proxy, DataMode::ProxyFuture]
        {
            let cluster = quick_cluster(4);
            let store = Store::memory("wf");
            let report = make().run(&cluster, &store, mode).unwrap();
            assert_eq!(report.sink_outputs.len(), 1, "{mode:?}");
            assert_eq!(
                report.sink_outputs[0].1,
                vec![2, 4, 6, 2, 4, 6],
                "{mode:?}"
            );
        }
    }

    #[test]
    fn proxyfuture_pipelines_overhead() {
        // 4 tasks × (40ms overhead + 40ms compute). Sequential ≥ ~320ms;
        // pipelined overlaps the 40ms overheads → makespan ≈ 40 + 4*40.
        let n = 4;
        let s = Duration::from_millis(80);
        let chain = synthetic_chain(n, s, 0.5, 1000);
        let store = Store::memory("wf");

        let cluster = quick_cluster(n);
        let seq = chain.run(&cluster, &store, DataMode::Proxy).unwrap();
        let cluster = quick_cluster(n);
        let chain = synthetic_chain(n, s, 0.5, 1000);
        let pipe = chain.run(&cluster, &store, DataMode::ProxyFuture).unwrap();

        assert!(
            pipe.makespan < seq.makespan * 0.85,
            "pipelined {:.3}s vs sequential {:.3}s",
            pipe.makespan,
            seq.makespan
        );
    }

    #[test]
    fn timeline_contains_all_stages() {
        let chain = synthetic_chain(3, Duration::from_millis(30), 0.3, 100);
        let cluster = quick_cluster(3);
        let store = Store::memory("wf");
        let report = chain.run(&cluster, &store, DataMode::Proxy).unwrap();
        let recs = report.timeline.records();
        for span in ["submit", "overhead", "resolve", "compute", "generate"] {
            assert!(
                recs.iter().any(|r| r.stage == span),
                "missing span {span}"
            );
        }
        assert!(report.makespan > 0.0);
    }

    #[test]
    fn work_error_propagates_in_all_modes() {
        let failing: WorkFn =
            Arc::new(|_, _| Err(Error::Task("bad work".into())));
        for mode in
            [DataMode::NoProxy, DataMode::Proxy, DataMode::ProxyFuture]
        {
            let p = Pipeline::new(vec![PipelineTask {
                name: "x".into(),
                stage: "s".into(),
                deps: vec![],
                overhead: Duration::ZERO,
                compute: Duration::ZERO,
                work: Some(failing.clone()),
                output_bytes: 0,
            }])
            .unwrap();
            let cluster = quick_cluster(1);
            let store = Store::memory("wf");
            let r = p.run(&cluster, &store, mode);
            assert!(r.is_err(), "{mode:?} must surface work errors");
        }
    }
}
