//! LocalCluster: scheduler + worker pool with modelled data movement.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::netsim::{spin_sleep, Link};
use crate::runtime::ModelRegistry;

use super::{DoneCallback, TaskFn};

/// Cluster configuration.
pub struct ClusterConfig {
    /// Worker thread count.
    pub workers: usize,
    /// Per-task submission overhead at the scheduler (engine bookkeeping,
    /// serialization of the task graph, etc. — Fig 5's `submit` span).
    pub submit_overhead: Duration,
    /// Link task payloads traverse client→worker (None = free).
    pub submit_link: Option<Arc<Link>>,
    /// Link results traverse worker→client (None = free).
    pub result_link: Option<Arc<Link>>,
    /// Compiled-model registry exposed to workers (PJRT executables).
    pub models: Option<Arc<ModelRegistry>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: 4,
            submit_overhead: Duration::ZERO,
            submit_link: None,
            result_link: None,
            models: None,
        }
    }
}

/// Context handed to every task.
pub struct WorkerCtx {
    pub worker_id: usize,
    /// Compiled models, when the cluster was configured with them.
    pub models: Option<Arc<ModelRegistry>>,
}

struct Job {
    func: TaskFn,
    payload: Vec<u8>,
    handle: Arc<TaskState>,
}

#[derive(Default)]
struct TaskState {
    done: Mutex<Option<Result<Vec<u8>>>>,
    cv: Condvar,
    callbacks: Mutex<Vec<DoneCallback>>,
}

impl TaskState {
    fn complete(&self, result: Result<Vec<u8>>) {
        let callbacks: Vec<DoneCallback> =
            std::mem::take(&mut *self.callbacks.lock().unwrap());
        for cb in callbacks {
            cb(&result);
        }
        *self.done.lock().unwrap() = Some(result);
        self.cv.notify_all();
    }
}

/// Future for a submitted task's serialized result.
#[derive(Clone)]
pub struct TaskFuture {
    state: Arc<TaskState>,
    pub task_id: u64,
}

/// Alias used by the executor layer.
pub type TaskHandle = TaskFuture;

impl TaskFuture {
    /// Block for the raw result bytes.
    pub fn wait(&self) -> Result<Vec<u8>> {
        let mut done = self.state.done.lock().unwrap();
        while done.is_none() {
            done = self.state.cv.wait(done).unwrap();
        }
        done.clone().expect("checked above")
    }

    /// Block with a timeout.
    pub fn wait_timeout(&self, timeout: Duration) -> Result<Vec<u8>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut done = self.state.done.lock().unwrap();
        while done.is_none() {
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(Error::Timeout(timeout, format!(
                    "task {}", self.task_id
                )));
            }
            let (guard, _) =
                self.state.cv.wait_timeout(done, deadline - now).unwrap();
            done = guard;
        }
        done.clone().expect("checked above")
    }

    pub fn is_done(&self) -> bool {
        self.state.done.lock().unwrap().is_some()
    }

    /// Attach a completion callback. If the task already finished, the
    /// callback runs immediately (on the caller's thread) — this is the
    /// hook the ownership StoreExecutor uses to release borrows.
    pub fn on_done(&self, cb: DoneCallback) {
        // Fast path check under the result lock to avoid racing complete().
        let done = self.state.done.lock().unwrap();
        if let Some(result) = done.as_ref() {
            cb(result);
        } else {
            self.state.callbacks.lock().unwrap().push(cb);
        }
    }
}

struct Queue {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A Dask-like local cluster: one scheduler queue, N worker threads.
pub struct LocalCluster {
    queue: Arc<Queue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_task: AtomicU64,
    config_submit_overhead: Duration,
    submit_link: Option<Arc<Link>>,
    #[allow(dead_code)] // kept for symmetry/diagnostics; workers hold a clone
    result_link: Option<Arc<Link>>,
    /// Tasks completed (throughput metric).
    completed: Arc<AtomicU64>,
}

impl LocalCluster {
    pub fn new(config: ClusterConfig) -> LocalCluster {
        let queue = Arc::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let completed = Arc::new(AtomicU64::new(0));
        let workers = (0..config.workers.max(1))
            .map(|worker_id| {
                let queue = queue.clone();
                let models = config.models.clone();
                let result_link = config.result_link.clone();
                let completed = completed.clone();
                std::thread::Builder::new()
                    .name(format!("worker-{worker_id}"))
                    .spawn(move || {
                        let ctx = WorkerCtx { worker_id, models };
                        loop {
                            let job = {
                                let mut jobs = queue.jobs.lock().unwrap();
                                loop {
                                    if let Some(j) = jobs.pop_front() {
                                        break j;
                                    }
                                    if queue.shutdown.load(Ordering::Relaxed) {
                                        return;
                                    }
                                    let (guard, _) = queue
                                        .cv
                                        .wait_timeout(
                                            jobs,
                                            Duration::from_millis(50),
                                        )
                                        .unwrap();
                                    jobs = guard;
                                }
                            };
                            let payload = job.payload;
                            let result = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    (job.func)(&ctx, payload)
                                }),
                            )
                            .unwrap_or_else(|p| {
                                let msg = p
                                    .downcast_ref::<&str>()
                                    .map(|s| s.to_string())
                                    .or_else(|| {
                                        p.downcast_ref::<String>().cloned()
                                    })
                                    .unwrap_or_else(|| "task panicked".into());
                                Err(Error::Task(msg))
                            });
                            // Result bytes traverse the worker→client link.
                            if let (Some(link), Ok(bytes)) =
                                (&result_link, &result)
                            {
                                link.transfer(bytes.len());
                            }
                            completed.fetch_add(1, Ordering::Relaxed);
                            job.handle.complete(result);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        LocalCluster {
            queue,
            workers,
            next_task: AtomicU64::new(0),
            config_submit_overhead: config.submit_overhead,
            submit_link: config.submit_link,
            result_link: config.result_link,
            completed,
        }
    }

    /// Submit a task with a serialized payload; returns its future.
    ///
    /// Models the engine's costs: fixed submission overhead plus payload
    /// wire time on the client→worker link.
    pub fn submit(&self, func: TaskFn, payload: Vec<u8>) -> TaskFuture {
        if !self.config_submit_overhead.is_zero() {
            spin_sleep(self.config_submit_overhead);
        }
        if let Some(link) = &self.submit_link {
            link.transfer(payload.len());
        }
        let state = Arc::<TaskState>::default();
        let fut = TaskFuture {
            state: state.clone(),
            task_id: self.next_task.fetch_add(1, Ordering::Relaxed),
        };
        let job = Job { func, payload, handle: state };
        let mut jobs = self.queue.jobs.lock().unwrap();
        jobs.push_back(job);
        self.queue.cv.notify_one();
        fut
    }

    /// Submit once all `deps` complete (spawns a waiter thread; the
    /// control-flow-synchronized baseline the paper critiques).
    pub fn submit_after(
        self: &Arc<Self>,
        deps: Vec<TaskFuture>,
        func: TaskFn,
        payload_fn: impl FnOnce(Vec<Result<Vec<u8>>>) -> Vec<u8> + Send + 'static,
    ) -> TaskFuture {
        let state = Arc::<TaskState>::default();
        let fut = TaskFuture {
            state: state.clone(),
            task_id: u64::MAX, // assigned at real submission
        };
        let cluster = self.clone();
        std::thread::Builder::new()
            .name("dep-waiter".into())
            .spawn(move || {
                let results: Vec<Result<Vec<u8>>> =
                    deps.iter().map(|d| d.wait()).collect();
                if let Some(err) =
                    results.iter().find_map(|r| r.as_ref().err())
                {
                    state.complete(Err(err.clone()));
                    return;
                }
                let payload = payload_fn(results);
                let inner = cluster.submit(func, payload);
                let result = inner.wait();
                state.complete(result);
            })
            .expect("spawn dep-waiter");
        fut
    }

    /// Tasks completed so far.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Pending (queued, unstarted) tasks.
    pub fn queued(&self) -> usize {
        self.queue.jobs.lock().unwrap().len()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Stop accepting work and join workers (queued jobs are dropped;
    /// their futures error).
    pub fn shutdown(mut self) {
        self.queue.shutdown.store(true, Ordering::Relaxed);
        self.queue.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Fail any jobs that never ran.
        let mut jobs = self.queue.jobs.lock().unwrap();
        for job in jobs.drain(..) {
            job.handle
                .complete(Err(Error::Task("cluster shut down".into())));
        }
    }
}

impl Drop for LocalCluster {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::Relaxed);
        self.queue.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{Decode, Encode};

    fn cluster(workers: usize) -> LocalCluster {
        LocalCluster::new(ClusterConfig { workers, ..Default::default() })
    }

    #[test]
    fn submit_and_wait() {
        let c = cluster(2);
        let fut = c.submit(
            Box::new(|_ctx, payload| {
                let x = u64::from_bytes(&payload)?;
                Ok((x * 2).to_bytes())
            }),
            21u64.to_bytes(),
        );
        assert_eq!(u64::from_bytes(&fut.wait().unwrap()).unwrap(), 42);
        assert_eq!(c.completed(), 1);
    }

    #[test]
    fn tasks_run_concurrently() {
        let c = cluster(4);
        let t0 = std::time::Instant::now();
        let futs: Vec<_> = (0..4)
            .map(|_| {
                c.submit(
                    Box::new(|_, _| {
                        std::thread::sleep(Duration::from_millis(50));
                        Ok(vec![])
                    }),
                    vec![],
                )
            })
            .collect();
        for f in futs {
            f.wait().unwrap();
        }
        assert!(t0.elapsed() < Duration::from_millis(150));
    }

    #[test]
    fn worker_ids_are_distinct() {
        let c = cluster(3);
        let futs: Vec<_> = (0..12)
            .map(|_| {
                c.submit(
                    Box::new(|ctx, _| {
                        std::thread::sleep(Duration::from_millis(10));
                        Ok((ctx.worker_id as u64).to_bytes())
                    }),
                    vec![],
                )
            })
            .collect();
        let ids: std::collections::HashSet<u64> = futs
            .iter()
            .map(|f| u64::from_bytes(&f.wait().unwrap()).unwrap())
            .collect();
        assert!(ids.len() > 1, "work should spread across workers: {ids:?}");
    }

    #[test]
    fn task_error_propagates() {
        let c = cluster(1);
        let fut = c.submit(
            Box::new(|_, _| Err(Error::Task("deliberate".into()))),
            vec![],
        );
        assert!(matches!(fut.wait(), Err(Error::Task(m)) if m == "deliberate"));
    }

    #[test]
    fn task_panic_is_captured() {
        let c = cluster(1);
        let fut = c.submit(Box::new(|_, _| panic!("boom-{}", 7)), vec![]);
        match fut.wait() {
            Err(Error::Task(m)) => assert!(m.contains("boom"), "{m}"),
            other => panic!("expected Task error, got {other:?}"),
        }
        // Worker survives the panic.
        let ok = c.submit(Box::new(|_, _| Ok(vec![1])), vec![]);
        assert_eq!(ok.wait().unwrap(), vec![1]);
    }

    #[test]
    fn callbacks_fire_on_completion() {
        let c = cluster(1);
        let hit = Arc::new(AtomicU64::new(0));
        let h2 = hit.clone();
        let fut = c.submit(Box::new(|_, _| Ok(vec![])), vec![]);
        fut.on_done(Box::new(move |r| {
            assert!(r.is_ok());
            h2.fetch_add(1, Ordering::Relaxed);
        }));
        fut.wait().unwrap();
        // Allow the callback ordering (fires before complete publishes).
        assert_eq!(hit.load(Ordering::Relaxed), 1);
        // Late registration fires immediately.
        let h3 = hit.clone();
        fut.on_done(Box::new(move |_| {
            h3.fetch_add(1, Ordering::Relaxed);
        }));
        assert_eq!(hit.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn submit_after_chains_dependencies() {
        let c = Arc::new(cluster(2));
        let a = c.submit(
            Box::new(|_, _| {
                std::thread::sleep(Duration::from_millis(30));
                Ok(5u64.to_bytes())
            }),
            vec![],
        );
        let b = c.submit_after(
            vec![a],
            Box::new(|_, payload| {
                let x = u64::from_bytes(&payload)?;
                Ok((x + 1).to_bytes())
            }),
            |results| results[0].clone().unwrap(),
        );
        assert_eq!(u64::from_bytes(&b.wait().unwrap()).unwrap(), 6);
    }

    #[test]
    fn submit_after_propagates_dep_failure() {
        let c = Arc::new(cluster(1));
        let bad = c.submit(Box::new(|_, _| Err(Error::Task("dep".into()))), vec![]);
        let b = c.submit_after(
            vec![bad],
            Box::new(|_, _| Ok(vec![])),
            |_| vec![],
        );
        assert!(matches!(b.wait(), Err(Error::Task(_))));
    }

    #[test]
    fn submit_overhead_and_links_cost_time() {
        let c = LocalCluster::new(ClusterConfig {
            workers: 1,
            submit_overhead: Duration::from_millis(5),
            submit_link: Some(Arc::new(Link::new(
                Duration::from_millis(5),
                1.0e9,
            ))),
            ..Default::default()
        });
        let t0 = std::time::Instant::now();
        let fut = c.submit(Box::new(|_, _| Ok(vec![])), vec![0; 1000]);
        assert!(t0.elapsed() >= Duration::from_millis(10), "{:?}", t0.elapsed());
        fut.wait().unwrap();
    }

    #[test]
    fn wait_timeout_errors() {
        let c = cluster(1);
        let fut = c.submit(
            Box::new(|_, _| {
                std::thread::sleep(Duration::from_millis(100));
                Ok(vec![])
            }),
            vec![],
        );
        assert!(matches!(
            fut.wait_timeout(Duration::from_millis(10)),
            Err(Error::Timeout(..))
        ));
        fut.wait().unwrap();
    }

    #[test]
    fn shutdown_fails_queued_tasks() {
        let c = cluster(1);
        let _running = c.submit(
            Box::new(|_, _| {
                std::thread::sleep(Duration::from_millis(100));
                Ok(vec![])
            }),
            vec![],
        );
        let queued = c.submit(Box::new(|_, _| Ok(vec![])), vec![]);
        c.shutdown();
        assert!(queued.wait().is_err() || queued.wait().is_ok());
        // (Either the worker drained it just in time or it was failed;
        // both are acceptable shutdown semantics — the point is no hang.)
    }
}
