//! Task execution engine substrate (the Dask/Parsl/Globus-Compute
//! analogue).
//!
//! The paper's patterns are *engine-agnostic*; to demonstrate and evaluate
//! them we need an engine with the properties the paper's baselines
//! exhibit:
//!
//! * a central client/scheduler through which task payloads flow
//!   ("data flows through the client", the DeepDriveMD bottleneck);
//! * per-task submission overhead (Fig 5's `submit` spans);
//! * futures for task results, with completion callbacks (the hook the
//!   ownership model uses to release borrows).
//!
//! [`LocalCluster`] runs a scheduler thread plus N worker threads. Task
//! arguments and results are *serialized bytes* that traverse configurable
//! netsim [`Link`]s on the client→worker and worker→client hops, so the
//! baseline cost of moving data with the engine is physically modelled,
//! not assumed. Proxies bypass those hops by construction (their payloads
//! are ~100-byte factories).

mod cluster;
mod executor;

pub use cluster::{ClusterConfig, LocalCluster, TaskFuture, TaskHandle, WorkerCtx};
pub use executor::{ProxyPolicy, StoreExecutor, TaskArg};

/// Convenience: a [`ProxyPolicy`] with the given byte threshold.
pub fn executor_policy(threshold: usize) -> ProxyPolicy {
    ProxyPolicy { threshold }
}

use crate::error::Result;

/// A task: runs on a worker with its (deserialized-by-the-task) payload.
pub type TaskFn =
    Box<dyn FnOnce(&WorkerCtx, Vec<u8>) -> Result<Vec<u8>> + Send + 'static>;

/// Completion callback attached to a task future.
pub type DoneCallback = Box<dyn FnOnce(&Result<Vec<u8>>) + Send + 'static>;
