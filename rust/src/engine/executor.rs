//! StoreExecutor: the engine wrapper that auto-proxies task parameters and
//! results and manages ownership references via task-completion callbacks
//! (paper Sec IV-C).
//!
//! The paper's problem statement: every engine has a different future
//! syntax, so instead of modifying engines, wrap the client. Our
//! [`StoreExecutor`] wraps a [`LocalCluster`] and:
//!
//! * serializes each argument as a [`TaskArg`]: small values inline
//!   (`Value`), large values proxied through the store (`Proxied`) per a
//!   size-threshold policy;
//! * supports ownership-aware argument modes — `Borrowed` / `BorrowedMut`
//!   references are **released when the task's future completes** (the
//!   callback trick from the paper), and `OwnedTransfer` hands the object
//!   to the task outright;
//! * auto-proxies large results on the worker side so they return to the
//!   client as cheap references.

use std::sync::Arc;

use crate::codec::{Bytes, Decode, Encode, Reader, get_varint, put_varint};
use crate::error::{Error, Result};
use crate::ownership::{OwnedProxy, OwnedToken, RefMutProxy, RefProxy};
use crate::proxy::Proxy;
use crate::store::Store;

use super::cluster::{LocalCluster, TaskFuture, WorkerCtx};

/// One task argument, as shipped in the task payload.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskArg {
    /// Inline encoded value (pass-by-value through the engine).
    Value(Bytes),
    /// Proxy factory bytes (pass-by-reference; read-only access).
    Proxied(Bytes),
    /// Borrowed reference — read-only, released when the task completes.
    Borrowed(Bytes),
    /// Mutable borrow — exclusive, released when the task completes.
    BorrowedMut(Bytes),
    /// Ownership transferred to the task (task's drop evicts).
    OwnedTransfer(Bytes),
}

impl Encode for TaskArg {
    fn encode(&self, buf: &mut Vec<u8>) {
        let (tag, bytes) = match self {
            TaskArg::Value(b) => (0, b),
            TaskArg::Proxied(b) => (1, b),
            TaskArg::Borrowed(b) => (2, b),
            TaskArg::BorrowedMut(b) => (3, b),
            TaskArg::OwnedTransfer(b) => (4, b),
        };
        put_varint(buf, tag);
        bytes.encode(buf);
    }
}

impl Decode for TaskArg {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let tag = get_varint(r)?;
        let bytes: Bytes = Decode::decode(r)?;
        Ok(match tag {
            0 => TaskArg::Value(bytes),
            1 => TaskArg::Proxied(bytes),
            2 => TaskArg::Borrowed(bytes),
            3 => TaskArg::BorrowedMut(bytes),
            4 => TaskArg::OwnedTransfer(bytes),
            t => return Err(Error::Codec(format!("bad TaskArg tag {t}"))),
        })
    }
}

impl TaskArg {
    /// Decode the argument as a `T`, resolving proxies as needed.
    /// (`Borrowed` access is read-only via the factory; release happens in
    /// the executor callback, not here.)
    pub fn get<T: Decode>(&self) -> Result<T> {
        match self {
            TaskArg::Value(b) => T::from_bytes(&b.0),
            TaskArg::Proxied(b) | TaskArg::Borrowed(b) => {
                let p: Proxy<T> = Proxy::from_bytes(&b.0)?;
                p.into_inner()
            }
            TaskArg::BorrowedMut(b) => {
                let p: Proxy<T> = Proxy::from_bytes(&b.0)?;
                p.into_inner()
            }
            TaskArg::OwnedTransfer(_) => Err(Error::Config(
                "use take_owned() for OwnedTransfer args".into(),
            )),
        }
    }

    /// Adopt a transferred owned object (its drop inside the task evicts).
    pub fn take_owned<T: Decode + Encode>(&self) -> Result<OwnedProxy<T>> {
        match self {
            TaskArg::OwnedTransfer(b) => {
                let token: OwnedToken<T> = OwnedToken::from_bytes(&b.0)?;
                OwnedProxy::from_token(token)
            }
            _ => Err(Error::Config("not an OwnedTransfer arg".into())),
        }
    }

    /// Adopt a mutable borrow for write-back (`commit`). The executor does
    /// NOT release adopted mut borrows — the returned proxy's drop does.
    pub fn take_mut<T: Decode + Encode>(&self) -> Result<RefMutProxy<T>> {
        match self {
            TaskArg::BorrowedMut(b) => RefMutProxy::from_wire(&b.0),
            _ => Err(Error::Config("not a BorrowedMut arg".into())),
        }
    }

    /// The approximate wire size of this argument.
    pub fn wire_len(&self) -> usize {
        match self {
            TaskArg::Value(b)
            | TaskArg::Proxied(b)
            | TaskArg::Borrowed(b)
            | TaskArg::BorrowedMut(b)
            | TaskArg::OwnedTransfer(b) => b.0.len(),
        }
    }
}

/// Typed task result: either the value inline or a proxy to it.
pub struct ExecutorFuture<T> {
    inner: TaskFuture,
    _marker: std::marker::PhantomData<fn() -> T>,
}

impl<T: Decode> ExecutorFuture<T> {
    /// Wait and decode, **consuming** a proxied result: the stored copy is
    /// evicted after the value is fetched. Results are single-consumer by
    /// construction (the future is the only handle), so this is the
    /// reference-managed behaviour the paper's StoreExecutor provides —
    /// without it every large task result would leak (Fig 7's "default"
    /// curve).
    pub fn result(&self) -> Result<T> {
        let bytes = self.inner.wait()?;
        let arg = TaskArg::from_bytes(&bytes)?;
        match &arg {
            TaskArg::Proxied(b) => {
                let p: Proxy<T> = Proxy::from_bytes(&b.0)?;
                let factory = p.factory().clone();
                let value = p.into_inner()?;
                factory.invalidate_cache();
                if let Ok(conn) = factory.connector() {
                    let _ = conn.evict(&factory.key);
                }
                Ok(value)
            }
            _ => arg.get(),
        }
    }

    /// Wait and decode without evicting a proxied result (for results that
    /// will be consumed again elsewhere).
    pub fn result_shared(&self) -> Result<T> {
        let bytes = self.inner.wait()?;
        TaskArg::from_bytes(&bytes)?.get()
    }

    pub fn raw(&self) -> &TaskFuture {
        &self.inner
    }
}

/// Policy: proxy arguments/results larger than this many bytes (the
/// paper's MOF deployment used 1 kB).
#[derive(Debug, Clone, Copy)]
pub struct ProxyPolicy {
    pub threshold: usize,
}

impl Default for ProxyPolicy {
    fn default() -> Self {
        ProxyPolicy { threshold: 1024 }
    }
}

/// Engine wrapper: auto-proxying + ownership-aware submission.
pub struct StoreExecutor {
    cluster: Arc<LocalCluster>,
    store: Store,
    policy: ProxyPolicy,
}

/// A typed task body: receives decoded [`TaskArg`]s.
pub type ArgTaskFn =
    Box<dyn FnOnce(&WorkerCtx, Vec<TaskArg>) -> Result<Vec<u8>> + Send>;

impl StoreExecutor {
    pub fn new(cluster: Arc<LocalCluster>, store: Store) -> StoreExecutor {
        StoreExecutor { cluster, store, policy: ProxyPolicy::default() }
    }

    pub fn with_policy(mut self, policy: ProxyPolicy) -> StoreExecutor {
        self.policy = policy;
        self
    }

    pub fn store(&self) -> &Store {
        &self.store
    }

    pub fn cluster(&self) -> &Arc<LocalCluster> {
        &self.cluster
    }

    /// Apply the auto-proxy policy to one encoded value.
    pub fn make_arg<T: Encode>(&self, value: &T) -> Result<TaskArg> {
        let encoded = value.to_bytes();
        if encoded.len() > self.policy.threshold {
            let key = self.store.put_at_raw(&encoded)?;
            let proxy_bytes =
                self.store.factory_for(&key, false, 0).to_bytes();
            Ok(TaskArg::Proxied(Bytes(proxy_bytes)))
        } else {
            Ok(TaskArg::Value(Bytes(encoded)))
        }
    }

    /// Borrow an owned object for the duration of one task.
    pub fn make_borrowed<T: Decode + Encode>(
        &self,
        owned: &OwnedProxy<T>,
    ) -> Result<TaskArg> {
        Ok(TaskArg::Borrowed(Bytes(owned.borrow()?.to_wire())))
    }

    /// Mutably borrow an owned object for one task.
    pub fn make_borrowed_mut<T: Decode + Encode>(
        &self,
        owned: &OwnedProxy<T>,
    ) -> Result<TaskArg> {
        Ok(TaskArg::BorrowedMut(Bytes(owned.mut_borrow()?.to_wire())))
    }

    /// Transfer ownership into the task.
    pub fn make_owned_transfer<T: Decode + Encode>(
        &self,
        owned: OwnedProxy<T>,
    ) -> TaskArg {
        TaskArg::OwnedTransfer(Bytes(owned.transfer().to_bytes()))
    }

    /// Submit a task over [`TaskArg`]s. Borrow-mode args are released when
    /// the future completes (whether the task succeeded or failed).
    pub fn submit<T: Decode>(
        &self,
        args: Vec<TaskArg>,
        func: ArgTaskFn,
    ) -> ExecutorFuture<T> {
        // Collect release actions before the args are shipped.
        let releases: Vec<TaskArg> = args
            .iter()
            .filter(|a| {
                matches!(a, TaskArg::Borrowed(_) | TaskArg::BorrowedMut(_))
            })
            .cloned()
            .collect();

        let payload = args.to_bytes();
        let store = self.store.clone();
        let threshold = self.policy.threshold;
        let fut = self.cluster.submit(
            Box::new(move |ctx, payload| {
                let args = Vec::<TaskArg>::from_bytes(&payload)?;
                let result = func(ctx, args)?;
                // Worker-side auto-proxy of large results.
                let out = if result.len() > threshold {
                    let key = store.put_at_raw(&result)?;
                    TaskArg::Proxied(Bytes(
                        store.factory_for(&key, false, 0).to_bytes(),
                    ))
                } else {
                    TaskArg::Value(Bytes(result))
                };
                Ok(out.to_bytes())
            }),
            payload,
        );

        if !releases.is_empty() {
            fut.on_done(Box::new(move |_result| {
                for arg in releases {
                    match arg {
                        TaskArg::Borrowed(b) => {
                            // Adopt + drop = decrement the borrow count.
                            drop(RefProxy::<Bytes>::from_wire(&b.0));
                        }
                        TaskArg::BorrowedMut(b) => {
                            drop(RefMutProxy::<Bytes>::from_wire(&b.0));
                        }
                        _ => {}
                    }
                }
            }));
        }

        ExecutorFuture { inner: fut, _marker: std::marker::PhantomData }
    }
}

// Store helper: put pre-encoded bytes (avoids double-encoding).
impl Store {
    /// Store raw already-encoded bytes under a fresh key.
    pub fn put_at_raw(&self, encoded: &[u8]) -> Result<String> {
        let key = self.new_key();
        self.connector().put(&key, encoded.to_vec())?;
        Ok(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cluster::ClusterConfig;
    use crate::ownership::{take_violations, StoreOwnedExt};

    fn executor() -> StoreExecutor {
        let cluster =
            Arc::new(LocalCluster::new(ClusterConfig { workers: 2, ..Default::default() }));
        StoreExecutor::new(cluster, Store::memory("exec"))
    }

    #[test]
    fn small_args_inline_large_args_proxied() {
        let ex = executor();
        let small = ex.make_arg(&7u32).unwrap();
        assert!(matches!(small, TaskArg::Value(_)));
        let big = ex.make_arg(&Bytes(vec![0; 10_000])).unwrap();
        assert!(matches!(big, TaskArg::Proxied(_)));
        assert!(big.wire_len() < 256, "proxied arg must be tiny");
    }

    #[test]
    fn submit_roundtrip_with_mixed_args() {
        let ex = executor();
        let a = ex.make_arg(&5u64).unwrap();
        let b = ex.make_arg(&Bytes(vec![1u8; 50_000])).unwrap();
        let fut: ExecutorFuture<u64> = ex.submit(
            vec![a, b],
            Box::new(|_ctx, args| {
                let x: u64 = args[0].get()?;
                let data: Bytes = args[1].get()?;
                Ok((x + data.0.len() as u64).to_bytes())
            }),
        );
        assert_eq!(fut.result().unwrap(), 50_005);
    }

    #[test]
    fn large_results_come_back_proxied() {
        let ex = executor();
        let fut: ExecutorFuture<Bytes> = ex.submit(
            vec![],
            Box::new(|_, _| Ok(Bytes(vec![9u8; 20_000]).to_bytes())),
        );
        let raw = fut.raw().wait().unwrap();
        assert!(raw.len() < 512, "result must travel as a proxy");
        assert_eq!(fut.result().unwrap().0.len(), 20_000);
    }

    #[test]
    fn borrowed_args_released_on_completion() {
        let ex = executor();
        let owned = ex.store().owned_proxy(&Bytes(vec![3u8; 2048])).unwrap();
        let arg = ex.make_borrowed(&owned).unwrap();
        // While the task is in flight (or at least until release), a mut
        // borrow is impossible.
        let fut: ExecutorFuture<u64> = ex.submit(
            vec![arg],
            Box::new(|_, args| {
                let data: Bytes = args[0].get()?;
                std::thread::sleep(std::time::Duration::from_millis(50));
                Ok((data.0.len() as u64).to_bytes())
            }),
        );
        assert!(owned.mut_borrow().is_err(), "borrow held during task");
        assert_eq!(fut.result().unwrap(), 2048);
        // Poll briefly: callback runs on the worker thread.
        let mut ok = false;
        for _ in 0..100 {
            if owned.mut_borrow().is_ok() {
                ok = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(ok, "borrow must be released after completion");
        assert_eq!(take_violations(), 0);
    }

    #[test]
    fn borrowed_released_even_when_task_fails() {
        let ex = executor();
        let owned = ex.store().owned_proxy(&1u32).unwrap();
        let arg = ex.make_borrowed(&owned).unwrap();
        let fut: ExecutorFuture<u32> = ex.submit(
            vec![arg],
            Box::new(|_, _| Err(Error::Task("fail".into()))),
        );
        assert!(fut.result().is_err());
        let mut ok = false;
        for _ in 0..100 {
            if owned.mut_borrow().is_ok() {
                ok = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(ok);
    }

    #[test]
    fn owned_transfer_evicts_at_task_end() {
        let ex = executor();
        let owned = ex.store().owned_proxy(&Bytes(vec![1; 4096])).unwrap();
        let key = owned.key().to_string();
        let store = ex.store().clone();
        let arg = ex.make_owned_transfer(owned);
        let fut: ExecutorFuture<u64> = ex.submit(
            vec![arg],
            Box::new(|_, args| {
                let owned = args[0].take_owned::<Bytes>()?;
                let n = owned.resolve()?.0.len() as u64;
                Ok(n.to_bytes()) // owned drops here → evict
            }),
        );
        assert_eq!(fut.result().unwrap(), 4096);
        assert!(!store.exists(&key).unwrap(), "transfer target evicted");
    }

    #[test]
    fn mut_borrow_commit_visible_after_release() {
        let ex = executor();
        let owned = ex.store().owned_proxy(&42u64).unwrap();
        let arg = ex.make_borrowed_mut(&owned).unwrap();
        let fut: ExecutorFuture<u64> = ex.submit(
            vec![arg],
            Box::new(|_, args| {
                // Read via factory, then write back through adoption.
                let v: u64 = args[0].get()?;
                let mut m = args[0].take_mut::<u64>()?;
                m.commit(&(v * 2))?;
                std::mem::forget(m); // executor callback owns the release
                Ok(0u64.to_bytes())
            }),
        );
        fut.result().unwrap();
        for _ in 0..100 {
            if owned.borrow().is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let r = owned.borrow().unwrap();
        assert_eq!(*r.resolve().unwrap(), 84);
    }
}
