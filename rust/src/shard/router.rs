//! The sharded connector: consistent-hash routing + replication over N
//! backend channels, behind the ordinary [`Connector`] interface.
//!
//! Writes land on the key's replica set (R distinct shards from the
//! ring's successor walk); reads try the primary first and fall back to
//! the remaining replicas on miss *or* failure, so a dead backend degrades
//! throughput instead of availability. Batched ops group keys by shard and
//! fan out in parallel over the shared reactor pool
//! ([`crate::ops::reactor`]) as submitted [`Op`]s — no per-call thread
//! spawns, and backends with a pipelined native submit (TCP) keep their
//! in-flight sub-batches on the wire rather than on a parked worker.

use std::sync::Arc;
use std::time::Instant;

use crate::codec::Buf;
use crate::error::{Error, Result};
use crate::metrics::telemetry::{self, MirroredCounter};
use crate::ops::reactor::fan_out_ops;
use crate::ops::{race, Op, OpResult, Pending};
use crate::shard::ring::HashRing;
use crate::store::{Blob, Connector, ConnectorDesc};

/// Default virtual nodes per shard (128 keeps per-shard load within a few
/// percent of uniform; see the ring's distribution tests).
pub const DEFAULT_VNODES: usize = 128;

/// Serializable description of a shard fabric. This is what a proxy
/// [`Factory`](crate::proxy::Factory) carries (as
/// [`ConnectorDesc::Sharded`]) so resolution can rebuild the exact same
/// ring — same shard order, same vnodes, same replica placement — in any
/// process.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedDesc {
    pub shards: Vec<ConnectorDesc>,
    pub replicas: usize,
    pub vnodes: usize,
}

impl ShardedDesc {
    /// Fabric over the given backends, replication factor 1.
    pub fn new(shards: Vec<ConnectorDesc>) -> ShardedDesc {
        ShardedDesc { shards, replicas: 1, vnodes: DEFAULT_VNODES }
    }

    /// Set the per-key replication factor (clamped to the shard count at
    /// connect time).
    pub fn with_replicas(mut self, replicas: usize) -> ShardedDesc {
        self.replicas = replicas;
        self
    }

    /// Set the virtual-node count per shard.
    pub fn with_vnodes(mut self, vnodes: usize) -> ShardedDesc {
        self.vnodes = vnodes;
        self
    }

    /// The wire form carried by factories.
    pub fn desc(&self) -> ConnectorDesc {
        ConnectorDesc::Sharded {
            shards: self.shards.clone(),
            replicas: self.replicas as u64,
            vnodes: self.vnodes as u64,
        }
    }

    /// Build the fabric (connects every backend).
    pub fn connect(&self) -> Result<Arc<dyn Connector>> {
        self.desc().connect()
    }
}

impl From<ShardedDesc> for ConnectorDesc {
    fn from(d: ShardedDesc) -> ConnectorDesc {
        d.desc()
    }
}

/// Per-shard results of a batched fan-out.
type ShardResults = Vec<(usize, Result<Vec<Option<Blob>>>)>;

/// Consistent-hash routing connector over N backends.
pub struct ShardedConnector {
    shards: Vec<Arc<dyn Connector>>,
    /// Stable ring id of each backend (`ids[i]` owns ring id for
    /// `shards[i]`). Identity for [`ShardedConnector::new`]; arbitrary for
    /// [`ShardedConnector::with_shard_ids`], which is what lets the
    /// elastic fabric keep ids stable across membership changes.
    ids: Vec<usize>,
    ring: HashRing,
    replicas: usize,
    vnodes: usize,
    /// Reads served by a non-primary replica (miss/failure fallbacks).
    /// Per-instance exact count, mirrored into the process registry as
    /// `shard.router.read_fallbacks`.
    fallbacks: MirroredCounter,
    /// Writes that landed on fewer than R replicas (some backend down).
    /// Mirrored as `shard.router.degraded_writes`.
    degraded_writes: MirroredCounter,
    /// Per-backend op latency, aligned with `shards` and named by stable
    /// ring id (`shard.{id}.op_us`) — a slow shard stands out by name
    /// even as membership changes around it.
    shard_op_us: Vec<Arc<telemetry::Histogram>>,
    /// Whole-batch latency of the fan-out paths (`get_many`/`put_many`/
    /// `delete_many`): wall time of the slowest shard in the round.
    batch_us: Arc<telemetry::Histogram>,
}

impl ShardedConnector {
    /// Fabric over explicit backends. `replicas` is clamped to
    /// `[1, shards.len()]`; `vnodes == 0` selects [`DEFAULT_VNODES`].
    pub fn new(
        shards: Vec<Arc<dyn Connector>>,
        replicas: usize,
        vnodes: usize,
    ) -> Result<ShardedConnector> {
        let ids = (0..shards.len()).collect();
        Self::with_shard_ids(ids, shards, replicas, vnodes)
    }

    /// Fabric over backends with explicit stable ring ids (`ids[i]` is the
    /// ring id of `shards[i]`). Ids survive membership changes, which is
    /// what gives the elastic fabric its remapping locality: rebuilding
    /// the router after add/remove moves only the ~1/N remapped keys.
    ///
    /// Caveat: [`ConnectorDesc::Sharded`] does not carry ids, so a
    /// non-identity router's own descriptor round-trips to an
    /// identity-ring fabric. The elastic layer serializes membership
    /// through its generation-aware `ConnectorDesc::Elastic` instead.
    pub fn with_shard_ids(
        ids: Vec<usize>,
        shards: Vec<Arc<dyn Connector>>,
        replicas: usize,
        vnodes: usize,
    ) -> Result<ShardedConnector> {
        if shards.is_empty() {
            return Err(Error::Config("sharded connector needs >= 1 shard".into()));
        }
        if ids.len() != shards.len() {
            return Err(Error::Config(format!(
                "{} shard ids for {} backends",
                ids.len(),
                shards.len()
            )));
        }
        let mut uniq = ids.clone();
        uniq.sort_unstable();
        uniq.dedup();
        if uniq.len() != ids.len() {
            return Err(Error::Config("duplicate shard ids".into()));
        }
        let vnodes = if vnodes == 0 { DEFAULT_VNODES } else { vnodes };
        let replicas = replicas.clamp(1, shards.len());
        let shard_op_us = ids
            .iter()
            .map(|id| telemetry::histogram(&format!("shard.{id}.op_us")))
            .collect();
        Ok(ShardedConnector {
            ring: HashRing::with_shards(ids.clone(), vnodes),
            ids,
            shards,
            replicas,
            vnodes,
            fallbacks: MirroredCounter::new("shard.router.read_fallbacks"),
            degraded_writes: MirroredCounter::new("shard.router.degraded_writes"),
            shard_op_us,
            batch_us: telemetry::histogram("shard.router.batch_us"),
        })
    }

    /// Primary shard ring id for a key (tests / diagnostics). Equals the
    /// backend position for identity-id fabrics ([`ShardedConnector::new`]).
    pub fn shard_for(&self, key: &str) -> usize {
        self.ring.shard_for(key)
    }

    /// The key's replica set as ring ids, primary first.
    pub fn replicas_for(&self, key: &str) -> Vec<usize> {
        self.ring.replicas_for(key, self.replicas)
    }

    /// Number of backends.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The stable ring ids, aligned with the backends.
    pub fn shard_ids(&self) -> &[usize] {
        &self.ids
    }

    /// Every `(ring_id, backend)` pair in the fabric — the enumeration
    /// cluster telemetry scraping fans across.
    pub fn members(&self) -> Vec<(usize, Arc<dyn Connector>)> {
        self.ids
            .iter()
            .zip(&self.shards)
            .map(|(&id, c)| (id, c.clone()))
            .collect()
    }

    /// Backend position of a ring id.
    fn idx(&self, id: usize) -> usize {
        // Fabrics hold a handful of shards; a linear scan beats a map.
        self.ids
            .iter()
            .position(|&s| s == id)
            .expect("ring id not in fabric")
    }

    /// The key's replica set as backend positions, primary first.
    fn replica_idxs(&self, key: &str) -> Vec<usize> {
        self.ring
            .replicas_for(key, self.replicas)
            .into_iter()
            .map(|id| self.idx(id))
            .collect()
    }

    /// Reads that were served by a fallback replica so far.
    pub fn fallback_reads(&self) -> u64 {
        self.fallbacks.get()
    }

    /// Writes that landed on fewer than their full replica set (a backend
    /// was down at write time). Such objects survive, but lose the
    /// redundancy budget until the missing copies are repaired.
    pub fn degraded_writes(&self) -> u64 {
        self.degraded_writes.get()
    }

    /// Fan a batched get out to every shard with a non-empty index group
    /// as submitted ops on the shared reactor pool; `groups[shard]` holds
    /// indices into `keys`.
    fn fan_out_get(&self, groups: &[Vec<usize>], keys: &[String]) -> ShardResults {
        let ops = groups
            .iter()
            .enumerate()
            .filter(|(_, group)| !group.is_empty())
            .map(|(shard, group)| {
                let batch: Vec<String> =
                    group.iter().map(|&i| keys[i].clone()).collect();
                (shard, self.shards[shard].clone(), Op::GetMany { keys: batch })
            })
            .collect();
        fan_out_ops(ops)
            .into_iter()
            .map(|(shard, res)| (shard, res.and_then(OpResult::into_values)))
            .collect()
    }

    /// Fan a batched existence probe out to every shard with a non-empty
    /// index group (the `exists_many` twin of
    /// [`ShardedConnector::fan_out_get`]).
    fn fan_out_exists(
        &self,
        groups: &[Vec<usize>],
        keys: &[String],
    ) -> Vec<(usize, Result<Vec<bool>>)> {
        let ops = groups
            .iter()
            .enumerate()
            .filter(|(_, group)| !group.is_empty())
            .map(|(shard, group)| {
                let batch: Vec<String> =
                    group.iter().map(|&i| keys[i].clone()).collect();
                (
                    shard,
                    self.shards[shard].clone(),
                    Op::ExistsMany { keys: batch },
                )
            })
            .collect();
        fan_out_ops(ops)
            .into_iter()
            .map(|(shard, res)| (shard, res.and_then(OpResult::into_bools)))
            .collect()
    }
}

impl Connector for ShardedConnector {
    fn desc(&self) -> ConnectorDesc {
        ConnectorDesc::Sharded {
            shards: self.shards.iter().map(|s| s.desc()).collect(),
            replicas: self.replicas as u64,
            vnodes: self.vnodes as u64,
        }
    }

    fn put(&self, key: &str, mut data: Vec<u8>) -> Result<()> {
        let reps = self.replica_idxs(key);
        let mut stored = 0usize;
        let mut last_err = None;
        for (ri, &shard) in reps.iter().enumerate() {
            let payload = if ri + 1 == reps.len() {
                std::mem::take(&mut data)
            } else {
                data.clone()
            };
            let t = Instant::now();
            let res = self.shards[shard].put(key, payload);
            self.shard_op_us[shard].record_duration(t.elapsed());
            match res {
                Ok(()) => stored += 1,
                Err(e) => last_err = Some(e),
            }
        }
        // A write is durable once any replica holds it; total write
        // failure surfaces the backend error. Partial placement is counted
        // so operators can see redundancy erode before it bites.
        if stored > 0 {
            if stored < reps.len() {
                self.degraded_writes.incr();
            }
            Ok(())
        } else {
            Err(last_err.unwrap_or_else(|| {
                Error::Connector(format!("no replica accepted {key}"))
            }))
        }
    }

    /// Store only if absent, atomically: the key's *primary* replica is
    /// the linearization point (its native `put_nx` decides the race), so
    /// two producers fanning in on one key cannot both win — unlike an
    /// exists+put over the fabric, where they could probe different
    /// replicas. Secondaries then receive plain copies; a secondary that
    /// fails only degrades redundancy, counted like any degraded write.
    /// A dead primary fails the conditional write — falling back to
    /// another replica would reintroduce the two-winners race.
    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        let reps = self.replica_idxs(key);
        if reps.len() == 1 {
            return self.shards[reps[0]].put_nx(key, data);
        }
        let stored = self.shards[reps[0]].put_nx(key, data.clone())?;
        if stored {
            let copies = reps[1..]
                .iter()
                .filter(|&&s| self.shards[s].put(key, data.clone()).is_ok())
                .count();
            if copies + 1 < reps.len() {
                self.degraded_writes.incr();
            }
        }
        Ok(stored)
    }

    /// Arm the watch on the key's whole replica set: a write lands on
    /// every live replica (and a degraded write on any subset of them),
    /// so the first arm to fire wins. The race fails only when *every*
    /// replica arm fails — a dead backend among live ones degrades
    /// nothing, matching read-fallback semantics.
    fn watch(&self, key: &str) -> Pending<Blob> {
        let reps = self.replica_idxs(key);
        let (group, handle) = race();
        group.add_all(
            reps.iter().map(|&s| self.shards[s].watch(key)).collect(),
        );
        handle
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        let reps = self.replica_idxs(key);
        let mut healthy_misses = 0usize;
        let mut last_err = None;
        for (attempt, &shard) in reps.iter().enumerate() {
            let t = Instant::now();
            let res = self.shards[shard].get(key);
            self.shard_op_us[shard].record_duration(t.elapsed());
            match res {
                Ok(Some(blob)) => {
                    if attempt > 0 {
                        self.fallbacks.incr();
                    }
                    return Ok(Some(blob));
                }
                Ok(None) => healthy_misses += 1,
                Err(e) => last_err = Some(e),
            }
        }
        // A healthy replica answering "absent" makes this a miss; only a
        // fully unreachable replica set is an error. Caveat (standard for
        // replication without read-repair): an object whose write was
        // degraded can be reported absent while its only copy sits on a
        // temporarily unreachable backend — `degraded_writes` makes that
        // window observable.
        match last_err {
            Some(e) if healthy_misses == 0 => Err(e),
            _ => Ok(None),
        }
    }

    /// Same replica walk as [`ShardedConnector::get`], but each backend
    /// serves its zero-copy view — on TCP shards the value stays in the
    /// response frame's allocation all the way to the caller.
    fn get_view(&self, key: &str) -> Result<Option<Buf>> {
        let reps = self.replica_idxs(key);
        let mut healthy_misses = 0usize;
        let mut last_err = None;
        for (attempt, &shard) in reps.iter().enumerate() {
            let t = Instant::now();
            let res = self.shards[shard].get_view(key);
            self.shard_op_us[shard].record_duration(t.elapsed());
            match res {
                Ok(Some(view)) => {
                    if attempt > 0 {
                        self.fallbacks.incr();
                    }
                    return Ok(Some(view));
                }
                Ok(None) => healthy_misses += 1,
                Err(e) => last_err = Some(e),
            }
        }
        // Same miss-vs-error policy as `get` above.
        match last_err {
            Some(e) if healthy_misses == 0 => Err(e),
            _ => Ok(None),
        }
    }

    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        if items.is_empty() {
            return Ok(());
        }
        let t_batch = Instant::now();
        let n = self.shards.len();
        let mut batches: Vec<Vec<(String, Vec<u8>)>> = vec![Vec::new(); n];
        let mut owners: Vec<(String, Vec<usize>)> = Vec::with_capacity(items.len());
        for (key, data) in items {
            let reps = self.replica_idxs(&key);
            for &shard in &reps {
                batches[shard].push((key.clone(), data.clone()));
            }
            owners.push((key, reps));
        }
        let mut shard_res: Vec<Option<Result<()>>> = vec![None; n];
        let ops = batches
            .into_iter()
            .enumerate()
            .filter(|(_, batch)| !batch.is_empty())
            .map(|(shard, batch)| {
                (shard, self.shards[shard].clone(), Op::PutMany { items: batch })
            })
            .collect();
        for (shard, res) in fan_out_ops(ops) {
            shard_res[shard] = Some(res.and_then(OpResult::into_unit));
        }
        for (key, reps) in owners {
            let stored = reps
                .iter()
                .filter(|&&sh| matches!(shard_res[sh], Some(Ok(()))))
                .count();
            if stored == 0 {
                let err = reps.iter().find_map(|&sh| match &shard_res[sh] {
                    Some(Err(e)) => Some(e.clone()),
                    _ => None,
                });
                return Err(err.unwrap_or_else(|| {
                    Error::Connector(format!("all replicas failed for {key}"))
                }));
            }
            if stored < reps.len() {
                self.degraded_writes.incr();
            }
        }
        self.batch_us.record_duration(t_batch.elapsed());
        Ok(())
    }

    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        let t_batch = Instant::now();
        let n = self.shards.len();
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, key) in keys.iter().enumerate() {
            groups[self.idx(self.ring.shard_for(key))].push(i);
        }
        let mut out: Vec<Option<Blob>> = vec![None; keys.len()];
        let mut healthy_miss = vec![false; keys.len()];
        let mut pending: Vec<usize> = Vec::new();
        let mut last_err: Option<Error> = None;
        // Parallel primary fetch: each shard serves its sub-batch
        // concurrently, so wall time is the slowest shard, not the sum.
        for (shard, res) in self.fan_out_get(&groups, keys) {
            match res {
                Ok(blobs) => {
                    for (&i, blob) in groups[shard].iter().zip(blobs) {
                        match blob {
                            Some(b) => out[i] = Some(b),
                            None => {
                                healthy_miss[i] = true;
                                if self.replicas > 1 {
                                    pending.push(i);
                                }
                            }
                        }
                    }
                }
                Err(e) => {
                    if self.replicas == 1 {
                        return Err(e);
                    }
                    pending.extend(groups[shard].iter().copied());
                    last_err = Some(e);
                }
            }
        }
        // Batched replica fallback: one parallel round per replica rank,
        // so a dead shard costs one extra fan-out round — not one failed
        // round trip per affected key.
        let mut depth = 1;
        while !pending.is_empty() && depth < self.replicas {
            let mut round_groups: Vec<Vec<usize>> = vec![Vec::new(); n];
            for &i in &pending {
                round_groups[self.replica_idxs(&keys[i])[depth]].push(i);
            }
            let mut next_pending = Vec::new();
            for (shard, res) in self.fan_out_get(&round_groups, keys) {
                match res {
                    Ok(blobs) => {
                        for (&i, blob) in round_groups[shard].iter().zip(blobs) {
                            match blob {
                                Some(b) => {
                                    out[i] = Some(b);
                                    self.fallbacks.incr();
                                }
                                None => {
                                    healthy_miss[i] = true;
                                    next_pending.push(i);
                                }
                            }
                        }
                    }
                    Err(e) => {
                        next_pending.extend(round_groups[shard].iter().copied());
                        last_err = Some(e);
                    }
                }
            }
            pending = next_pending;
            depth += 1;
        }
        // Same semantics as `get`: a key every replica errored on (no
        // healthy "absent" answer anywhere) surfaces the backend error.
        if pending.iter().any(|&i| !healthy_miss[i]) {
            if let Some(e) = last_err {
                return Err(e);
            }
        }
        self.batch_us.record_duration(t_batch.elapsed());
        Ok(out)
    }

    fn evict(&self, key: &str) -> Result<()> {
        let reps = self.replica_idxs(key);
        let mut any_ok = false;
        let mut last_err = None;
        for &shard in &reps {
            match self.shards[shard].evict(key) {
                Ok(()) => any_ok = true,
                Err(e) => last_err = Some(e),
            }
        }
        match last_err {
            Some(e) if !any_ok => Err(e),
            _ => Ok(()),
        }
    }

    fn delete_many(&self, keys: &[String]) -> Result<()> {
        if keys.is_empty() {
            return Ok(());
        }
        let t_batch = Instant::now();
        // Group every key's full replica set per shard, sweep all shards
        // in parallel (each pays one native MDEL / batched evict).
        let n = self.shards.len();
        let mut batches: Vec<Vec<String>> = vec![Vec::new(); n];
        let mut owners: Vec<Vec<usize>> = Vec::with_capacity(keys.len());
        for key in keys {
            let reps = self.replica_idxs(key);
            for &shard in &reps {
                batches[shard].push(key.clone());
            }
            owners.push(reps);
        }
        let mut shard_res: Vec<Option<Result<()>>> = vec![None; n];
        let ops = batches
            .into_iter()
            .enumerate()
            .filter(|(_, batch)| !batch.is_empty())
            .map(|(shard, batch)| {
                (
                    shard,
                    self.shards[shard].clone(),
                    Op::DeleteMany { keys: batch },
                )
            })
            .collect();
        for (shard, res) in fan_out_ops(ops) {
            shard_res[shard] = Some(res.and_then(OpResult::into_unit));
        }
        // Same semantics as `evict`: a key is gone once any replica
        // confirmed; only a fully failed replica set surfaces the error.
        for (key, reps) in keys.iter().zip(owners) {
            let any_ok =
                reps.iter().any(|&sh| matches!(shard_res[sh], Some(Ok(()))));
            if !any_ok {
                let err = reps.iter().find_map(|&sh| match &shard_res[sh] {
                    Some(Err(e)) => Some(e.clone()),
                    _ => None,
                });
                return Err(err.unwrap_or_else(|| {
                    Error::Connector(format!("all replicas failed deleting {key}"))
                }));
            }
        }
        self.batch_us.record_duration(t_batch.elapsed());
        Ok(())
    }

    fn exists(&self, key: &str) -> Result<bool> {
        let reps = self.replica_idxs(key);
        let mut healthy = 0usize;
        let mut last_err = None;
        for &shard in &reps {
            match self.shards[shard].exists(key) {
                Ok(true) => return Ok(true),
                Ok(false) => healthy += 1,
                Err(e) => last_err = Some(e),
            }
        }
        match last_err {
            Some(e) if healthy == 0 => Err(e),
            _ => Ok(false),
        }
    }

    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        // Same shape as `get_many`: one parallel fan-out per replica rank,
        // with `exists` semantics per key — true once any replica answers
        // true, false on an all-healthy miss, error only when every
        // replica of some key is unreachable.
        let n = self.shards.len();
        let mut out = vec![false; keys.len()];
        let mut healthy = vec![false; keys.len()];
        let mut pending: Vec<usize> = (0..keys.len()).collect();
        let mut last_err: Option<Error> = None;
        let mut depth = 0;
        while !pending.is_empty() && depth < self.replicas {
            let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n];
            for &i in &pending {
                groups[self.replica_idxs(&keys[i])[depth]].push(i);
            }
            let mut next_pending = Vec::new();
            for (shard, res) in self.fan_out_exists(&groups, keys) {
                match res {
                    Ok(flags) => {
                        for (&i, hit) in groups[shard].iter().zip(flags) {
                            if hit {
                                out[i] = true;
                            } else {
                                healthy[i] = true;
                                next_pending.push(i);
                            }
                        }
                    }
                    Err(e) => {
                        next_pending.extend(groups[shard].iter().copied());
                        last_err = Some(e);
                    }
                }
            }
            pending = next_pending;
            depth += 1;
        }
        if pending.iter().any(|&i| !healthy[i]) {
            if let Some(e) = last_err {
                return Err(e);
            }
        }
        Ok(out)
    }

    fn list_keys(&self) -> Result<Vec<String>> {
        // Union over all backends; replicated keys dedupe to one entry.
        let mut all = Vec::new();
        for shard in &self.shards {
            all.extend(shard.list_keys()?);
        }
        all.sort_unstable();
        all.dedup();
        Ok(all)
    }

    fn len(&self) -> Result<usize> {
        // Sum over backends; replicated objects count once per copy.
        let mut total = 0;
        for shard in &self.shards {
            total += shard.len()?;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{Decode, Encode};
    use crate::store::MemoryConnector;
    use crate::testing::fail::FlakyConnector;

    fn fabric(
        n: usize,
        replicas: usize,
    ) -> (ShardedConnector, Vec<Arc<dyn Connector>>) {
        let backends: Vec<Arc<dyn Connector>> =
            (0..n).map(|_| MemoryConnector::new()).collect();
        let router =
            ShardedConnector::new(backends.clone(), replicas, 64).unwrap();
        (router, backends)
    }

    #[test]
    fn routes_to_primary_shard_only() {
        let (router, backends) = fabric(4, 1);
        for i in 0..32 {
            let key = format!("obj-{i}");
            router.put(&key, vec![i as u8]).unwrap();
            let primary = router.shard_for(&key);
            for (s, b) in backends.iter().enumerate() {
                assert_eq!(
                    b.exists(&key).unwrap(),
                    s == primary,
                    "key {key} on wrong shard {s}"
                );
            }
            assert_eq!(
                router.get(&key).unwrap().map(|b| b.to_vec()),
                Some(vec![i as u8])
            );
        }
    }

    #[test]
    fn replication_writes_r_copies() {
        let (router, backends) = fabric(5, 3);
        router.put("replicated", vec![7; 100]).unwrap();
        let copies = backends
            .iter()
            .filter(|b| b.exists("replicated").unwrap())
            .count();
        assert_eq!(copies, 3);
        assert_eq!(router.len().unwrap(), 3); // counted once per copy
        router.evict("replicated").unwrap();
        assert!(!router.exists("replicated").unwrap());
        assert_eq!(router.len().unwrap(), 0);
    }

    #[test]
    fn read_falls_back_when_primary_is_down() {
        let backends: Vec<Arc<FlakyConnector>> = (0..3)
            .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
            .collect();
        let as_conns: Vec<Arc<dyn Connector>> = backends
            .iter()
            .map(|b| b.clone() as Arc<dyn Connector>)
            .collect();
        let router = ShardedConnector::new(as_conns, 2, 64).unwrap();
        router.put("k", vec![42; 64]).unwrap();
        let reps = router.replicas_for("k");
        assert_eq!(reps.len(), 2);

        // Kill the primary: reads must transparently fall back.
        backends[reps[0]].set_down(true);
        assert_eq!(router.fallback_reads(), 0);
        assert_eq!(router.get("k").unwrap().map(|b| b.to_vec()), Some(vec![42; 64]));
        assert_eq!(router.fallback_reads(), 1);
        assert!(router.exists("k").unwrap());

        // Kill every replica: now the error surfaces.
        backends[reps[1]].set_down(true);
        assert!(router.get("k").is_err());

        // Recovery restores primary reads.
        backends[reps[0]].set_down(false);
        backends[reps[1]].set_down(false);
        assert_eq!(router.get("k").unwrap().map(|b| b.to_vec()), Some(vec![42; 64]));
    }

    #[test]
    fn write_survives_one_dead_replica() {
        let backends: Vec<Arc<FlakyConnector>> = (0..3)
            .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
            .collect();
        let as_conns: Vec<Arc<dyn Connector>> = backends
            .iter()
            .map(|b| b.clone() as Arc<dyn Connector>)
            .collect();
        let router = ShardedConnector::new(as_conns, 2, 64).unwrap();
        let reps = router.replicas_for("k");
        backends[reps[0]].set_down(true);
        assert_eq!(router.degraded_writes(), 0);
        router.put("k", vec![5]).unwrap(); // secondary accepted it
        assert_eq!(router.degraded_writes(), 1);
        assert_eq!(router.get("k").unwrap().map(|b| b.to_vec()), Some(vec![5]));

        // With every backend down the write failure surfaces.
        for b in &backends {
            b.set_down(true);
        }
        assert!(router.put("k2", vec![6]).is_err());
    }

    #[test]
    fn watch_wakes_from_any_replica_and_survives_dead_backends() {
        let (router, _b) = fabric(4, 1);
        let handle = router.watch("later");
        assert!(!handle.is_complete());
        router.put("later", vec![6]).unwrap();
        assert_eq!(handle.wait().unwrap().to_vec(), vec![6]);

        // Replicated: a degraded write (dead primary) still fires the
        // watch through a secondary's arm.
        let backends: Vec<Arc<FlakyConnector>> = (0..3)
            .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
            .collect();
        let as_conns: Vec<Arc<dyn Connector>> = backends
            .iter()
            .map(|b| b.clone() as Arc<dyn Connector>)
            .collect();
        let router = ShardedConnector::new(as_conns, 2, 64).unwrap();
        let reps = router.replicas_for("k");
        let handle = router.watch("k");
        backends[reps[0]].set_down(true);
        router.put("k", vec![9]).unwrap(); // lands on the secondary only
        assert_eq!(handle.wait().unwrap().to_vec(), vec![9]);
    }

    #[test]
    fn put_nx_single_winner_across_concurrent_producers() {
        let (router, _b) = fabric(4, 2);
        let router = Arc::new(router);
        let wins: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let r = router.clone();
                    s.spawn(move || r.put_nx("contended", vec![i as u8]).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            wins.iter().filter(|&&w| w).count(),
            1,
            "exactly one producer must win the conditional write"
        );
        // The winner's value replicated to the full replica set.
        assert_eq!(router.len().unwrap(), 2);
    }

    #[test]
    fn put_nx_requires_live_primary() {
        let backends: Vec<Arc<FlakyConnector>> = (0..3)
            .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
            .collect();
        let as_conns: Vec<Arc<dyn Connector>> = backends
            .iter()
            .map(|b| b.clone() as Arc<dyn Connector>)
            .collect();
        let router = ShardedConnector::new(as_conns, 2, 64).unwrap();
        let reps = router.replicas_for("k");
        backends[reps[0]].set_down(true);
        assert!(
            router.put_nx("k", vec![1]).is_err(),
            "no linearization point without the primary"
        );
        // A dead secondary degrades but does not fail.
        backends[reps[0]].set_down(false);
        backends[reps[1]].set_down(true);
        assert!(router.put_nx("k", vec![1]).unwrap());
        assert_eq!(router.degraded_writes(), 1);
    }

    #[test]
    fn batched_ops_roundtrip_across_shards() {
        let (router, backends) = fabric(4, 1);
        let items: Vec<(String, Vec<u8>)> = (0..64)
            .map(|i| (format!("batch-{i}"), vec![i as u8; 16]))
            .collect();
        router.put_many(items.clone()).unwrap();
        // Every shard received some portion of the batch.
        for b in &backends {
            assert!(b.len().unwrap() > 0, "a shard got no keys from the batch");
        }
        let keys: Vec<String> =
            items.iter().map(|(k, _)| k.clone()).collect();
        let got = router.get_many(&keys).unwrap();
        for (i, blob) in got.iter().enumerate() {
            assert_eq!(blob.as_ref().unwrap().to_vec(), vec![i as u8; 16]);
        }
        // Partial miss keeps positional alignment.
        let mixed = vec![
            "batch-0".to_string(),
            "missing".to_string(),
            "batch-63".to_string(),
        ];
        let got = router.get_many(&mixed).unwrap();
        assert!(got[0].is_some());
        assert!(got[1].is_none());
        assert!(got[2].is_some());
        // Empty batch.
        assert_eq!(router.get_many(&[]).unwrap(), Vec::new());
        router.put_many(Vec::new()).unwrap();
    }

    #[test]
    fn batched_get_falls_back_per_key() {
        let backends: Vec<Arc<FlakyConnector>> = (0..4)
            .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
            .collect();
        let as_conns: Vec<Arc<dyn Connector>> = backends
            .iter()
            .map(|b| b.clone() as Arc<dyn Connector>)
            .collect();
        let router = ShardedConnector::new(as_conns, 2, 64).unwrap();
        let items: Vec<(String, Vec<u8>)> =
            (0..32).map(|i| (format!("fb-{i}"), vec![i as u8])).collect();
        router.put_many(items.clone()).unwrap();
        let keys: Vec<String> = items.iter().map(|(k, _)| k.clone()).collect();

        backends[0].set_down(true);
        let got = router.get_many(&keys).unwrap();
        for (i, blob) in got.iter().enumerate() {
            assert_eq!(
                blob.as_ref().map(|b| b.to_vec()),
                Some(vec![i as u8]),
                "key {} lost with one shard down",
                keys[i]
            );
        }
        assert!(router.fallback_reads() > 0);
    }

    #[test]
    fn delete_many_sweeps_all_replicas() {
        let (router, backends) = fabric(4, 2);
        let items: Vec<(String, Vec<u8>)> =
            (0..24).map(|i| (format!("dm-{i}"), vec![i as u8])).collect();
        router.put_many(items.clone()).unwrap();
        assert_eq!(router.len().unwrap(), 48); // R=2 copies
        let keys: Vec<String> = items.iter().map(|(k, _)| k.clone()).collect();
        router.delete_many(&keys).unwrap();
        assert_eq!(router.len().unwrap(), 0);
        for b in &backends {
            assert_eq!(b.len().unwrap(), 0);
        }
        // Idempotent + empty batch.
        router.delete_many(&keys).unwrap();
        router.delete_many(&[]).unwrap();
    }

    #[test]
    fn delete_many_survives_one_dead_replica() {
        let backends: Vec<Arc<FlakyConnector>> = (0..3)
            .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
            .collect();
        let as_conns: Vec<Arc<dyn Connector>> = backends
            .iter()
            .map(|b| b.clone() as Arc<dyn Connector>)
            .collect();
        let router = ShardedConnector::new(as_conns, 2, 64).unwrap();
        let keys: Vec<String> = (0..16).map(|i| format!("dmf-{i}")).collect();
        router
            .put_many(keys.iter().map(|k| (k.clone(), vec![1])).collect())
            .unwrap();
        backends[0].set_down(true);
        // Every key still has a live replica: the sweep succeeds.
        router.delete_many(&keys).unwrap();
        backends[0].set_down(false);
        // With everything down the failure surfaces.
        for b in &backends {
            b.set_down(true);
        }
        assert!(router.delete_many(&keys).is_err());
    }

    #[test]
    fn exists_many_spans_shards_with_replica_fallback() {
        let (router, _b) = fabric(4, 1);
        let items: Vec<(String, Vec<u8>)> =
            (0..24).map(|i| (format!("em-{i}"), vec![i as u8])).collect();
        router.put_many(items).unwrap();
        let mut keys: Vec<String> = (0..24).map(|i| format!("em-{i}")).collect();
        keys.push("ghost".into());
        let got = router.exists_many(&keys).unwrap();
        assert!(got[..24].iter().all(|&b| b), "resident key reported absent");
        assert!(!got[24], "ghost key reported present");
        assert_eq!(router.exists_many(&[]).unwrap(), Vec::<bool>::new());

        // Probe survives a dead primary when replicated; an all-dead
        // replica set surfaces the error.
        let backends: Vec<Arc<FlakyConnector>> = (0..3)
            .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
            .collect();
        let as_conns: Vec<Arc<dyn Connector>> = backends
            .iter()
            .map(|b| b.clone() as Arc<dyn Connector>)
            .collect();
        let router = ShardedConnector::new(as_conns, 2, 64).unwrap();
        router.put("k", vec![1]).unwrap();
        let reps = router.replicas_for("k");
        backends[reps[0]].set_down(true);
        assert_eq!(router.exists_many(&["k".into()]).unwrap(), vec![true]);
        backends[reps[1]].set_down(true);
        assert!(router.exists_many(&["k".into()]).is_err());
    }

    #[test]
    fn list_keys_unions_replicated_shards() {
        let (router, _b) = fabric(3, 2);
        let items: Vec<(String, Vec<u8>)> =
            (0..12).map(|i| (format!("lk-{i}"), vec![i as u8])).collect();
        router.put_many(items).unwrap();
        let keys = router.list_keys().unwrap();
        // R=2 copies dedupe back to 12 logical keys, sorted.
        assert_eq!(keys.len(), 12);
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn stable_ids_keep_surviving_placement() {
        // A 3-shard fabric with ids [0,1,2] and the 2-shard fabric left
        // after removing id 1 must agree on every key whose primary
        // survives — the property the elastic rebalancer builds on.
        let backends: Vec<Arc<dyn Connector>> =
            (0..3).map(|_| MemoryConnector::new()).collect();
        let full = ShardedConnector::with_shard_ids(
            vec![0, 1, 2],
            backends.clone(),
            1,
            64,
        )
        .unwrap();
        let shrunk = ShardedConnector::with_shard_ids(
            vec![0, 2],
            vec![backends[0].clone(), backends[2].clone()],
            1,
            64,
        )
        .unwrap();
        for i in 0..200 {
            let key = format!("stable-{i}");
            let old = full.shard_for(&key);
            if old != 1 {
                assert_eq!(
                    shrunk.shard_for(&key),
                    old,
                    "key {key} moved although its shard survived"
                );
                // Routing agrees end to end, not just in the ring: a put
                // through one fabric is visible through the other.
                full.put(&key, vec![i as u8]).unwrap();
                assert_eq!(
                    shrunk.get(&key).unwrap().map(|b| b.to_vec()),
                    Some(vec![i as u8])
                );
            }
        }
        // Id/backends arity and duplicate ids are rejected.
        assert!(ShardedConnector::with_shard_ids(
            vec![0],
            backends.clone(),
            1,
            64
        )
        .is_err());
        assert!(ShardedConnector::with_shard_ids(
            vec![7, 7, 8],
            backends.clone(),
            1,
            64
        )
        .is_err());
    }

    #[test]
    fn desc_roundtrips_through_codec_and_reconnects() {
        let (router, _backends) = fabric(3, 2);
        router.put("shared", vec![9; 32]).unwrap();
        let desc = router.desc();
        let decoded = ConnectorDesc::from_bytes(&desc.to_bytes()).unwrap();
        assert_eq!(desc, decoded);
        let rebuilt = decoded.connect().unwrap();
        assert_eq!(
            rebuilt.get("shared").unwrap().map(|b| b.to_vec()),
            Some(vec![9; 32])
        );
        // Same ring on both sides: writes through the rebuilt fabric are
        // visible through the original.
        rebuilt.put("back", vec![1]).unwrap();
        assert_eq!(router.get("back").unwrap().map(|b| b.to_vec()), Some(vec![1]));
    }

    #[test]
    fn sharded_desc_builder() {
        let d = ShardedDesc::new(vec![
            ConnectorDesc::Memory { id: "a".into() },
            ConnectorDesc::Memory { id: "b".into() },
        ])
        .with_replicas(2)
        .with_vnodes(32);
        match d.desc() {
            ConnectorDesc::Sharded { shards, replicas, vnodes } => {
                assert_eq!(shards.len(), 2);
                assert_eq!(replicas, 2);
                assert_eq!(vnodes, 32);
            }
            other => panic!("unexpected desc {other:?}"),
        }
        let conn = d.connect().unwrap();
        conn.put("x", vec![1]).unwrap();
        assert!(conn.exists("x").unwrap());
    }

    #[test]
    fn empty_fabric_rejected_and_replicas_clamped() {
        assert!(ShardedConnector::new(Vec::new(), 1, 64).is_err());
        let (router, _b) = fabric(2, 99);
        assert_eq!(router.replicas_for("k").len(), 2);
        let (router, _b) = fabric(2, 0);
        assert_eq!(router.replicas_for("k").len(), 1);
    }
}
