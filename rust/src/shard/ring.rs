//! Consistent-hash ring with virtual nodes.
//!
//! Maps object keys to shard indices so that adding or removing a shard
//! only remaps ~1/N of the key space (remapping locality), while virtual
//! nodes smooth the per-shard load to within a few percent of uniform.
//! The ring is deterministic: any process that builds it from the same
//! `(n_shards, vnodes)` pair — e.g. by decoding a serialized
//! [`ConnectorDesc::Sharded`](crate::store::ConnectorDesc) out of a proxy
//! factory — routes every key identically, which is what makes sharded
//! proxies self-contained.

/// FNV-1a 64-bit hash with an avalanche finalizer (splitmix64's mixer).
/// FNV alone clusters on short sequential keys; the finalizer spreads the
/// low-entropy tail across the whole 64-bit space.
pub fn hash_key(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    mix64(h)
}

fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A consistent-hash ring over shard indices `0..n` with `vnodes` virtual
/// nodes per shard.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(ring position, shard index)` sorted by position.
    points: Vec<(u64, usize)>,
    shards: Vec<usize>,
    vnodes: usize,
}

impl HashRing {
    /// Ring over shards `0..n_shards`.
    pub fn new(n_shards: usize, vnodes: usize) -> HashRing {
        Self::with_shards((0..n_shards).collect(), vnodes)
    }

    /// Ring over an explicit shard-id set (ids survive add/remove, which
    /// is what gives consistent hashing its remapping locality).
    pub fn with_shards(shards: Vec<usize>, vnodes: usize) -> HashRing {
        let vnodes = vnodes.max(1);
        let mut ring = HashRing { points: Vec::new(), shards, vnodes };
        ring.rebuild();
        ring
    }

    fn rebuild(&mut self) {
        self.points.clear();
        self.points.reserve(self.shards.len() * self.vnodes);
        for &shard in &self.shards {
            for v in 0..self.vnodes {
                let point = hash_key(format!("shard-{shard}-vnode-{v}").as_bytes());
                self.points.push((point, shard));
            }
        }
        // Position ties (vanishingly rare) resolve to the lower shard id,
        // deterministically on every host.
        self.points.sort_unstable();
    }

    pub fn shards(&self) -> &[usize] {
        &self.shards
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Add a shard id (no-op if present).
    pub fn add_shard(&mut self, shard: usize) {
        if !self.shards.contains(&shard) {
            self.shards.push(shard);
            self.rebuild();
        }
    }

    /// Remove a shard id (no-op if absent).
    pub fn remove_shard(&mut self, shard: usize) {
        let before = self.shards.len();
        self.shards.retain(|&s| s != shard);
        if self.shards.len() != before {
            self.rebuild();
        }
    }

    /// Primary shard for a key: first ring point clockwise of its hash.
    pub fn shard_for(&self, key: &str) -> usize {
        self.replica_walk(key)
            .next()
            .expect("shard_for on an empty ring")
    }

    /// Up to `r` distinct shards for a key, primary first — the key's
    /// replica set. Capped at the number of live shards.
    pub fn replicas_for(&self, key: &str, r: usize) -> Vec<usize> {
        self.replica_walk(key).take(r.max(1)).collect()
    }

    /// Whether `key`'s replica set differs between this ring (the old
    /// placement) and `new`. The elastic rebalancer filters every resident
    /// key through this to compute the migration delta — consistent
    /// hashing guarantees only ~1/N of keys answer true after a
    /// single-shard membership change.
    pub fn remapped(&self, new: &HashRing, key: &str, replicas: usize) -> bool {
        self.replicas_for(key, replicas) != new.replicas_for(key, replicas)
    }

    /// Clockwise walk from the key's hash yielding each distinct shard
    /// once (the classic successor-list replica placement).
    fn replica_walk(&self, key: &str) -> impl Iterator<Item = usize> + '_ {
        let h = hash_key(key.as_bytes());
        let start = self
            .points
            .partition_point(|&(p, _)| p < h)
            .checked_rem(self.points.len().max(1))
            .unwrap_or(0);
        let n = self.points.len();
        let mut seen = Vec::with_capacity(self.shards.len());
        (0..n).filter_map(move |i| {
            let (_, shard) = self.points[(start + i) % n];
            if seen.contains(&shard) {
                None
            } else {
                seen.push(shard);
                Some(shard)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, gens};
    use std::collections::HashMap;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("store-ab12-{i}")).collect()
    }

    #[test]
    fn distribution_is_balanced() {
        // Chi-square-ish bound: with 128 vnodes/shard over 20k keys the
        // per-shard load must sit close to uniform. We assert every shard
        // holds between half and double its fair share — far looser than
        // the observed spread, far tighter than what a broken ring gives.
        let shards = 4;
        let ring = HashRing::new(shards, 128);
        let mut counts: HashMap<usize, usize> = HashMap::new();
        let ks = keys(20_000);
        for k in &ks {
            *counts.entry(ring.shard_for(k)).or_default() += 1;
        }
        assert_eq!(counts.len(), shards, "all shards must receive keys");
        let fair = ks.len() / shards;
        for (&shard, &n) in &counts {
            assert!(
                n > fair / 2 && n < fair * 2,
                "shard {shard} holds {n} of {} keys (fair {fair})",
                ks.len()
            );
        }
        // Chi-square-style statistic against uniform. The ring's own arc
        // skew with v vnodes contributes ~keys/v per shard (≈156 total
        // here), so the bound is set a few multiples above that; a ring
        // without vnodes or with a clustering hash lands in the thousands.
        let chi2: f64 = counts
            .values()
            .map(|&n| {
                let d = n as f64 - fair as f64;
                d * d / fair as f64
            })
            .sum();
        assert!(chi2 < 800.0, "chi-square {chi2:.1} too far from uniform");
    }

    #[test]
    fn routing_is_deterministic_across_instances() {
        let a = HashRing::new(8, 64);
        let b = HashRing::new(8, 64);
        for k in keys(500) {
            assert_eq!(a.shard_for(&k), b.shard_for(&k));
        }
    }

    #[test]
    fn adding_a_shard_remaps_only_a_fraction() {
        let before = HashRing::new(4, 128);
        let mut after = before.clone();
        after.add_shard(4);
        let ks = keys(10_000);
        let mut moved = 0;
        for k in &ks {
            let old = before.shard_for(k);
            let new = after.shard_for(k);
            if old != new {
                // Consistent hashing: keys only ever move TO the new shard.
                assert_eq!(new, 4, "key {k} moved {old}->{new}, not to new");
                moved += 1;
            }
        }
        let frac = moved as f64 / ks.len() as f64;
        // Expected 1/5; a naive `hash % n` ring moves ~4/5.
        assert!(
            frac > 0.05 && frac < 0.40,
            "moved fraction {frac:.3} outside consistent-hash locality"
        );
    }

    #[test]
    fn removing_a_shard_only_moves_its_keys() {
        let before = HashRing::new(5, 128);
        let mut after = before.clone();
        after.remove_shard(2);
        for k in keys(5_000) {
            let old = before.shard_for(&k);
            let new = after.shard_for(&k);
            if old != 2 {
                assert_eq!(old, new, "key {k} moved despite its shard surviving");
            } else {
                assert_ne!(new, 2, "key {k} still routed to removed shard");
            }
        }
    }

    #[test]
    fn replicas_are_distinct_and_led_by_primary() {
        let ring = HashRing::new(6, 64);
        for k in keys(1_000) {
            let reps = ring.replicas_for(&k, 3);
            assert_eq!(reps.len(), 3);
            assert_eq!(reps[0], ring.shard_for(&k));
            let mut uniq = reps.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "replica set {reps:?} has duplicates");
        }
    }

    #[test]
    fn replica_count_caps_at_shard_count() {
        let ring = HashRing::new(2, 16);
        assert_eq!(ring.replicas_for("k", 5).len(), 2);
        assert_eq!(ring.replicas_for("k", 1).len(), 1);
    }

    #[test]
    fn single_shard_ring_routes_everything_to_it() {
        let ring = HashRing::new(1, 32);
        forall(gens::string(1..40), 200, |k| ring.shard_for(k) == 0);
    }

    #[test]
    fn prop_primary_is_stable_under_unrelated_removal() {
        // Removing shard X never moves a key whose primary is Y != X.
        let ring = HashRing::new(4, 64);
        forall(gens::string(1..32), 300, |k| {
            let primary = ring.shard_for(k);
            let victim = (primary + 1) % 4;
            let mut smaller = ring.clone();
            smaller.remove_shard(victim);
            smaller.shard_for(k) == primary
        });
    }

    #[test]
    fn remapped_matches_placement_delta() {
        let before = HashRing::new(4, 128);
        let mut after = before.clone();
        after.add_shard(4);
        let ks = keys(2_000);
        let mut remapped = 0;
        for k in &ks {
            let moved = before.remapped(&after, k, 1);
            assert_eq!(
                moved,
                before.shard_for(k) != after.shard_for(k),
                "remapped() disagrees with shard_for delta on {k}"
            );
            if moved {
                remapped += 1;
            }
        }
        // ~1/5 of keys move when growing 4 -> 5.
        let frac = remapped as f64 / ks.len() as f64;
        assert!(
            frac > 0.05 && frac < 0.40,
            "remapped fraction {frac:.3} outside consistent-hash locality"
        );
        // Identical rings never remap.
        for k in ks.iter().take(100) {
            assert!(!before.remapped(&before.clone(), k, 2));
        }
    }

    #[test]
    fn hash_spreads_sequential_keys() {
        // Guard against FNV's short-key clustering: consecutive generated
        // store keys must not land on one shard.
        let ring = HashRing::new(4, 128);
        let mut hit = [false; 4];
        for i in 0..64 {
            hit[ring.shard_for(&format!("s-{i}"))] = true;
        }
        assert!(hit.iter().all(|&h| h), "sequential keys cluster: {hit:?}");
    }
}
