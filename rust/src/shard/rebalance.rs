//! Elastic shard fabric: live membership changes with read-through
//! migration.
//!
//! The static fabric ([`ShardedConnector`]) fixes its shard set at
//! construction: growing it means building a new ring and orphaning the
//! ~1/N remapped keys. This module adds the control plane that makes the
//! shard set *elastic*:
//!
//! * [`ElasticShards::add_shard`] / [`ElasticShards::remove_shard`] change
//!   membership at runtime. Each change starts a new **epoch**: a fresh
//!   [`ShardedConnector`] built with [stable ring
//!   ids](ShardedConnector::with_shard_ids), so consistent hashing moves
//!   only the ~1/N remapped keys;
//! * a **migration daemon** (short-lived batch jobs on the shared reactor
//!   pool, [`crate::ops::reactor`] — no per-rebalance thread spawns)
//!   copies exactly the remapped keys from the old placement to the new
//!   one with batched `get_many`/`put_many` moves, then retires the stale
//!   copies with `delete_many`;
//! * while the daemon drains, the router serves **read-through**: reads
//!   try the new placement first and fall back to the old epoch (then
//!   re-check the new placement, closing the copy/delete race), writes go
//!   to the new placement only — so no client ever observes a missing key
//!   during a rebalance;
//! * [`ConnectorDesc::Elastic`] is the generation-aware descriptor. In the
//!   minting process it names a registered control plane, so a proxy
//!   created before a rebalance resolves through the *live* membership
//!   afterwards; in a fresh process it rebuilds the fabric from its
//!   membership snapshot and registers that as the live control plane.
//!
//! Consistency model (documented, not negotiable): store keys are
//! generated unique and never reused ([`crate::store::Store::new_key`]),
//! so an object is written once and read many times. The migration copy is
//! therefore idempotent. Overwriting a key *during* a migration that moves
//! it is outside the model — the daemon could re-land the older value.
//! Likewise an eviction that races the copy of the same key can resurrect
//! it until the next rebalance; `Store`-level usage (evict after the
//! owning workflow is done with the key) does not hit this window.
//! Failure handling is deliberately boring: a migration batch that errors
//! is re-enqueued with bounded retries ([`RebalanceSnapshot::batch_retries`]),
//! then dropped and counted ([`RebalanceSnapshot::keys_failed`]). Dropped
//! keys stay readable through read-through only while the epoch drains;
//! once it retires their bytes survive on the old backends but are no
//! longer routed to — a non-zero `keys_failed` after a rebalance is an
//! operator signal, not a silent loss.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::codec::Buf;
use crate::error::{Error, Result};
use crate::metrics::{RebalanceMetrics, RebalanceSnapshot};
use crate::ops::{race, Pending, Race};
use crate::shard::router::{ShardedConnector, DEFAULT_VNODES};
use crate::store::{Blob, Connector, ConnectorDesc};

/// Keys per migration batch: one `get_many` + one `put_many` (plus the
/// stale-copy `delete_many` sweep) per batch. Each batch is one
/// short-lived job on the shared reactor pool.
pub const MIGRATION_BATCH: usize = 64;

/// Migration batch jobs in flight at once. Each lane is one single-batch
/// job that chains the next batch when it settles, so a migration — no
/// matter how large — occupies at most this many pool slots and never
/// floods the shared queue ahead of data-plane work.
const MIGRATION_LANES: usize = 4;

/// A batch is retried this many times before its keys are abandoned at
/// the old placement and counted in `keys_failed`.
const MAX_BATCH_ATTEMPTS: u32 = 5;

/// Stable-id shard membership: `(ring id, backend)` pairs.
pub type ShardMembers = Vec<(usize, Arc<dyn Connector>)>;

// ---------------------------------------------------------------------
// Process-wide registry: what makes stale elastic descriptors resolve
// against the live membership (the memory-connector registry idiom).
// ---------------------------------------------------------------------

fn registry() -> &'static Mutex<HashMap<String, ElasticShards>> {
    static REG: std::sync::OnceLock<Mutex<HashMap<String, ElasticShards>>> =
        std::sync::OnceLock::new();
    REG.get_or_init(Default::default)
}

/// Resolve a [`ConnectorDesc::Elastic`]: attach to the live control plane
/// registered under its name, or (in a fresh process) rebuild the fabric
/// from the descriptor's membership snapshot and register it.
pub fn connect_elastic(desc: &ConnectorDesc) -> Result<Arc<dyn Connector>> {
    let ConnectorDesc::Elastic {
        name,
        generation,
        shard_ids,
        shards,
        replicas,
        vnodes,
    } = desc
    else {
        return Err(Error::Config("not an elastic descriptor".into()));
    };
    if let Some(live) = registry().lock().unwrap().get(name) {
        return Ok(Arc::new(live.clone()));
    }
    if shard_ids.len() != shards.len() {
        return Err(Error::Config(format!(
            "elastic desc: {} ids for {} shards",
            shard_ids.len(),
            shards.len()
        )));
    }
    let members: ShardMembers = shard_ids
        .iter()
        .zip(shards)
        .map(|(&id, d)| Ok((id as usize, d.connect()?)))
        .collect::<Result<_>>()?;
    let built = ElasticShards::build(
        name,
        members,
        *replicas as usize,
        *vnodes as usize,
        *generation,
    )?;
    // Two threads may race to rebuild the same fabric; the registry is the
    // single source of truth, so a lost race just attaches to the winner.
    let mut reg = registry().lock().unwrap();
    let live = reg.entry(name.clone()).or_insert(built).clone();
    Ok(Arc::new(live))
}

/// Serializable description of an elastic fabric (builder mirror of
/// [`crate::shard::ShardedDesc`]; wire form [`ConnectorDesc::Elastic`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticDesc {
    pub name: String,
    pub shard_ids: Vec<usize>,
    pub shards: Vec<ConnectorDesc>,
    pub replicas: usize,
    pub vnodes: usize,
    pub generation: u64,
}

impl ElasticDesc {
    /// Fabric over the given backends with identity ids, replication
    /// factor 1, generation 0.
    pub fn new(name: &str, shards: Vec<ConnectorDesc>) -> ElasticDesc {
        ElasticDesc {
            name: name.to_string(),
            shard_ids: (0..shards.len()).collect(),
            shards,
            replicas: 1,
            vnodes: DEFAULT_VNODES,
            generation: 0,
        }
    }

    /// Set the per-key replication factor (clamped to the live shard
    /// count at every epoch).
    pub fn with_replicas(mut self, replicas: usize) -> ElasticDesc {
        self.replicas = replicas;
        self
    }

    /// Set the virtual-node count per shard.
    pub fn with_vnodes(mut self, vnodes: usize) -> ElasticDesc {
        self.vnodes = vnodes;
        self
    }

    /// The wire form carried by proxy factories.
    pub fn desc(&self) -> ConnectorDesc {
        ConnectorDesc::Elastic {
            name: self.name.clone(),
            generation: self.generation,
            shard_ids: self.shard_ids.iter().map(|&id| id as u64).collect(),
            shards: self.shards.clone(),
            replicas: self.replicas as u64,
            vnodes: self.vnodes as u64,
        }
    }

    /// Build / attach the fabric (see [`connect_elastic`]).
    pub fn connect(&self) -> Result<Arc<dyn Connector>> {
        self.desc().connect()
    }
}

impl From<ElasticDesc> for ConnectorDesc {
    fn from(d: ElasticDesc) -> ConnectorDesc {
        d.desc()
    }
}

// ---------------------------------------------------------------------
// Control plane
// ---------------------------------------------------------------------

/// One retired epoch kept alive while its keys drain.
struct PrevEpoch {
    router: Arc<ShardedConnector>,
    members: ShardMembers,
}

struct EpochState {
    members: ShardMembers,
    current: Arc<ShardedConnector>,
    prev: Option<PrevEpoch>,
    /// Token of the in-flight migration; a straggler worker from an older
    /// migration must not retire a newer epoch.
    migration_token: u64,
}

struct MigrationBatch {
    keys: Vec<String>,
    attempts: u32,
}

/// Everything a migration batch job needs, owned per migration so
/// stragglers can never touch a newer migration's work.
struct MigrationCtx {
    token: u64,
    /// Batches waiting for a lane (retries re-enter here).
    queue: Mutex<VecDeque<MigrationBatch>>,
    /// Batches not yet terminally settled (moved or abandoned). The job
    /// that drops this to zero retires the old epoch. A retried batch
    /// stays outstanding — it re-queues itself instead of settling.
    outstanding: AtomicUsize,
    old_router: Arc<ShardedConnector>,
    new_router: Arc<ShardedConnector>,
    old_members: HashMap<usize, Arc<dyn Connector>>,
}

struct ElasticInner {
    name: String,
    replicas: usize,
    vnodes: usize,
    generation: AtomicU64,
    state: RwLock<EpochState>,
    /// Serializes membership changes (`add_shard`/`remove_shard`).
    admin: Mutex<()>,
    /// Signaled when a migration fully drains.
    idle: Mutex<()>,
    idle_cv: Condvar,
    /// Live watches, re-armed on every epoch flip so a rebalance mid-wait
    /// never strands a waiter (its key may land at the *new* placement,
    /// which the pre-flip arms don't cover). Settled entries are pruned
    /// opportunistically on arm and flip.
    watchers: Mutex<Vec<(String, Race<Blob>)>>,
    metrics: Arc<RebalanceMetrics>,
}

/// Elastic control plane over a shard fabric. Cheap to clone (Arc
/// inside); implements [`Connector`], so a [`crate::store::Store`] can sit
/// directly on top of it.
#[derive(Clone)]
pub struct ElasticShards {
    inner: Arc<ElasticInner>,
}

impl ElasticShards {
    /// Create and register an elastic fabric. `name` is the process-wide
    /// identity stale descriptors re-attach through; it must be unused.
    /// `replicas` is clamped to the live shard count at every epoch;
    /// `vnodes == 0` selects [`DEFAULT_VNODES`].
    pub fn new(
        name: &str,
        members: ShardMembers,
        replicas: usize,
        vnodes: usize,
    ) -> Result<ElasticShards> {
        let e = Self::build(name, members, replicas, vnodes, 0)?;
        let mut reg = registry().lock().unwrap();
        if reg.contains_key(name) {
            return Err(Error::Config(format!(
                "elastic fabric {name:?} already registered"
            )));
        }
        reg.insert(name.to_string(), e.clone());
        // Readiness flips false while a migration drains: a scraper (or a
        // load balancer) polling `/readyz` sees the fabric as not-ready
        // until the old epoch retires. The probe holds a Weak so it never
        // keeps an unregistered fabric's backends alive; a dead fabric
        // reads as ready.
        let weak = Arc::downgrade(&e.inner);
        crate::net::http::register_readiness(
            &format!("elastic.{name}"),
            Arc::new(move || match weak.upgrade() {
                Some(inner) => inner.state.read().unwrap().prev.is_none(),
                None => true,
            }),
        );
        Ok(e)
    }

    /// Drop a fabric from the process-wide registry, releasing its name
    /// (and, once every outstanding handle is gone, its backends). Stale
    /// descriptors for it will rebuild from their membership snapshot
    /// instead of attaching. Returns whether the name was registered.
    pub fn unregister(name: &str) -> bool {
        crate::net::http::unregister_readiness(&format!("elastic.{name}"));
        registry().lock().unwrap().remove(name).is_some()
    }

    /// Construct without registering (the [`connect_elastic`] rebuild
    /// path, which registers under the registry lock itself).
    fn build(
        name: &str,
        members: ShardMembers,
        replicas: usize,
        vnodes: usize,
        generation: u64,
    ) -> Result<ElasticShards> {
        let vnodes = if vnodes == 0 { DEFAULT_VNODES } else { vnodes };
        let router = Self::router_for(&members, replicas, vnodes)?;
        Ok(ElasticShards {
            inner: Arc::new(ElasticInner {
                name: name.to_string(),
                replicas,
                vnodes,
                generation: AtomicU64::new(generation),
                state: RwLock::new(EpochState {
                    members,
                    current: router,
                    prev: None,
                    migration_token: 0,
                }),
                admin: Mutex::new(()),
                idle: Mutex::new(()),
                idle_cv: Condvar::new(),
                watchers: Mutex::new(Vec::new()),
                metrics: RebalanceMetrics::new(),
            }),
        })
    }

    fn router_for(
        members: &ShardMembers,
        replicas: usize,
        vnodes: usize,
    ) -> Result<Arc<ShardedConnector>> {
        let ids: Vec<usize> = members.iter().map(|(id, _)| *id).collect();
        let backends: Vec<Arc<dyn Connector>> =
            members.iter().map(|(_, c)| c.clone()).collect();
        Ok(Arc::new(ShardedConnector::with_shard_ids(
            ids, backends, replicas, vnodes,
        )?))
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Membership-change counter: bumps once per add/remove.
    pub fn generation(&self) -> u64 {
        self.inner.generation.load(Ordering::SeqCst)
    }

    /// Live shard ids, in membership order.
    pub fn shard_ids(&self) -> Vec<usize> {
        let st = self.inner.state.read().unwrap();
        st.members.iter().map(|(id, _)| *id).collect()
    }

    /// The current epoch's router (diagnostics / tests: placement checks).
    pub fn router(&self) -> Arc<ShardedConnector> {
        self.inner.state.read().unwrap().current.clone()
    }

    /// Whether a migration is draining (an old epoch is still live).
    pub fn migrating(&self) -> bool {
        self.inner.state.read().unwrap().prev.is_some()
    }

    /// Every `(ring_id, backend)` pair in the current epoch — the
    /// enumeration cluster telemetry scraping fans across.
    pub fn members(&self) -> ShardMembers {
        self.inner.state.read().unwrap().members.clone()
    }

    /// Counter snapshot.
    pub fn metrics(&self) -> RebalanceSnapshot {
        self.inner.metrics.snapshot()
    }

    /// Block until no migration is in flight. Returns false on timeout
    /// (`None` waits forever).
    pub fn wait_quiescent(&self, timeout: Option<Duration>) -> bool {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut guard = self.inner.idle.lock().unwrap();
        while self.migrating() {
            let slice = match deadline {
                None => Duration::from_millis(50),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return false;
                    }
                    (d - now).min(Duration::from_millis(50))
                }
            };
            let (g, _) = self.inner.idle_cv.wait_timeout(guard, slice).unwrap();
            guard = g;
        }
        true
    }

    /// Grow the fabric: add a backend under a fresh stable id and migrate
    /// the ~1/N keys the ring remaps onto it. Returns once the migration
    /// daemon is running (or immediately if nothing remapped); use
    /// [`ElasticShards::wait_quiescent`] to block until it drains.
    pub fn add_shard(
        &self,
        id: usize,
        backend: Arc<dyn Connector>,
    ) -> Result<()> {
        self.rebalance(move |members| {
            if members.iter().any(|(m, _)| *m == id) {
                return Err(Error::Config(format!("shard id {id} already live")));
            }
            members.push((id, backend));
            Ok(())
        })
    }

    /// Reconnect a live shard id to a fresh backend — the recovery path
    /// for a crashed-and-restarted shard (e.g. a durable KV server
    /// brought back on the same address after replaying its WAL).
    ///
    /// The id keeps its ring position, so the placement delta is empty:
    /// no keys migrate, the epoch flips and finalizes immediately, and
    /// reads that were riding replica fallback while the shard was down
    /// resume hitting it through the new connector. Old connectors to a
    /// dead process never reconnect (the pipelined client fails fast on
    /// a dead pipe), which is why rejoin takes a *new* backend.
    pub fn rejoin_shard(
        &self,
        id: usize,
        backend: Arc<dyn Connector>,
    ) -> Result<()> {
        self.rebalance(move |members| {
            match members.iter_mut().find(|(m, _)| *m == id) {
                Some(slot) => {
                    slot.1 = backend;
                    Ok(())
                }
                None => Err(Error::Config(format!("shard id {id} not live"))),
            }
        })
    }

    /// Shrink the fabric: retire a shard id, draining its keys onto the
    /// survivors. The removed backend keeps serving reads until the
    /// migration finishes, then drops out of the fabric.
    pub fn remove_shard(&self, id: usize) -> Result<()> {
        self.rebalance(move |members| {
            let before = members.len();
            members.retain(|(m, _)| *m != id);
            if members.len() == before {
                return Err(Error::Config(format!("shard id {id} not live")));
            }
            Ok(())
        })
    }

    /// The shared membership-change path: flip epochs, compute the
    /// remapped key delta, hand it to the migration daemon.
    fn rebalance(
        &self,
        change: impl FnOnce(&mut ShardMembers) -> Result<()>,
    ) -> Result<()> {
        let inner = &self.inner;
        // One membership change at a time, and never while a previous
        // migration is still draining (epochs would have to chain).
        let _admin = inner.admin.lock().unwrap();
        self.wait_quiescent(None);

        let (old_router, old_members) = {
            let st = inner.state.read().unwrap();
            (st.current.clone(), st.members.clone())
        };
        let mut members = old_members.clone();
        change(&mut members)?;
        if members.is_empty() {
            return Err(Error::Config("elastic fabric needs >= 1 shard".into()));
        }
        let new_router =
            Self::router_for(&members, inner.replicas, inner.vnodes)?;

        // Flip epochs: from here writes land at the new placement and
        // reads fall back through the old one.
        let token;
        {
            let mut st = inner.state.write().unwrap();
            st.prev = Some(PrevEpoch {
                router: st.current.clone(),
                members: st.members.clone(),
            });
            st.current = new_router.clone();
            st.members = members;
            token = inner.generation.fetch_add(1, Ordering::SeqCst) + 1;
            st.migration_token = token;
        }

        // Re-arm every live watch on the post-flip placement. The old
        // arms stay valid (they cover values already resident or still
        // landing at the old epoch, which the daemon will copy through
        // the new router — itself firing the new arms); the fresh arm
        // covers writes that go straight to the new placement. Arming
        // checks existence, so a put that slips in between the flip and
        // this loop still fires. The sweep snapshots under the lock and
        // arms outside it — arming touches backends (Watch frames on TCP
        // shards), and concurrent `watch()` callers must not queue behind
        // that I/O; a watch registered mid-sweep covers itself via its
        // own post-registration epoch re-check.
        let live_watches: Vec<(String, Race<Blob>)> = {
            let mut watchers = inner.watchers.lock().unwrap();
            watchers.retain(|(_, group)| !group.settled());
            watchers
                .iter()
                .map(|(key, group)| (key.clone(), group.clone()))
                .collect()
        };
        crate::metrics::telemetry::counter("watch.rearms")
            .add(live_watches.len() as u64);
        for (key, group) in live_watches {
            group.add(new_router.watch(&key));
        }

        // Migration plan: every key whose replica set changed, each
        // enumerated exactly once (by its old primary). A shard that fails
        // enumeration contributes nothing — its keys stay where they are,
        // readable as long as it remains a member (module docs).
        let mut planned: Vec<String> = Vec::new();
        for (id, conn) in &old_members {
            let Ok(keys) = list_keys_with_retry(conn.as_ref()) else {
                continue;
            };
            for key in keys {
                let old_set = old_router.replicas_for(&key);
                if old_set.first() != Some(id) {
                    continue;
                }
                if old_set != new_router.replicas_for(&key) {
                    planned.push(key);
                }
            }
        }
        let m = &inner.metrics;
        m.add(&m.keys_planned, planned.len() as u64);
        if planned.is_empty() {
            self.finalize_epoch(token);
            return Ok(());
        }

        let batches: VecDeque<MigrationBatch> = planned
            .chunks(MIGRATION_BATCH)
            .map(|c| MigrationBatch { keys: c.to_vec(), attempts: 0 })
            .collect();
        let n_batches = batches.len();
        let ctx = Arc::new(MigrationCtx {
            token,
            queue: Mutex::new(batches),
            outstanding: AtomicUsize::new(n_batches),
            old_router,
            new_router,
            old_members: old_members.into_iter().collect(),
        });
        // The "daemon" is a bounded set of lanes on the shared reactor
        // pool: each lane is one single-batch job that chains the next
        // batch when it settles. No per-rebalance thread spawns, and a
        // large migration can neither flood the shared queue ahead of
        // data-plane work nor occupy more than MIGRATION_LANES slots.
        for _ in 0..MIGRATION_LANES.min(n_batches) {
            self.spawn_next_batch(ctx.clone());
        }
        Ok(())
    }

    /// Pull the next waiting batch (if any) onto a pool lane.
    fn spawn_next_batch(&self, ctx: Arc<MigrationCtx>) {
        let Some(batch) = ctx.queue.lock().unwrap().pop_front() else {
            return; // lane retires; outstanding work is already in flight
        };
        let this = self.clone();
        crate::ops::reactor::global()
            .spawn_detached(move || this.run_batch(ctx, batch));
    }

    /// Migration lane body: process one batch, then chain the lane's next
    /// batch. On a pool worker the chain goes back through the queue (one
    /// job per batch, so data-plane jobs interleave FIFO with a long
    /// migration); run inline — `spawn_detached` under a saturated pool
    /// executes on the submitter — the lane stays iterative instead,
    /// never recursing and never creating jobs the pool can't take.
    fn run_batch(&self, ctx: Arc<MigrationCtx>, batch: MigrationBatch) {
        let mut next = Some(batch);
        while let Some(batch) = next.take() {
            if self.process_batch(&ctx, batch) {
                return; // migration fully settled; this lane retires
            }
            if crate::ops::reactor::Reactor::in_worker() {
                self.spawn_next_batch(ctx);
                return;
            }
            next = ctx.queue.lock().unwrap().pop_front();
        }
    }

    /// One lane step: move the keys, retry on failure with bounded
    /// attempts, retire the old epoch when the last batch settles.
    /// Returns true once the whole migration has settled.
    fn process_batch(&self, ctx: &Arc<MigrationCtx>, batch: MigrationBatch) -> bool {
        // A panicking batch must not strand the migration (outstanding
        // would never reach zero): convert it into an ordinary batch
        // failure and let the retry path handle it.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || self.migrate_batch(ctx, &batch.keys),
        ))
        .unwrap_or_else(|_| {
            Err(Error::Connector("migration batch panicked".into()))
        });
        let m = &self.inner.metrics;
        if result.is_err() {
            if batch.attempts + 1 < MAX_BATCH_ATTEMPTS {
                m.add(&m.batch_retries, 1);
                // Still outstanding: back of the batch queue (a natural
                // backoff — other batches go first). The push happens
                // before the lane chains, so a lane can never observe an
                // empty queue and retire while a retry still needs it.
                ctx.queue.lock().unwrap().push_back(MigrationBatch {
                    keys: batch.keys,
                    attempts: batch.attempts + 1,
                });
                return false;
            }
            // Abandoned: the keys stay at their old placement (module
            // docs spell out the consequences).
            m.add(&m.keys_failed, batch.keys.len() as u64);
        }
        if ctx.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
            self.finalize_epoch(ctx.token);
            return true;
        }
        false
    }

    /// Move one batch: read from the old placement, write to the new one,
    /// then retire the copies on shards that left the replica set.
    fn migrate_batch(&self, ctx: &MigrationCtx, keys: &[String]) -> Result<()> {
        let blobs = ctx.old_router.get_many(keys)?;
        let mut items: Vec<(String, Vec<u8>)> = Vec::new();
        let mut bytes = 0u64;
        let mut skipped = 0u64;
        for (key, blob) in keys.iter().zip(blobs) {
            match blob {
                Some(b) => {
                    bytes += b.len() as u64;
                    items.push((key.clone(), b.to_vec()));
                }
                // Evicted concurrently, or a fresh key that was planned
                // but only ever lived at the new placement.
                None => skipped += 1,
            }
        }
        let migrated = items.len() as u64;
        if !items.is_empty() {
            ctx.new_router.put_many(items)?;
        }
        // Stale-copy sweep, batched per retired shard. Best-effort: a
        // failure leaves a redundant copy behind (wasted bytes, never a
        // wrong read — lookups go to the new placement first).
        let mut stale: HashMap<usize, Vec<String>> = HashMap::new();
        for key in keys {
            let new_set = ctx.new_router.replicas_for(key);
            for id in ctx.old_router.replicas_for(key) {
                if !new_set.contains(&id) {
                    stale.entry(id).or_default().push(key.clone());
                }
            }
        }
        for (id, batch) in stale {
            if let Some(conn) = ctx.old_members.get(&id) {
                let _ = conn.delete_many(&batch);
            }
        }
        let m = &self.inner.metrics;
        m.add(&m.keys_migrated, migrated);
        m.add(&m.bytes_moved, bytes);
        m.add(&m.keys_skipped, skipped);
        Ok(())
    }

    /// Retire the old epoch once its migration drained. Token-guarded so a
    /// straggler from an older migration cannot retire a newer epoch.
    fn finalize_epoch(&self, token: u64) {
        let retired = {
            let mut st = self.inner.state.write().unwrap();
            if st.migration_token == token { st.prev.take() } else { None }
        };
        if retired.is_some() {
            let m = &self.inner.metrics;
            m.add(&m.rebalances, 1);
        }
        let _g = self.inner.idle.lock().unwrap();
        self.inner.idle_cv.notify_all();
    }

    /// Epoch snapshot for the read/write paths: the lock is held only for
    /// the two Arc clones, never across backend I/O.
    fn snapshot(
        &self,
    ) -> (Arc<ShardedConnector>, Option<Arc<ShardedConnector>>) {
        let st = self.inner.state.read().unwrap();
        (st.current.clone(), st.prev.as_ref().map(|p| p.router.clone()))
    }

    /// Whether the current epoch moved on since `cur` was snapshotted. A
    /// read that misses after racing a flip (snapshot taken just before,
    /// probes landing after the drain) retries on the fresh epoch; a miss
    /// on a stable epoch is a genuine miss.
    fn epoch_changed(&self, cur: &Arc<ShardedConnector>) -> bool {
        !Arc::ptr_eq(&self.inner.state.read().unwrap().current, cur)
    }

    /// Epoch-stability retry (write half of the `get` retry): a write
    /// that raced a flip may have landed at a placement that is already
    /// draining — or drained, if the migration plan missed it. Re-home
    /// it through the fresh epoch, reading back from the epoch we wrote
    /// (still alive via our Arc). A `None` read-back means the daemon
    /// itself already moved the key.
    fn rehome(&self, key: &str, mut used: Arc<ShardedConnector>) -> Result<()> {
        for _ in 0..4 {
            if !self.epoch_changed(&used) {
                return Ok(());
            }
            let blob = used.get(key)?;
            let (cur, _) = self.snapshot();
            if let Some(b) = blob {
                cur.put(key, b.to_vec())?;
            }
            used = cur;
        }
        Ok(())
    }

    /// One read-through pass for `get` against a fixed epoch pair.
    fn get_via(
        &self,
        cur: &Arc<ShardedConnector>,
        prev: Option<&Arc<ShardedConnector>>,
        key: &str,
    ) -> Result<Option<Blob>> {
        let first = cur.get(key);
        let Some(prev) = prev else { return first };
        if let Ok(Some(ref b)) = first {
            return Ok(Some(b.clone()));
        }
        // Read-through: the key may not have been copied yet.
        let m = &self.inner.metrics;
        m.add(&m.dual_reads, 1);
        match prev.get(key) {
            Ok(Some(b)) => {
                m.add(&m.dual_read_hits, 1);
                Ok(Some(b))
            }
            prev_res => {
                // Copy/delete race: the daemon may have landed the key at
                // its new placement between our two probes.
                if let Some(b) = cur.get(key)? {
                    return Ok(Some(b));
                }
                first?;
                prev_res
            }
        }
    }

    /// One read-through pass for `get_many` (same order as [`get_via`]:
    /// new placement, old epoch, new placement again).
    fn get_many_via(
        &self,
        cur: &Arc<ShardedConnector>,
        prev: Option<&Arc<ShardedConnector>>,
        keys: &[String],
    ) -> Result<Vec<Option<Blob>>> {
        let mut out = cur.get_many(keys)?;
        let Some(prev) = prev else { return Ok(out) };
        let miss_idx: Vec<usize> = out
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.is_none().then_some(i))
            .collect();
        if miss_idx.is_empty() {
            return Ok(out);
        }
        let m = &self.inner.metrics;
        m.add(&m.dual_reads, miss_idx.len() as u64);
        let miss_keys: Vec<String> =
            miss_idx.iter().map(|&i| keys[i].clone()).collect();
        let mut still: Vec<usize> = Vec::new();
        for (&i, blob) in miss_idx.iter().zip(prev.get_many(&miss_keys)?) {
            match blob {
                Some(b) => {
                    m.add(&m.dual_read_hits, 1);
                    out[i] = Some(b);
                }
                None => still.push(i),
            }
        }
        if !still.is_empty() {
            let still_keys: Vec<String> =
                still.iter().map(|&i| keys[i].clone()).collect();
            for (&i, blob) in still.iter().zip(cur.get_many(&still_keys)?) {
                out[i] = blob;
            }
        }
        Ok(out)
    }

    /// One read-through pass for `exists` (same probe order as `get_via`).
    fn exists_via(
        &self,
        cur: &Arc<ShardedConnector>,
        prev: Option<&Arc<ShardedConnector>>,
        key: &str,
    ) -> Result<bool> {
        if cur.exists(key)? {
            return Ok(true);
        }
        let Some(prev) = prev else { return Ok(false) };
        let m = &self.inner.metrics;
        m.add(&m.dual_reads, 1);
        if prev.exists(key)? {
            m.add(&m.dual_read_hits, 1);
            return Ok(true);
        }
        cur.exists(key)
    }

    /// One read-through pass for `exists_many`.
    fn exists_many_via(
        &self,
        cur: &Arc<ShardedConnector>,
        prev: Option<&Arc<ShardedConnector>>,
        keys: &[String],
    ) -> Result<Vec<bool>> {
        let mut out = cur.exists_many(keys)?;
        let Some(prev) = prev else { return Ok(out) };
        let miss_idx: Vec<usize> = out
            .iter()
            .enumerate()
            .filter_map(|(i, &hit)| (!hit).then_some(i))
            .collect();
        if miss_idx.is_empty() {
            return Ok(out);
        }
        let m = &self.inner.metrics;
        m.add(&m.dual_reads, miss_idx.len() as u64);
        let miss_keys: Vec<String> =
            miss_idx.iter().map(|&i| keys[i].clone()).collect();
        let mut still: Vec<usize> = Vec::new();
        for (&i, hit) in miss_idx.iter().zip(prev.exists_many(&miss_keys)?) {
            if hit {
                m.add(&m.dual_read_hits, 1);
                out[i] = true;
            } else {
                still.push(i);
            }
        }
        if !still.is_empty() {
            let still_keys: Vec<String> =
                still.iter().map(|&i| keys[i].clone()).collect();
            for (&i, hit) in still.iter().zip(cur.exists_many(&still_keys)?) {
                out[i] = hit;
            }
        }
        Ok(out)
    }
}

fn list_keys_with_retry(conn: &dyn Connector) -> Result<Vec<String>> {
    let mut last = None;
    for _ in 0..3 {
        match conn.list_keys() {
            Ok(keys) => return Ok(keys),
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    Err(last.expect("retry loop ran"))
}

impl Connector for ElasticShards {
    fn desc(&self) -> ConnectorDesc {
        let st = self.inner.state.read().unwrap();
        ConnectorDesc::Elastic {
            name: self.inner.name.clone(),
            generation: self.inner.generation.load(Ordering::SeqCst),
            shard_ids: st.members.iter().map(|(id, _)| *id as u64).collect(),
            shards: st.members.iter().map(|(_, c)| c.desc()).collect(),
            replicas: self.inner.replicas as u64,
            vnodes: self.inner.vnodes as u64,
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        // Writes always land at the newest placement; the daemon never has
        // to chase them.
        let used = {
            let (cur, _) = self.snapshot();
            cur.put(key, data)?;
            cur
        };
        self.rehome(key, used)
    }

    /// Store only if absent. Read-through existence first (during a
    /// migration the value may live only at the old placement), then take
    /// the conditional write at the current epoch's primary — the
    /// linearization point for producers racing on one key.
    ///
    /// The whole decision holds the epoch **read lock**, unlike every
    /// other path (which snapshots and releases): an epoch flip takes the
    /// write lock, so no membership change can interleave between the
    /// probe and the conditional write. Without this, a producer that
    /// snapshotted the pre-flip epoch could miss a rival's win at the
    /// post-flip primary (a brand-new shard its probe never visits) and
    /// claim a second win at the old primary. The rare writer — a
    /// rebalance — waits out an in-flight conditional write; re-homing
    /// (which does its own locking) runs after the guard drops.
    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        let stored = {
            let st = self.inner.state.read().unwrap();
            let cur = st.current.clone();
            let prev = st.prev.as_ref().map(|p| p.router.clone());
            if self.exists_via(&cur, prev.as_ref(), key)? {
                return Ok(false);
            }
            let stored = cur.put_nx(key, data)?;
            drop(st);
            if stored {
                self.rehome(key, cur)?;
            }
            stored
        };
        Ok(stored)
    }

    /// Arm a watch that survives membership changes: arms on the current
    /// epoch (and the draining one, whose backends may already hold — or
    /// still receive — the value), and registers with the control plane,
    /// which re-arms it on every future epoch flip. First arm to fire
    /// wins; duplicates land nowhere.
    fn watch(&self, key: &str) -> Pending<Blob> {
        let (group, handle) = race();
        let (cur, prev) = self.snapshot();
        let mut arms = vec![cur.watch(key)];
        if let Some(prev) = prev {
            arms.push(prev.watch(key));
        }
        group.add_all(arms);
        {
            let mut watchers = self.inner.watchers.lock().unwrap();
            watchers.retain(|(_, g)| !g.settled());
            if !group.settled() {
                watchers.push((key.to_string(), group.clone()));
            }
        }
        // Close the arm/flip race: a rebalance that flipped epochs after
        // our snapshot but ran its re-arm loop before our registration
        // above would never cover this watch. Registration happens-before
        // any *later* flip's re-arm loop, so one re-check of the current
        // epoch here makes the coverage gap impossible.
        if !group.settled() && self.epoch_changed(&cur) {
            let (fresh, _) = self.snapshot();
            group.add(fresh.watch(key));
        }
        handle
    }

    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        let keys: Vec<String> = items.iter().map(|(k, _)| k.clone()).collect();
        let mut used = {
            let (cur, _) = self.snapshot();
            cur.put_many(items)?;
            cur
        };
        // Same re-homing retry as `put`, batched.
        for _ in 0..4 {
            if !self.epoch_changed(&used) {
                return Ok(());
            }
            let blobs = used.get_many(&keys)?;
            let rehome: Vec<(String, Vec<u8>)> = keys
                .iter()
                .zip(blobs)
                .filter_map(|(k, b)| b.map(|b| (k.clone(), b.to_vec())))
                .collect();
            let (cur, _) = self.snapshot();
            if !rehome.is_empty() {
                cur.put_many(rehome)?;
            }
            used = cur;
        }
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        // Bounded epoch-stability retry: a miss that raced a concurrent
        // flip (snapshot before, probes after the drain) re-reads on the
        // fresh epoch; a miss on a stable epoch is genuine.
        for _ in 0..4 {
            let (cur, prev) = self.snapshot();
            let res = self.get_via(&cur, prev.as_ref(), key);
            match &res {
                Ok(None) if self.epoch_changed(&cur) => continue,
                _ => return res,
            }
        }
        let (cur, prev) = self.snapshot();
        self.get_via(&cur, prev.as_ref(), key)
    }

    /// Rides [`Connector::get`]'s dual-epoch fallback unchanged: the blob
    /// a live epoch serves is already the backend's shared allocation, so
    /// the view is a full window over it — a refcount bump, no byte copy,
    /// and no second copy of the epoch-retry logic to keep in sync.
    fn get_view(&self, key: &str) -> Result<Option<Buf>> {
        Ok(self.get(key)?.map(Buf::from_arc))
    }

    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        let (cur, prev) = self.snapshot();
        let mut out = self.get_many_via(&cur, prev.as_ref(), keys)?;
        let mut used = cur;
        // Same epoch-stability retry as `get`, re-probing only the misses.
        for _ in 0..4 {
            let miss_idx: Vec<usize> = out
                .iter()
                .enumerate()
                .filter_map(|(i, b)| b.is_none().then_some(i))
                .collect();
            if miss_idx.is_empty() || !self.epoch_changed(&used) {
                break;
            }
            let miss_keys: Vec<String> =
                miss_idx.iter().map(|&i| keys[i].clone()).collect();
            let (cur, prev) = self.snapshot();
            let filled = self.get_many_via(&cur, prev.as_ref(), &miss_keys)?;
            for (&i, blob) in miss_idx.iter().zip(filled) {
                out[i] = blob;
            }
            used = cur;
        }
        Ok(out)
    }

    fn evict(&self, key: &str) -> Result<()> {
        // Delete at both placements during a migration, so an un-copied
        // old replica cannot outlive the eviction.
        let (cur, prev) = self.snapshot();
        let first = cur.evict(key);
        match prev {
            Some(prev) => {
                let second = prev.evict(key);
                first?;
                second
            }
            None => first,
        }
    }

    fn delete_many(&self, keys: &[String]) -> Result<()> {
        let (cur, prev) = self.snapshot();
        let first = cur.delete_many(keys);
        match prev {
            Some(prev) => {
                let second = prev.delete_many(keys);
                first?;
                second
            }
            None => first,
        }
    }

    fn exists(&self, key: &str) -> Result<bool> {
        // Same epoch-stability retry as `get`.
        for _ in 0..4 {
            let (cur, prev) = self.snapshot();
            let res = self.exists_via(&cur, prev.as_ref(), key);
            match &res {
                Ok(false) if self.epoch_changed(&cur) => continue,
                _ => return res,
            }
        }
        let (cur, prev) = self.snapshot();
        self.exists_via(&cur, prev.as_ref(), key)
    }

    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        let (cur, prev) = self.snapshot();
        let mut out = self.exists_many_via(&cur, prev.as_ref(), keys)?;
        let mut used = cur;
        for _ in 0..4 {
            let miss_idx: Vec<usize> = out
                .iter()
                .enumerate()
                .filter_map(|(i, &hit)| (!hit).then_some(i))
                .collect();
            if miss_idx.is_empty() || !self.epoch_changed(&used) {
                break;
            }
            let miss_keys: Vec<String> =
                miss_idx.iter().map(|&i| keys[i].clone()).collect();
            let (cur, prev) = self.snapshot();
            let filled =
                self.exists_many_via(&cur, prev.as_ref(), &miss_keys)?;
            for (&i, hit) in miss_idx.iter().zip(filled) {
                out[i] = hit;
            }
            used = cur;
        }
        Ok(out)
    }

    fn list_keys(&self) -> Result<Vec<String>> {
        // Union over current members plus any epoch still draining.
        let (members, prev_members) = {
            let st = self.inner.state.read().unwrap();
            (
                st.members.clone(),
                st.prev.as_ref().map(|p| p.members.clone()).unwrap_or_default(),
            )
        };
        let live: HashSet<usize> = members.iter().map(|(id, _)| *id).collect();
        let mut all = Vec::new();
        for (_, conn) in &members {
            all.extend(conn.list_keys()?);
        }
        for (id, conn) in &prev_members {
            if !live.contains(id) {
                all.extend(conn.list_keys()?);
            }
        }
        all.sort_unstable();
        all.dedup();
        Ok(all)
    }

    fn len(&self) -> Result<usize> {
        // Copies count once each (fabric convention); a draining epoch
        // contributes only the members that already left the fabric.
        let (members, prev_members) = {
            let st = self.inner.state.read().unwrap();
            (
                st.members.clone(),
                st.prev.as_ref().map(|p| p.members.clone()).unwrap_or_default(),
            )
        };
        let live: HashSet<usize> = members.iter().map(|(id, _)| *id).collect();
        let mut total = 0;
        for (_, conn) in &members {
            total += conn.len()?;
        }
        for (id, conn) in &prev_members {
            if !live.contains(id) {
                total += conn.len()?;
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemoryConnector;

    fn unique_name(tag: &str) -> String {
        use std::sync::atomic::AtomicU64;
        static NEXT: AtomicU64 = AtomicU64::new(0);
        format!("el-{tag}-{}", NEXT.fetch_add(1, Ordering::Relaxed))
    }

    fn members(n: usize) -> ShardMembers {
        (0..n).map(|id| (id, MemoryConnector::new())).collect()
    }

    fn put_keys(e: &ElasticShards, n: usize) -> Vec<String> {
        (0..n)
            .map(|i| {
                let key = format!("obj-{i:04}");
                e.put(&key, vec![i as u8; 32]).unwrap();
                key
            })
            .collect()
    }

    #[test]
    fn add_shard_migrates_only_remapped_keys() {
        let e =
            ElasticShards::new(&unique_name("grow"), members(4), 1, 64).unwrap();
        let keys = put_keys(&e, 400);
        let extra = MemoryConnector::new();
        e.add_shard(4, extra.clone()).unwrap();
        assert!(e.wait_quiescent(Some(Duration::from_secs(30))));
        assert!(!e.migrating());
        assert_eq!(e.generation(), 1);
        assert_eq!(e.shard_ids(), vec![0, 1, 2, 3, 4]);

        let m = e.metrics();
        assert_eq!(m.rebalances, 1);
        assert!(m.keys_migrated > 0, "nothing migrated");
        assert!(
            m.keys_migrated < 200,
            "{} of 400 keys moved — not ~1/5",
            m.keys_migrated
        );
        assert!(m.bytes_moved >= m.keys_migrated * 32);
        // The new shard holds exactly the migrated keys.
        assert_eq!(extra.len().unwrap() as u64, m.keys_migrated);

        // Every key readable, every key at its new primary.
        let router = e.router();
        for key in &keys {
            assert_eq!(
                e.get(key).unwrap().map(|b| b.len()),
                Some(32),
                "key {key} lost by the rebalance"
            );
            assert!(router.get(key).unwrap().is_some(), "{key} not at new placement");
        }
        // No stale copies left behind: one copy per key fabric-wide.
        assert_eq!(e.len().unwrap(), 400);
    }

    #[test]
    fn remove_shard_drains_it_completely() {
        let e = ElasticShards::new(&unique_name("shrink"), members(3), 1, 64)
            .unwrap();
        let victim: Arc<dyn Connector> = {
            let st = e.inner.state.read().unwrap();
            st.members[1].1.clone()
        };
        let keys = put_keys(&e, 200);
        let resident_before = victim.len().unwrap();
        assert!(resident_before > 0, "victim shard got no keys");

        e.remove_shard(1).unwrap();
        assert!(e.wait_quiescent(Some(Duration::from_secs(30))));
        assert_eq!(e.shard_ids(), vec![0, 2]);
        assert_eq!(victim.len().unwrap(), 0, "removed shard not drained");
        for key in &keys {
            assert!(e.get(key).unwrap().is_some(), "key {key} lost on shrink");
        }
        assert_eq!(e.len().unwrap(), 200);
        let m = e.metrics();
        assert_eq!(m.keys_migrated, resident_before as u64);
    }

    #[test]
    fn empty_fabric_rebalance_finalizes_inline() {
        let e =
            ElasticShards::new(&unique_name("empty"), members(2), 1, 32).unwrap();
        e.add_shard(2, MemoryConnector::new()).unwrap();
        // No keys -> no plan -> already quiescent.
        assert!(!e.migrating());
        assert_eq!(e.generation(), 1);
        assert_eq!(e.metrics().rebalances, 1);
        assert_eq!(e.metrics().keys_planned, 0);
    }

    #[test]
    fn membership_validation() {
        let e =
            ElasticShards::new(&unique_name("valid"), members(2), 1, 32).unwrap();
        assert!(e.add_shard(0, MemoryConnector::new()).is_err()); // dup id
        assert!(e.remove_shard(9).is_err()); // unknown id
        e.remove_shard(0).unwrap();
        e.wait_quiescent(None);
        assert!(e.remove_shard(1).is_err()); // would empty the fabric
        // Name collisions are rejected until the name is unregistered.
        let name = unique_name("collide");
        let _a = ElasticShards::new(&name, members(1), 1, 32).unwrap();
        assert!(ElasticShards::new(&name, members(1), 1, 32).is_err());
        assert!(ElasticShards::unregister(&name));
        assert!(!ElasticShards::unregister(&name));
        let _b = ElasticShards::new(&name, members(1), 1, 32).unwrap();
    }

    #[test]
    fn desc_attaches_to_live_control_plane() {
        let name = unique_name("attach");
        let e = ElasticShards::new(&name, members(3), 1, 64).unwrap();
        let keys = put_keys(&e, 60);
        // Serialize the generation-0 descriptor (a proxy minted now would
        // carry exactly these bytes) ...
        use crate::codec::{Decode, Encode};
        let stale = e.desc().to_bytes();
        // ... rebalance ...
        e.add_shard(3, MemoryConnector::new()).unwrap();
        assert!(e.wait_quiescent(Some(Duration::from_secs(30))));
        // ... and the stale descriptor still resolves every key, because
        // connect() re-attaches to the live control plane.
        let decoded = ConnectorDesc::from_bytes(&stale).unwrap();
        assert!(matches!(
            &decoded,
            ConnectorDesc::Elastic { generation: 0, .. }
        ));
        let conn = decoded.connect().unwrap();
        for key in &keys {
            assert!(
                conn.get(key).unwrap().is_some(),
                "stale desc lost key {key} after rebalance"
            );
        }
        // The attached handle reports the live generation, not the stale one.
        match conn.desc() {
            ConnectorDesc::Elastic { generation, shards, .. } => {
                assert_eq!(generation, 1);
                assert_eq!(shards.len(), 4);
            }
            other => panic!("unexpected desc {other:?}"),
        }
    }

    #[test]
    fn watch_rearms_across_epoch_flip() {
        let e =
            ElasticShards::new(&unique_name("watch"), members(3), 1, 64).unwrap();
        // Arm watches on keys that do not exist yet, then change the
        // membership: some keys' placement moves to the new shard, and a
        // post-flip put must still wake the pre-flip watch.
        let keys: Vec<String> =
            (0..40).map(|i| format!("pending-{i:03}")).collect();
        let handles: Vec<_> = keys.iter().map(|k| e.watch(k)).collect();
        e.add_shard(3, MemoryConnector::new()).unwrap();
        assert!(e.wait_quiescent(Some(Duration::from_secs(30))));
        // At least one armed key now has its primary on the new shard.
        let router = e.router();
        assert!(
            keys.iter().any(|k| router.shard_for(k) == 3),
            "test needs a key remapped to the new shard"
        );
        for (key, handle) in keys.iter().zip(&handles) {
            assert!(!handle.is_complete(), "{key} fired without a put");
        }
        for (i, key) in keys.iter().enumerate() {
            e.put(key, vec![i as u8; 8]).unwrap();
        }
        for (i, handle) in handles.into_iter().enumerate() {
            assert_eq!(
                handle.wait().unwrap().to_vec(),
                vec![i as u8; 8],
                "watch {i} stranded by the epoch flip"
            );
        }
    }

    #[test]
    fn put_nx_single_assignment_through_migration() {
        let e = ElasticShards::new(&unique_name("nx"), members(3), 1, 64)
            .unwrap();
        assert!(e.put_nx("winner", vec![1]).unwrap());
        assert!(!e.put_nx("winner", vec![2]).unwrap());
        e.add_shard(3, MemoryConnector::new()).unwrap();
        assert!(e.wait_quiescent(Some(Duration::from_secs(30))));
        // Post-migration: the value survives and the key stays taken —
        // including via read-through semantics mid-state.
        assert!(!e.put_nx("winner", vec![3]).unwrap());
        assert_eq!(e.get("winner").unwrap().map(|b| b.to_vec()), Some(vec![1]));
    }

    #[test]
    fn replicated_fabric_survives_rebalance() {
        let e = ElasticShards::new(&unique_name("repl"), members(3), 2, 64)
            .unwrap();
        let keys = put_keys(&e, 120);
        assert_eq!(e.len().unwrap(), 240); // R=2 copies
        e.add_shard(3, MemoryConnector::new()).unwrap();
        assert!(e.wait_quiescent(Some(Duration::from_secs(30))));
        for key in &keys {
            assert!(e.get(key).unwrap().is_some());
        }
        // Replica sets converged: exactly two copies per key, no strays.
        assert_eq!(e.len().unwrap(), 240);
        let flags = e.exists_many(&keys).unwrap();
        assert!(flags.iter().all(|&b| b));
    }
}
