//! Sharded store fabric: consistent-hash routing, replication, and
//! batched multi-key traffic over N backend connectors.
//!
//! The paper's proxy patterns (Sec III) mediate every object through one
//! channel, which caps aggregate throughput at that single endpoint. This
//! module removes the bottleneck while keeping proxies fully transparent:
//!
//! * [`ring`] — a consistent-hash ring with virtual nodes mapping object
//!   keys to shards, with the classic remapping-locality property (adding
//!   a shard moves ~1/N of the keys, all of them *to* the new shard);
//! * [`router`] — [`ShardedConnector`], an ordinary
//!   [`Connector`](crate::store::Connector) that routes each key to its
//!   replica set (R distinct shards), falls back to surviving replicas on
//!   read miss/failure, and fans batched `put_many`/`get_many` traffic out
//!   to all shards in parallel;
//! * [`ShardedDesc`] — the serializable fabric description (wire form:
//!   [`ConnectorDesc::Sharded`](crate::store::ConnectorDesc)). A proxy
//!   minted against the fabric embeds it in its factory, so resolution in
//!   any process rebuilds the identical ring and routes to the same shard.
//!
//! ```no_run
//! use proxystore::prelude::*;
//! use proxystore::shard::ShardedDesc;
//!
//! let desc = ShardedDesc::new(vec![
//!     ConnectorDesc::TcpKv { addr: "10.0.0.1:6379".into() },
//!     ConnectorDesc::TcpKv { addr: "10.0.0.2:6379".into() },
//! ])
//! .with_replicas(2);
//! let store = Store::new("fabric", desc.connect()?);
//! let keys = store.put_many(&[Bytes(vec![1]), Bytes(vec![2])])?;
//! let objs: Vec<Option<Bytes>> = store.get_many(&keys)?;
//! # Ok::<(), proxystore::Error>(())
//! ```

pub mod ring;
pub mod router;

pub use ring::{hash_key, HashRing};
pub use router::{ShardedConnector, ShardedDesc, DEFAULT_VNODES};
