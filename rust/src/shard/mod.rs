//! Sharded store fabric: consistent-hash routing, replication, batched
//! multi-key traffic, and live rebalancing over N backend connectors.
//!
//! The paper's proxy patterns (Sec III) mediate every object through one
//! channel, which caps aggregate throughput at that single endpoint. This
//! module removes the bottleneck while keeping proxies fully transparent.
//! It is built as three layers, each on top of the previous:
//!
//! * [`ring`] — the placement function: a consistent-hash ring with
//!   virtual nodes mapping object keys to stable shard ids, with the
//!   classic remapping-locality property (adding a shard moves ~1/N of
//!   the keys, all of them *to* the new shard). Pure data, no I/O.
//! * [`router`] — the data plane: [`ShardedConnector`], an ordinary
//!   [`Connector`](crate::store::Connector) that routes each key to its
//!   replica set (R distinct shards), falls back to surviving replicas on
//!   read miss/failure, and fans batched `put_many`/`get_many`/
//!   `exists_many` traffic out to all shards in parallel as submitted ops
//!   on the shared reactor pool ([`crate::ops::reactor`]). Its membership
//!   is fixed at construction — one router is one *epoch* of the fabric.
//! * [`rebalance`] — the control plane: [`ElasticShards`] owns a sequence
//!   of router epochs and supports live
//!   [`add_shard`](ElasticShards::add_shard) /
//!   [`remove_shard`](ElasticShards::remove_shard). A background
//!   migration daemon copies exactly the remapped ~1/N keys between
//!   epochs with batched moves while reads serve *through* both epochs
//!   (new placement first, old as fallback), so a rebalance never loses a
//!   read. [`ConnectorDesc::Elastic`](crate::store::ConnectorDesc) is its
//!   generation-aware wire form: proxies minted before a rebalance
//!   re-attach to the live control plane and keep resolving.
//!
//! [`ShardedDesc`] / [`ElasticDesc`] are the serializable fabric
//! descriptions. A proxy minted against either embeds it in its factory,
//! so resolution in any process rebuilds the identical ring and routes to
//! the same shard.
//!
//! ```no_run
//! use proxystore::prelude::*;
//! use proxystore::shard::ShardedDesc;
//!
//! let desc = ShardedDesc::new(vec![
//!     ConnectorDesc::TcpKv { addr: "10.0.0.1:6379".into() },
//!     ConnectorDesc::TcpKv { addr: "10.0.0.2:6379".into() },
//! ])
//! .with_replicas(2);
//! let store = Store::new("fabric", desc.connect()?);
//! let keys = store.put_many(&[Bytes(vec![1]), Bytes(vec![2])])?;
//! let objs: Vec<Option<Bytes>> = store.get_many(&keys)?;
//! # Ok::<(), proxystore::Error>(())
//! ```
//!
//! Growing the fabric under load:
//!
//! ```no_run
//! use proxystore::prelude::*;
//! use proxystore::shard::ElasticShards;
//! use std::sync::Arc;
//!
//! let members: proxystore::shard::ShardMembers =
//!     (0..4).map(|id| (id, MemoryConnector::new())).collect();
//! let elastic = ElasticShards::new("fleet", members, 1, 0)?;
//! let store = Store::new("fleet", Arc::new(elastic.clone()));
//! let objs: Vec<Bytes> = (0..128u8).map(|i| Bytes(vec![i])).collect();
//! let keys = store.put_many(&objs)?;
//! elastic.add_shard(4, MemoryConnector::new())?; // reads keep working
//! elastic.wait_quiescent(None);                  // ~1/5 of keys migrated
//! # Ok::<(), proxystore::Error>(())
//! ```

pub mod rebalance;
pub mod ring;
pub mod router;

pub use rebalance::{
    connect_elastic, ElasticDesc, ElasticShards, ShardMembers,
    MIGRATION_BATCH,
};
pub use ring::{hash_key, HashRing};
pub use router::{ShardedConnector, ShardedDesc, DEFAULT_VNODES};
