//! PJRT runtime: load and execute the JAX/Pallas AOT artifacts.
//!
//! `python/compile/aot.py` lowers the L2 graphs (which embed the L1 Pallas
//! kernels, interpret-mode) to **HLO text** under `artifacts/`, plus a
//! line-oriented `manifest.txt` describing every entry point's I/O shapes
//! and an initial-parameter bank (`params.bin`). This module is the only
//! bridge between that build-time world and the Rust request path:
//!
//! ```text
//! manifest.txt ──► ModelRegistry::load ──► HloModuleProto::from_text_file
//!                                          └► PjRtClient::cpu().compile
//! worker task  ──► registry.execute_f32("encode_b8", inputs) ─► outputs
//! ```
//!
//! HLO *text* (not serialized protos) is the interchange format: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Executables are compiled once (lazily, cached)
//! and shared across worker threads.

mod manifest;

pub use manifest::{Manifest, ModelSpec, ParamSpec, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Error, Result};

/// Compiled-model registry shared by all workers.
pub struct ModelRegistry {
    dir: PathBuf,
    manifest: Manifest,
    /// name → compiled executable (lazy, compile-once).
    ///
    /// Declared BEFORE `client`: struct fields drop in declaration order,
    /// and loaded executables must be destroyed before the PJRT client
    /// that owns their runtime (reversing the order is a use-after-free
    /// inside xla_extension).
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    client: xla::PjRtClient,
    /// Cached parameter bank.
    params: OnceLock<HashMap<String, Vec<f32>>>,
}

// The PJRT CPU client and loaded executables are internally synchronized.
unsafe impl Send for ModelRegistry {}
unsafe impl Sync for ModelRegistry {}

impl ModelRegistry {
    /// Load the manifest and create the PJRT CPU client (no compilation
    /// happens yet).
    pub fn load(dir: impl AsRef<Path>) -> Result<Arc<ModelRegistry>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::parse_file(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("pjrt cpu client: {e}")))?;
        Ok(Arc::new(ModelRegistry {
            dir,
            manifest,
            client,
            compiled: Mutex::new(HashMap::new()),
            params: OnceLock::new(),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch the cached) executable for a model.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.model(name)?;
        let path = self.dir.join(&spec.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 path {path:?}"))
            })?,
        )
        .map_err(|e| Error::Runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?,
        );
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a model on f32 host buffers (shapes validated against the
    /// manifest). Returns one `Vec<f32>` per declared output.
    pub fn execute_f32(
        &self,
        name: &str,
        inputs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.model(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, tensor) in inputs.iter().zip(&spec.inputs) {
            let want: usize = tensor.elements();
            if buf.len() != want {
                return Err(Error::Runtime(format!(
                    "{name}: input {} expects {} elems ({}), got {}",
                    tensor.name,
                    want,
                    tensor.shape_string(),
                    buf.len()
                )));
            }
            let lit = if tensor.shape.is_empty() {
                xla::Literal::scalar(buf[0])
            } else {
                let dims: Vec<i64> =
                    tensor.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf).reshape(&dims).map_err(|e| {
                    Error::Runtime(format!("reshape {}: {e}", tensor.name))
                })?
            };
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let root = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime(format!("{name}: empty result")))?
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let outs = root
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;
        if outs.len() != spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: manifest declares {} outputs, executable returned {}",
                spec.outputs.len(),
                outs.len()
            )));
        }
        outs.into_iter()
            .map(|lit| {
                lit.to_vec::<f32>().map_err(|e| {
                    Error::Runtime(format!("output of {name}: {e}"))
                })
            })
            .collect()
    }

    /// Execute a model, auto-filling any input whose name matches an entry
    /// in the parameter bank; remaining inputs are taken from `extra` by
    /// name. This is the worker-facing convenience used by the apps.
    pub fn execute_with_bank(
        &self,
        name: &str,
        extra: &[(&str, &[f32])],
    ) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.model(name)?.clone();
        let bank = self.initial_params()?;
        let mut inputs: Vec<&[f32]> = Vec::with_capacity(spec.inputs.len());
        for tensor in &spec.inputs {
            if let Some((_, buf)) =
                extra.iter().find(|(n, _)| *n == tensor.name)
            {
                inputs.push(buf);
            } else if let Some(p) = bank.get(&tensor.name) {
                inputs.push(p.as_slice());
            } else {
                return Err(Error::Runtime(format!(
                    "{name}: no binding for input {}",
                    tensor.name
                )));
            }
        }
        self.execute_f32(name, &inputs)
    }

    /// Initial parameters from `params.bin`, in manifest order.
    pub fn initial_params(&self) -> Result<&HashMap<String, Vec<f32>>> {
        if let Some(p) = self.params.get() {
            return Ok(p);
        }
        let path = self.dir.join("params.bin");
        let raw = std::fs::read(&path)?;
        let mut map = HashMap::new();
        for p in &self.manifest.params {
            let end = p.offset + p.nbytes;
            if end > raw.len() {
                return Err(Error::Runtime(format!(
                    "params.bin truncated: {} needs {}..{}",
                    p.name, p.offset, end
                )));
            }
            let floats: Vec<f32> = raw[p.offset..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            map.insert(p.name.clone(), floats);
        }
        let _ = self.params.set(map);
        Ok(self.params.get().expect("just set"))
    }

    /// Parameter vector in the canonical (manifest) order — the order the
    /// flat-argument entry points expect.
    pub fn params_in_order(&self) -> Result<Vec<Vec<f32>>> {
        let bank = self.initial_params()?;
        self.manifest
            .params
            .iter()
            .map(|p| {
                bank.get(&p.name).cloned().ok_or_else(|| {
                    Error::Runtime(format!("missing param {}", p.name))
                })
            })
            .collect()
    }

    /// Model geometry value from the manifest.
    pub fn geometry(&self, key: &str) -> Option<u64> {
        self.manifest.geometry.get(key).copied()
    }
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRegistry")
            .field("dir", &self.dir)
            .field("models", &self.manifest.models.len())
            .finish()
    }
}

/// Repo-level artifacts directory (used by tests/benches/examples).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("PROXYSTORE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Arc<ModelRegistry> {
        let dir = default_artifacts_dir();
        assert!(
            dir.join("manifest.txt").exists(),
            "artifacts not built — run `make artifacts` first"
        );
        ModelRegistry::load(dir).unwrap()
    }

    #[test]
    fn manifest_loads_with_expected_models() {
        let reg = registry();
        for name in ["encode_b1", "encode_b8", "train_step_b32",
                     "featurize_b1", "mof_score_c256"] {
            assert!(reg.manifest().model(name).is_ok(), "{name}");
        }
        assert_eq!(reg.geometry("feature_dim"), Some(1024));
    }

    #[test]
    fn encode_executes_with_params() {
        let reg = registry();
        let d = reg.geometry("feature_dim").unwrap() as usize;
        let l = reg.geometry("latent_dim").unwrap() as usize;
        let x = vec![0.1f32; d]; // batch 1
        let out = reg
            .execute_with_bank("encode_b1", &[("x", &x)])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), l);
        assert!(out[0].iter().all(|v| v.is_finite()));
        // Deterministic across calls.
        let out2 = reg.execute_with_bank("encode_b1", &[("x", &x)]).unwrap();
        assert_eq!(out[0], out2[0]);
    }

    #[test]
    fn featurize_matches_contact_map_properties() {
        let reg = registry();
        let n = reg.geometry("n_residues").unwrap() as usize;
        let coords: Vec<f32> = (0..n * 3).map(|i| (i as f32) * 0.1).collect();
        let out = reg.execute_f32("featurize_b1", &[&coords]).unwrap();
        let map = &out[0];
        assert_eq!(map.len(), n * n);
        // Soft contact values are in (0, 1); self-contact ~ sigmoid(1).
        assert!(map.iter().all(|&v| (0.0..=1.0).contains(&v)));
        for i in 0..n {
            assert!(map[i * n + i] > 0.7, "diag {i} = {}", map[i * n + i]);
        }
    }

    #[test]
    fn train_step_reduces_loss_over_iterations() {
        let reg = registry();
        let d = reg.geometry("feature_dim").unwrap() as usize;
        let b = reg.geometry("train_batch").unwrap() as usize;
        let mut params = reg.params_in_order().unwrap();
        let x: Vec<f32> = (0..b * d).map(|i| ((i % 97) as f32) / 97.0).collect();
        let lr = [0.05f32];
        let mut losses = Vec::new();
        for _ in 0..3 {
            let mut inputs: Vec<&[f32]> =
                params.iter().map(|p| p.as_slice()).collect();
            inputs.push(&x);
            inputs.push(&lr);
            let mut out = reg.execute_f32("train_step_b32", &inputs).unwrap();
            let loss = out.pop().expect("loss")[0];
            losses.push(loss);
            params = out;
        }
        assert!(
            losses[2] < losses[0],
            "training diverged: {losses:?}"
        );
    }

    #[test]
    fn mof_score_executes() {
        let reg = registry();
        let c = reg.geometry("mof_candidates").unwrap() as usize;
        let d = reg.geometry("mof_dim").unwrap() as usize;
        let feats = vec![0.1f32; c * d];
        let w = vec![0.2f32; d];
        let out = reg.execute_f32("mof_score_c256", &[&feats, &w]).unwrap();
        assert_eq!(out[0].len(), c);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn shape_mismatch_is_runtime_error() {
        let reg = registry();
        let bad = vec![0.0f32; 7];
        let r = reg.execute_f32("featurize_b1", &[&bad]);
        assert!(matches!(r, Err(Error::Runtime(_))));
        let r = reg.execute_f32("nope", &[]);
        assert!(r.is_err());
    }
}
