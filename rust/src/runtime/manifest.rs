//! Parser for the line-oriented AOT manifest written by `aot.py`.
//!
//! Format (one record per line, whitespace-separated):
//! ```text
//! geometry <key> <u64>
//! model <name> <hlo-file>
//! input <name> <dtype> <AxBxC|scalar>     # within a model block
//! output <name> <dtype> <AxBxC|scalar>
//! end
//! param <name> <dtype> <shape> <offset> <nbytes>
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// One tensor (input or output) of a model entry point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    /// Empty = scalar.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn shape_string(&self) -> String {
        if self.shape.is_empty() {
            "scalar".into()
        } else {
            self.shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x")
        }
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub hlo: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One entry in the initial-parameter bank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Manifest {
    pub geometry: BTreeMap<String, u64>,
    pub models: Vec<ModelSpec>,
    pub params: Vec<ParamSpec>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(Vec::new());
    }
    s.split('x')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|e| Error::Config(format!("bad shape {s}: {e}")))
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut current: Option<ModelSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            let ctx = |msg: &str| {
                Error::Config(format!("manifest line {}: {msg}", lineno + 1))
            };
            match parts.as_slice() {
                [] => {}
                [w, ..] if w.starts_with('#') => {}
                ["geometry", k, v] => {
                    let v = v.parse().map_err(|_| ctx("bad geometry value"))?;
                    m.geometry.insert(k.to_string(), v);
                }
                ["model", name, hlo] => {
                    if current.is_some() {
                        return Err(ctx("model block not closed with `end`"));
                    }
                    current = Some(ModelSpec {
                        name: name.to_string(),
                        hlo: hlo.to_string(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
                [kind @ ("input" | "output"), name, dtype, shape] => {
                    let spec = TensorSpec {
                        name: name.to_string(),
                        dtype: dtype.to_string(),
                        shape: parse_shape(shape)?,
                    };
                    let cur = current
                        .as_mut()
                        .ok_or_else(|| ctx("io line outside model block"))?;
                    if *kind == "input" {
                        cur.inputs.push(spec);
                    } else {
                        cur.outputs.push(spec);
                    }
                }
                ["end"] => {
                    let cur =
                        current.take().ok_or_else(|| ctx("stray `end`"))?;
                    m.models.push(cur);
                }
                ["param", name, dtype, shape, offset, nbytes] => {
                    m.params.push(ParamSpec {
                        name: name.to_string(),
                        dtype: dtype.to_string(),
                        shape: parse_shape(shape)?,
                        offset: offset
                            .parse()
                            .map_err(|_| ctx("bad offset"))?,
                        nbytes: nbytes
                            .parse()
                            .map_err(|_| ctx("bad nbytes"))?,
                    });
                }
                _ => return Err(ctx(&format!("unrecognized line: {line:?}"))),
            }
        }
        if current.is_some() {
            return Err(Error::Config("manifest ends inside model block".into()));
        }
        Ok(m)
    }

    pub fn parse_file(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("read manifest {path:?}: {e}"))
        })?;
        Self::parse(&text)
    }

    /// Find a model by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.iter().find(|m| m.name == name).ok_or_else(|| {
            Error::Config(format!(
                "model {name} not in manifest (have: {})",
                self.models
                    .iter()
                    .map(|m| m.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
geometry feature_dim 64
model enc enc.hlo.txt
input w1 float32 64x32
input x float32 4x64
output z float32 4x8
end
model ts ts.hlo.txt
input lr float32 scalar
output loss float32 scalar
end
param w1 float32 64x32 0 8192
param b1 float32 32 8192 128
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.geometry["feature_dim"], 64);
        assert_eq!(m.models.len(), 2);
        let enc = m.model("enc").unwrap();
        assert_eq!(enc.hlo, "enc.hlo.txt");
        assert_eq!(enc.inputs[1].shape, vec![4, 64]);
        assert_eq!(enc.inputs[1].elements(), 256);
        let ts = m.model("ts").unwrap();
        assert_eq!(ts.inputs[0].shape, Vec::<usize>::new());
        assert_eq!(ts.inputs[0].elements(), 1);
        assert_eq!(ts.inputs[0].shape_string(), "scalar");
        assert_eq!(m.params[1].offset, 8192);
    }

    #[test]
    fn unknown_model_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("bogus line here").is_err());
        assert!(Manifest::parse("input x float32 2x2").is_err()); // outside block
        assert!(Manifest::parse("model a a.hlo\nmodel b b.hlo").is_err());
        assert!(Manifest::parse("model a a.hlo\ninput x f32 2y2\nend").is_err());
        assert!(Manifest::parse("end").is_err());
        assert!(Manifest::parse("model a a.hlo").is_err()); // unclosed
    }

    #[test]
    fn empty_and_comments_ok() {
        let m = Manifest::parse("\n# nothing\n\n").unwrap();
        assert!(m.models.is_empty());
    }
}
