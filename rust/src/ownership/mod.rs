//! Proxy ownership model (Sec IV-C): Rust's ownership & borrowing rules
//! applied to *distributed* objects.
//!
//! - [`OwnedProxy<T>`] — the single owner of a stored object. When it goes
//!   out of scope the object is evicted from the mediated channel.
//! - [`RefProxy<T>`] — an immutable borrow; any number may exist at once.
//! - [`RefMutProxy<T>`] — a mutable borrow with exclusive write access to
//!   the global copy; at most one, and never alongside `RefProxy`s.
//!
//! The compiler already enforces these rules for *local* lifetimes; the
//! distributed part — "is the object still resident in the store, and who
//! may mutate it" — is enforced at runtime through a per-key
//! [`BorrowState`] registry, mirroring the paper's Python implementation
//! (which has no compiler to lean on at all). Violations (e.g. dropping an
//! owner while borrows are live) are recorded in a global counter and the
//! eviction is *deferred* to the last borrow, trading the paper's runtime
//! exception for memory safety plus an observable diagnostic;
//! [`take_violations`] lets tests and the StoreExecutor surface them.

pub mod lifetime;

pub use lifetime::{ContextLifetime, LeaseLifetime, Lifetime, StaticLifetime};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::codec::{Decode, Encode, Reader};
use crate::error::{Error, Result};
use crate::proxy::{Factory, Proxy};
use crate::store::Store;

/// Borrow bookkeeping for one stored object.
#[derive(Debug, Default)]
pub struct BorrowState {
    inner: Mutex<BorrowInner>,
}

#[derive(Debug, Default)]
struct BorrowInner {
    refs: u32,
    mut_out: bool,
    owner_alive: bool,
    /// Owner dropped while borrows were live: evict when the last borrow
    /// returns.
    evict_deferred: bool,
}

fn registry() -> &'static Mutex<HashMap<String, Arc<BorrowState>>> {
    static REG: OnceLock<Mutex<HashMap<String, Arc<BorrowState>>>> =
        OnceLock::new();
    REG.get_or_init(Default::default)
}

fn state_for(key: &str) -> Arc<BorrowState> {
    registry()
        .lock()
        .unwrap()
        .entry(key.to_string())
        .or_default()
        .clone()
}

fn drop_state(key: &str) {
    registry().lock().unwrap().remove(key);
}

static VIOLATIONS: AtomicU64 = AtomicU64::new(0);

fn record_violation(msg: &str) {
    VIOLATIONS.fetch_add(1, Ordering::Relaxed);
    eprintln!("[proxystore] ownership violation: {msg}");
}

/// Total ownership violations since the last [`take_violations`] call.
pub fn take_violations() -> u64 {
    VIOLATIONS.swap(0, Ordering::Relaxed)
}

fn evict_key(factory: &Factory) {
    factory.invalidate_cache();
    if let Ok(conn) = factory.connector() {
        let _ = conn.evict(&factory.key);
    }
    drop_state(&factory.key);
}

// --------------------------------------------------------------------------
// OwnedProxy
// --------------------------------------------------------------------------

/// Sole owner of a stored object; evicts the global copy on drop.
pub struct OwnedProxy<T: Decode + Encode> {
    proxy: Proxy<T>,
    state: Arc<BorrowState>,
    /// Cleared when ownership is transferred (wire move) or consumed.
    armed: bool,
}

impl<T: Decode + Encode> OwnedProxy<T> {
    fn register(proxy: Proxy<T>) -> Result<OwnedProxy<T>> {
        let state = state_for(proxy.key());
        {
            let mut inner = state.inner.lock().unwrap();
            if inner.owner_alive {
                return Err(Error::Ownership(format!(
                    "object {} already has an owner",
                    proxy.key()
                )));
            }
            inner.owner_alive = true;
        }
        Ok(OwnedProxy { proxy, state, armed: true })
    }

    /// Create from a store (see also `owned_proxy` on [`StoreOwnedExt`]).
    pub fn create(store: &Store, obj: &T) -> Result<OwnedProxy<T>> {
        let proxy = store.proxy(obj)?;
        Self::register(proxy)
    }

    pub fn key(&self) -> &str {
        self.proxy.key()
    }

    pub fn factory(&self) -> &Factory {
        self.proxy.factory()
    }

    /// Resolve the target (read access through the owner).
    pub fn resolve(&self) -> Result<&T> {
        self.proxy.resolve()
    }

    /// Immutable borrow. Fails if a mutable borrow is outstanding.
    pub fn borrow(&self) -> Result<RefProxy<T>> {
        let mut inner = self.state.inner.lock().unwrap();
        if inner.mut_out {
            return Err(Error::Ownership(format!(
                "cannot borrow {}: mutable borrow outstanding",
                self.key()
            )));
        }
        inner.refs += 1;
        Ok(RefProxy {
            proxy: self.proxy.clone(),
            state: self.state.clone(),
            armed: true,
        })
    }

    /// Mutable borrow. Fails if any borrow is outstanding.
    pub fn mut_borrow(&self) -> Result<RefMutProxy<T>> {
        let mut inner = self.state.inner.lock().unwrap();
        if inner.mut_out {
            return Err(Error::Ownership(format!(
                "cannot mut-borrow {}: mutable borrow outstanding",
                self.key()
            )));
        }
        if inner.refs > 0 {
            return Err(Error::Ownership(format!(
                "cannot mut-borrow {}: {} immutable borrow(s) outstanding",
                self.key(),
                inner.refs
            )));
        }
        inner.mut_out = true;
        Ok(RefMutProxy {
            proxy: self.proxy.clone(),
            state: self.state.clone(),
            armed: true,
        })
    }

    /// Deep-copy the object under a new key owned by the clone.
    pub fn clone_owned(&self, store: &Store) -> Result<OwnedProxy<T>> {
        let conn = self.proxy.factory().connector()?;
        let bytes = conn.get(self.key())?.ok_or_else(|| {
            Error::NotFound(self.key().to_string())
        })?;
        let key = store.new_key();
        store.connector().put(&key, bytes.to_vec())?;
        Self::register(store.proxy_from_key(&key))
    }

    /// Overwrite the stored object. Fails if any borrow is outstanding
    /// (same rule as mutating through an `&mut` while borrowed).
    pub fn update(&mut self, obj: &T) -> Result<()> {
        {
            let inner = self.state.inner.lock().unwrap();
            if inner.mut_out || inner.refs > 0 {
                return Err(Error::Ownership(format!(
                    "cannot update {}: borrows outstanding",
                    self.key()
                )));
            }
        }
        let conn = self.proxy.factory().connector()?;
        conn.put(self.key(), obj.to_bytes())?;
        self.proxy.factory().invalidate_cache();
        // Invalidate the proxy-local cache by swapping in a fresh proxy.
        self.proxy = Proxy::from_factory(self.proxy.factory().clone());
        Ok(())
    }

    /// Package ownership for transfer across a wire / engine boundary.
    /// `self` is disarmed; exactly one receiver may re-own via
    /// [`OwnedProxy::from_token`].
    pub fn transfer(mut self) -> OwnedToken<T> {
        self.armed = false;
        self.state.inner.lock().unwrap().owner_alive = false;
        OwnedToken {
            factory: self.proxy.factory().clone(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Re-own a transferred object.
    pub fn from_token(token: OwnedToken<T>) -> Result<OwnedProxy<T>> {
        Self::register(Proxy::from_factory(token.factory))
    }

    /// Explicit end-of-life with error reporting (unlike `Drop`, which can
    /// only record violations).
    pub fn end(mut self) -> Result<()> {
        self.armed = false;
        let outstanding = {
            let mut inner = self.state.inner.lock().unwrap();
            inner.owner_alive = false;
            if inner.refs > 0 || inner.mut_out {
                inner.evict_deferred = true;
                true
            } else {
                false
            }
        };
        if outstanding {
            return Err(Error::Ownership(format!(
                "owner of {} ended while borrows outstanding",
                self.key()
            )));
        }
        evict_key(self.proxy.factory());
        Ok(())
    }
}

impl<T: Decode + Encode> Drop for OwnedProxy<T> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let outstanding = {
            let mut inner = self.state.inner.lock().unwrap();
            inner.owner_alive = false;
            if inner.refs > 0 || inner.mut_out {
                inner.evict_deferred = true;
                true
            } else {
                false
            }
        };
        if outstanding {
            record_violation(&format!(
                "owner of {} dropped while borrows outstanding; eviction deferred",
                self.key()
            ));
        } else {
            evict_key(self.proxy.factory());
        }
    }
}

impl<T: Decode + Encode> std::fmt::Debug for OwnedProxy<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OwnedProxy").field("key", &self.key()).finish()
    }
}

/// Wire token representing transferred ownership.
pub struct OwnedToken<T> {
    factory: Factory,
    _marker: std::marker::PhantomData<fn() -> T>,
}

impl<T> Encode for OwnedToken<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.factory.encode(buf);
    }
}
impl<T> Decode for OwnedToken<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(OwnedToken {
            factory: Factory::decode(r)?,
            _marker: std::marker::PhantomData,
        })
    }
}

// --------------------------------------------------------------------------
// RefProxy / RefMutProxy
// --------------------------------------------------------------------------

/// Immutable borrow of a stored object.
pub struct RefProxy<T: Decode> {
    proxy: Proxy<T>,
    state: Arc<BorrowState>,
    armed: bool,
}

impl<T: Decode> RefProxy<T> {
    pub fn key(&self) -> &str {
        self.proxy.key()
    }

    /// Read the target.
    pub fn resolve(&self) -> Result<&T> {
        self.proxy.resolve()
    }

    /// Package for wire transfer; the receiving side reconstructs with
    /// [`RefProxy::from_wire`] and the borrow count carries over.
    pub fn to_wire(mut self) -> Vec<u8> {
        self.armed = false; // count stays held by the wire token
        self.proxy.factory().to_bytes()
    }

    /// Adopt a wire-transferred borrow (does NOT increment again).
    pub fn from_wire(bytes: &[u8]) -> Result<RefProxy<T>> {
        let factory = Factory::from_bytes(bytes)?;
        let state = state_for(&factory.key);
        Ok(RefProxy {
            proxy: Proxy::from_factory(factory),
            state,
            armed: true,
        })
    }
}

fn release_read(state: &Arc<BorrowState>, factory: &Factory) {
    let evict = {
        let mut inner = state.inner.lock().unwrap();
        inner.refs = inner.refs.saturating_sub(1);
        inner.evict_deferred && inner.refs == 0 && !inner.mut_out
    };
    if evict {
        evict_key(factory);
    }
}

impl<T: Decode> Drop for RefProxy<T> {
    fn drop(&mut self) {
        if self.armed {
            release_read(&self.state, self.proxy.factory());
        }
    }
}

impl<T: Decode> std::fmt::Debug for RefProxy<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RefProxy").field("key", &self.key()).finish()
    }
}

/// Mutable borrow: exclusive right to rewrite the global copy.
pub struct RefMutProxy<T: Decode + Encode> {
    proxy: Proxy<T>,
    state: Arc<BorrowState>,
    armed: bool,
}

impl<T: Decode + Encode> RefMutProxy<T> {
    pub fn key(&self) -> &str {
        self.proxy.key()
    }

    pub fn resolve(&self) -> Result<&T> {
        self.proxy.resolve()
    }

    /// Write a new value to the global copy (the borrow stays live, so
    /// repeated commits are allowed until drop).
    pub fn commit(&mut self, obj: &T) -> Result<()> {
        let conn = self.proxy.factory().connector()?;
        conn.put(self.key(), obj.to_bytes())?;
        self.proxy.factory().invalidate_cache();
        self.proxy = Proxy::from_factory(self.proxy.factory().clone());
        Ok(())
    }

    /// Wire transfer (exclusive right moves with the token).
    pub fn to_wire(mut self) -> Vec<u8> {
        self.armed = false;
        self.proxy.factory().to_bytes()
    }

    pub fn from_wire(bytes: &[u8]) -> Result<RefMutProxy<T>> {
        let factory = Factory::from_bytes(bytes)?;
        let state = state_for(&factory.key);
        Ok(RefMutProxy {
            proxy: Proxy::from_factory(factory),
            state,
            armed: true,
        })
    }
}

fn release_write(state: &Arc<BorrowState>, factory: &Factory) {
    let evict = {
        let mut inner = state.inner.lock().unwrap();
        inner.mut_out = false;
        inner.evict_deferred && inner.refs == 0
    };
    if evict {
        evict_key(factory);
    }
}

impl<T: Decode + Encode> Drop for RefMutProxy<T> {
    fn drop(&mut self) {
        if self.armed {
            release_write(&self.state, self.proxy.factory());
        }
    }
}

impl<T: Decode + Encode> std::fmt::Debug for RefMutProxy<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RefMutProxy").field("key", &self.key()).finish()
    }
}

// --------------------------------------------------------------------------
// Store extension + free functions mirroring Listing 3
// --------------------------------------------------------------------------

/// `Store::owned_proxy` (Listing 3).
pub trait StoreOwnedExt {
    fn owned_proxy<T: Decode + Encode>(&self, obj: &T) -> Result<OwnedProxy<T>>;
}

impl StoreOwnedExt for Store {
    fn owned_proxy<T: Decode + Encode>(&self, obj: &T) -> Result<OwnedProxy<T>> {
        OwnedProxy::create(self, obj)
    }
}

/// Adopt an unowned proxy into the ownership model (Listing 3's
/// `into_owned`). The proxy's target must still exist.
pub fn into_owned<T: Decode + Encode>(proxy: Proxy<T>) -> Result<OwnedProxy<T>> {
    let conn = proxy.factory().connector()?;
    if !conn.exists(proxy.key())? {
        return Err(Error::NotFound(proxy.key().to_string()));
    }
    OwnedProxy::register_pub(proxy)
}

impl<T: Decode + Encode> OwnedProxy<T> {
    fn register_pub(proxy: Proxy<T>) -> Result<OwnedProxy<T>> {
        Self::register(proxy)
    }
}

/// Listing 3's `borrow(...)`.
pub fn borrow<T: Decode + Encode>(owned: &OwnedProxy<T>) -> Result<RefProxy<T>> {
    owned.borrow()
}

/// Listing 3's `mut_borrow(...)`.
pub fn mut_borrow<T: Decode + Encode>(
    owned: &OwnedProxy<T>,
) -> Result<RefMutProxy<T>> {
    owned.mut_borrow()
}

/// Listing 3's `clone(...)`.
pub fn clone_owned<T: Decode + Encode>(
    owned: &OwnedProxy<T>,
    store: &Store,
) -> Result<OwnedProxy<T>> {
    owned.clone_owned(store)
}

/// Listing 3's `update(...)`.
pub fn update<T: Decode + Encode>(
    owned: &mut OwnedProxy<T>,
    obj: &T,
) -> Result<()> {
    owned.update(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> Store {
        Store::memory("own")
    }

    #[test]
    fn owner_drop_evicts() {
        let s = store();
        let key;
        {
            let owned = s.owned_proxy(&"v".to_string()).unwrap();
            key = owned.key().to_string();
            assert!(s.exists(&key).unwrap());
            assert_eq!(owned.resolve().unwrap(), "v");
        }
        assert!(!s.exists(&key).unwrap());
        assert_eq!(take_violations(), 0);
    }

    #[test]
    fn single_owner_enforced() {
        let s = store();
        let owned = s.owned_proxy(&1u32).unwrap();
        let plain: Proxy<u32> = s.proxy_from_key(owned.key());
        assert!(matches!(into_owned(plain), Err(Error::Ownership(_))));
    }

    #[test]
    fn many_readers_allowed() {
        let s = store();
        let owned = s.owned_proxy(&5u32).unwrap();
        let r1 = borrow(&owned).unwrap();
        let r2 = borrow(&owned).unwrap();
        assert_eq!(*r1.resolve().unwrap(), 5);
        assert_eq!(*r2.resolve().unwrap(), 5);
        // With readers out, no mut borrow and no update.
        assert!(mut_borrow(&owned).is_err());
        drop(r1);
        drop(r2);
        let mut owned = owned;
        update(&mut owned, &6u32).unwrap();
        assert_eq!(*owned.resolve().unwrap(), 6);
    }

    #[test]
    fn mut_borrow_exclusive() {
        let s = store();
        let owned = s.owned_proxy(&1u32).unwrap();
        let m = mut_borrow(&owned).unwrap();
        assert!(borrow(&owned).is_err());
        assert!(mut_borrow(&owned).is_err());
        drop(m);
        assert!(borrow(&owned).is_ok());
    }

    #[test]
    fn ref_mut_commit_visible_to_owner() {
        let s = store();
        let owned = s.owned_proxy(&10u32).unwrap();
        {
            let mut m = mut_borrow(&owned).unwrap();
            assert_eq!(*m.resolve().unwrap(), 10);
            m.commit(&20u32).unwrap();
        }
        // Owner sees the committed value (fresh resolve; owner hadn't
        // cached yet in this test).
        assert_eq!(*owned.resolve().unwrap(), 20);
    }

    #[test]
    fn owner_drop_with_live_borrow_defers_eviction() {
        let s = store();
        let owned = s.owned_proxy(&"x".to_string()).unwrap();
        let key = owned.key().to_string();
        let r = borrow(&owned).unwrap();
        drop(owned); // violation: reader still out
        assert_eq!(take_violations(), 1);
        assert!(s.exists(&key).unwrap(), "eviction must be deferred");
        assert_eq!(r.resolve().unwrap(), "x");
        drop(r);
        assert!(!s.exists(&key).unwrap(), "last borrow evicts");
    }

    #[test]
    fn end_reports_violation_as_error() {
        let s = store();
        let owned = s.owned_proxy(&1u8).unwrap();
        let _r = borrow(&owned).unwrap();
        assert!(matches!(owned.end(), Err(Error::Ownership(_))));
    }

    #[test]
    fn clone_owned_is_independent() {
        let s = store();
        let a = s.owned_proxy(&7u32).unwrap();
        let b = clone_owned(&a, &s).unwrap();
        assert_ne!(a.key(), b.key());
        let (ka, kb) = (a.key().to_string(), b.key().to_string());
        drop(a);
        assert!(!s.exists(&ka).unwrap());
        assert!(s.exists(&kb).unwrap());
        assert_eq!(*b.resolve().unwrap(), 7);
    }

    #[test]
    fn transfer_moves_ownership() {
        let s = store();
        let owned = s.owned_proxy(&3u32).unwrap();
        let key = owned.key().to_string();
        let token = owned.transfer();
        assert!(s.exists(&key).unwrap(), "transfer must not evict");
        let wire = token.to_bytes();
        let token2: OwnedToken<u32> = OwnedToken::from_bytes(&wire).unwrap();
        let owned2 = OwnedProxy::from_token(token2).unwrap();
        assert_eq!(*owned2.resolve().unwrap(), 3);
        drop(owned2);
        assert!(!s.exists(&key).unwrap());
    }

    #[test]
    fn ref_wire_transfer_keeps_count() {
        let s = store();
        let owned = s.owned_proxy(&2u32).unwrap();
        let wire = borrow(&owned).unwrap().to_wire();
        // Count is still held by the wire token: mut borrow fails.
        assert!(mut_borrow(&owned).is_err());
        let r = RefProxy::<u32>::from_wire(&wire).unwrap();
        assert_eq!(*r.resolve().unwrap(), 2);
        drop(r);
        assert!(mut_borrow(&owned).is_ok());
    }

    #[test]
    fn into_owned_requires_live_target() {
        let s = store();
        let p: Proxy<u32> = s.proxy(&1u32).unwrap();
        s.evict(p.key()).unwrap();
        assert!(matches!(into_owned(p), Err(Error::NotFound(_))));
    }
}
