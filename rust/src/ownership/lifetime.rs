//! Lifetimes (Sec IV-C, Listing 4): coarser-than-task scopes that clean up
//! every object associated with them when they end.
//!
//! Three built-ins, matching the paper: [`ContextLifetime`] (RAII scope),
//! [`LeaseLifetime`] (time-based lease with extension, after Gray &
//! Cheriton), and [`StaticLifetime`] (process-long). All share the
//! [`Lifetime`] trait so `Store::proxy` integration and user extensions
//! are uniform.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::codec::Encode;
use crate::error::Result;
use crate::proxy::{Factory, Proxy};
use crate::store::Store;

/// A scope that owns stored objects and evicts them when it ends.
pub trait Lifetime: Send + Sync {
    /// Associate a stored object with this lifetime.
    fn attach(&self, factory: Factory);

    /// Has the lifetime ended (objects cleaned up)?
    fn done(&self) -> bool;

    /// End the lifetime now, evicting all associated objects.
    fn close(&self);
}

/// Extension for proxy creation with a lifetime attached.
pub trait StoreLifetimeExt {
    /// `Store.proxy(obj, lifetime=...)` from Listing 4.
    fn proxy_with_lifetime<T: Encode>(
        &self,
        obj: &T,
        lifetime: &dyn Lifetime,
    ) -> Result<Proxy<T>>;
}

impl StoreLifetimeExt for Store {
    fn proxy_with_lifetime<T: Encode>(
        &self,
        obj: &T,
        lifetime: &dyn Lifetime,
    ) -> Result<Proxy<T>> {
        let p = self.proxy(obj)?;
        lifetime.attach(p.factory().clone());
        Ok(p)
    }
}

#[derive(Default)]
struct Attached {
    factories: Vec<Factory>,
    closed: bool,
}

impl Attached {
    fn close_now(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        // Group keys by channel so each mediated channel sees ONE batched
        // eviction (native MDEL over the wire, parallel per-shard sweep on
        // the fabric) instead of a round trip per attached object.
        let mut groups: HashMap<Vec<u8>, (Factory, Vec<String>)> =
            HashMap::new();
        for f in self.factories.drain(..) {
            f.invalidate_cache();
            let desc = f.desc.to_bytes();
            let keys = &mut groups
                .entry(desc)
                .or_insert_with(|| (f.clone(), Vec::new()))
                .1;
            keys.push(f.key);
        }
        for (f, keys) in groups.into_values() {
            if let Ok(conn) = f.connector() {
                let _ = conn.delete_many(&keys);
            }
        }
    }
}

// --------------------------------------------------------------------------

/// RAII scope: evicts attached objects when dropped (or on `close`).
#[derive(Default)]
pub struct ContextLifetime {
    attached: Mutex<Attached>,
}

impl ContextLifetime {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Lifetime for ContextLifetime {
    fn attach(&self, factory: Factory) {
        let mut a = self.attached.lock().unwrap();
        assert!(!a.closed, "attach on closed lifetime");
        a.factories.push(factory);
    }

    fn done(&self) -> bool {
        self.attached.lock().unwrap().closed
    }

    fn close(&self) {
        self.attached.lock().unwrap().close_now();
    }
}

impl Drop for ContextLifetime {
    fn drop(&mut self) {
        self.close();
    }
}

// --------------------------------------------------------------------------

/// Time-leased lifetime: objects are evicted when the lease expires and is
/// not extended. A monitor thread enforces expiry without client polling.
pub struct LeaseLifetime {
    inner: Arc<LeaseInner>,
}

struct LeaseInner {
    attached: Mutex<Attached>,
    expiry: Mutex<Instant>,
}

impl LeaseLifetime {
    /// Lease expiring `ttl` from now.
    pub fn new(ttl: Duration) -> LeaseLifetime {
        let inner = Arc::new(LeaseInner {
            attached: Mutex::new(Attached::default()),
            expiry: Mutex::new(Instant::now() + ttl),
        });
        let monitor = Arc::downgrade(&inner);
        std::thread::Builder::new()
            .name("lease-monitor".into())
            .spawn(move || loop {
                let Some(inner) = monitor.upgrade() else { return };
                let expiry = *inner.expiry.lock().unwrap();
                let now = Instant::now();
                if now >= expiry {
                    inner.attached.lock().unwrap().close_now();
                    return;
                }
                let wait = (expiry - now).min(Duration::from_millis(50));
                drop(inner);
                std::thread::sleep(wait);
            })
            .expect("spawn lease-monitor");
        LeaseLifetime { inner }
    }

    /// Extend the lease by `extra` (from the current expiry; Listing 4's
    /// `lease.extend(5)`).
    pub fn extend(&self, extra: Duration) {
        let mut expiry = self.inner.expiry.lock().unwrap();
        *expiry += extra;
    }

    /// Remaining time on the lease.
    pub fn remaining(&self) -> Duration {
        self.inner
            .expiry
            .lock()
            .unwrap()
            .saturating_duration_since(Instant::now())
    }
}

impl Lifetime for LeaseLifetime {
    fn attach(&self, factory: Factory) {
        let mut a = self.inner.attached.lock().unwrap();
        assert!(!a.closed, "attach on expired lease");
        a.factories.push(factory);
    }

    fn done(&self) -> bool {
        self.inner.attached.lock().unwrap().closed
    }

    fn close(&self) {
        self.inner.attached.lock().unwrap().close_now();
    }
}

// --------------------------------------------------------------------------

/// Process-long lifetime: objects persist until explicit global close.
pub struct StaticLifetime;

fn static_attached() -> &'static Mutex<Attached> {
    static A: std::sync::OnceLock<Mutex<Attached>> = std::sync::OnceLock::new();
    A.get_or_init(Default::default)
}

impl StaticLifetime {
    /// Evict everything attached to the static lifetime (e.g. at shutdown).
    pub fn close_all() {
        let mut a = static_attached().lock().unwrap();
        a.close_now();
        a.closed = false; // static lifetime is reusable after a sweep
    }
}

impl Lifetime for StaticLifetime {
    fn attach(&self, factory: Factory) {
        static_attached().lock().unwrap().factories.push(factory);
    }

    fn done(&self) -> bool {
        false
    }

    fn close(&self) {
        StaticLifetime::close_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_lifetime_evicts_on_drop() {
        let s = Store::memory("lt");
        let key;
        {
            let lt = ContextLifetime::new();
            let p = s.proxy_with_lifetime(&"v".to_string(), &lt).unwrap();
            key = p.key().to_string();
            assert!(s.exists(&key).unwrap());
            assert!(!lt.done());
        }
        assert!(!s.exists(&key).unwrap());
    }

    #[test]
    fn context_close_is_idempotent() {
        let s = Store::memory("lt");
        let lt = ContextLifetime::new();
        let p = s.proxy_with_lifetime(&1u8, &lt).unwrap();
        lt.close();
        lt.close();
        assert!(lt.done());
        assert!(!s.exists(p.key()).unwrap());
    }

    #[test]
    fn lease_expires_and_cleans_up() {
        let s = Store::memory("lt");
        let lease = LeaseLifetime::new(Duration::from_millis(60));
        let p = s.proxy_with_lifetime(&"x".to_string(), &lease).unwrap();
        assert!(s.exists(p.key()).unwrap());
        std::thread::sleep(Duration::from_millis(160));
        assert!(lease.done());
        assert!(!s.exists(p.key()).unwrap());
    }

    #[test]
    fn lease_extension_delays_expiry() {
        // Listing 4's scenario: 10-unit lease extended by 5.
        let s = Store::memory("lt");
        let lease = LeaseLifetime::new(Duration::from_millis(80));
        let p = s.proxy_with_lifetime(&1u32, &lease).unwrap();
        lease.extend(Duration::from_millis(120));
        std::thread::sleep(Duration::from_millis(120));
        assert!(!lease.done(), "extension must delay expiry");
        assert!(s.exists(p.key()).unwrap());
        std::thread::sleep(Duration::from_millis(150));
        assert!(lease.done());
        assert!(!s.exists(p.key()).unwrap());
    }

    #[test]
    fn static_lifetime_survives_until_sweep() {
        let s = Store::memory("lt");
        let p = s
            .proxy_with_lifetime(&"static".to_string(), &StaticLifetime)
            .unwrap();
        assert!(s.exists(p.key()).unwrap());
        StaticLifetime::close_all();
        assert!(!s.exists(p.key()).unwrap());
    }
}
