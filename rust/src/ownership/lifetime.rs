//! Lifetimes (Sec IV-C, Listing 4): coarser-than-task scopes that clean up
//! every object associated with them when they end.
//!
//! Three built-ins, matching the paper: [`ContextLifetime`] (RAII scope),
//! [`LeaseLifetime`] (time-based lease with extension, after Gray &
//! Cheriton), and [`StaticLifetime`] (process-long). All share the
//! [`Lifetime`] trait so `Store::proxy` integration and user extensions
//! are uniform.
//!
//! The release path is event-driven end to end: closing a lifetime
//! batches its keys per channel and fans the eviction sweeps out as
//! submitted ops ([`fan_out_ops`]) — channels settle concurrently through
//! completion handles instead of serial round trips — and the lease
//! monitor parks on a condvar until the exact expiry instant
//! ([`LeaseLifetime::extend`] wakes it to recompute) rather than ticking
//! a poll loop.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::codec::Encode;
use crate::error::Result;
use crate::ops::reactor::fan_out_ops;
use crate::ops::Op;
use crate::proxy::{Factory, Proxy};
use crate::store::Store;

/// A scope that owns stored objects and evicts them when it ends.
pub trait Lifetime: Send + Sync {
    /// Associate a stored object with this lifetime.
    fn attach(&self, factory: Factory);

    /// Has the lifetime ended (objects cleaned up)?
    fn done(&self) -> bool;

    /// End the lifetime now, evicting all associated objects.
    fn close(&self);
}

/// Extension for proxy creation with a lifetime attached.
pub trait StoreLifetimeExt {
    /// `Store.proxy(obj, lifetime=...)` from Listing 4.
    fn proxy_with_lifetime<T: Encode>(
        &self,
        obj: &T,
        lifetime: &dyn Lifetime,
    ) -> Result<Proxy<T>>;
}

impl StoreLifetimeExt for Store {
    fn proxy_with_lifetime<T: Encode>(
        &self,
        obj: &T,
        lifetime: &dyn Lifetime,
    ) -> Result<Proxy<T>> {
        let p = self.proxy(obj)?;
        lifetime.attach(p.factory().clone());
        Ok(p)
    }
}

#[derive(Default)]
struct Attached {
    factories: Vec<Factory>,
    closed: bool,
}

impl Attached {
    fn close_now(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        // Group keys by channel so each mediated channel sees ONE batched
        // eviction (native MDEL over the wire, parallel per-shard sweep on
        // the fabric) instead of a round trip per attached object.
        let mut groups: HashMap<Vec<u8>, (Factory, Vec<String>)> =
            HashMap::new();
        for f in self.factories.drain(..) {
            f.invalidate_cache();
            let desc = f.desc.to_bytes();
            let keys = &mut groups
                .entry(desc)
                .or_insert_with(|| (f.clone(), Vec::new()))
                .1;
            keys.push(f.key);
        }
        // Fan the per-channel sweeps out as submitted ops: pipelined
        // channels put their MDEL on the wire, the rest ride the shared
        // reactor — a multi-channel release settles in the slowest
        // channel's time, not the sum. Best-effort, like the serial
        // sweeps this replaces.
        let ops: Vec<_> = groups
            .into_values()
            .enumerate()
            .filter_map(|(i, (f, keys))| {
                f.connector().ok().map(|conn| (i, conn, Op::DeleteMany { keys }))
            })
            .collect();
        for (_, result) in fan_out_ops(ops) {
            let _ = result;
        }
    }
}

// --------------------------------------------------------------------------

/// RAII scope: evicts attached objects when dropped (or on `close`).
#[derive(Default)]
pub struct ContextLifetime {
    attached: Mutex<Attached>,
}

impl ContextLifetime {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Lifetime for ContextLifetime {
    fn attach(&self, factory: Factory) {
        let mut a = self.attached.lock().unwrap();
        assert!(!a.closed, "attach on closed lifetime");
        a.factories.push(factory);
    }

    fn done(&self) -> bool {
        self.attached.lock().unwrap().closed
    }

    fn close(&self) {
        self.attached.lock().unwrap().close_now();
    }
}

impl Drop for ContextLifetime {
    fn drop(&mut self) {
        self.close();
    }
}

// --------------------------------------------------------------------------

/// Time-leased lifetime: objects are evicted when the lease expires and is
/// not extended. A monitor thread enforces expiry without client polling:
/// it parks on a condvar until the exact expiry instant, and
/// [`LeaseLifetime::extend`] wakes it to recompute — no periodic tick.
pub struct LeaseLifetime {
    inner: Arc<LeaseInner>,
}

struct LeaseInner {
    attached: Mutex<Attached>,
    expiry: Mutex<Instant>,
    /// Wakes the monitor when the expiry moves — or the handle dropped.
    extended: Condvar,
    /// Set when the `LeaseLifetime` handle is dropped: the monitor exits
    /// promptly instead of holding the lease state for the rest of the
    /// TTL (pre-watch-plane behaviour, event-driven instead of a 50ms
    /// liveness poll).
    handle_dropped: std::sync::atomic::AtomicBool,
}

impl LeaseLifetime {
    /// Lease expiring `ttl` from now.
    pub fn new(ttl: Duration) -> LeaseLifetime {
        let inner = Arc::new(LeaseInner {
            attached: Mutex::new(Attached::default()),
            expiry: Mutex::new(Instant::now() + ttl),
            extended: Condvar::new(),
            handle_dropped: std::sync::atomic::AtomicBool::new(false),
        });
        let monitor = Arc::downgrade(&inner);
        std::thread::Builder::new()
            .name("lease-monitor".into())
            .spawn(move || loop {
                let Some(inner) = monitor.upgrade() else { return };
                let expiry = inner.expiry.lock().unwrap();
                // Checked under the condvar's mutex (drop sets it under
                // the same lock), so the wakeup cannot be lost between
                // this check and the park below.
                if inner
                    .handle_dropped
                    .load(std::sync::atomic::Ordering::SeqCst)
                {
                    return; // abandoned lease: release state promptly
                }
                let now = Instant::now();
                if now >= *expiry {
                    drop(expiry);
                    inner.attached.lock().unwrap().close_now();
                    return;
                }
                // Park until expiry; extend() (or the handle's drop)
                // notifies and the loop recomputes.
                let wait = *expiry - now;
                let (guard, _) =
                    inner.extended.wait_timeout(expiry, wait).unwrap();
                drop(guard);
            })
            .expect("spawn lease-monitor");
        LeaseLifetime { inner }
    }

    /// Extend the lease by `extra` (from the current expiry; Listing 4's
    /// `lease.extend(5)`). Wakes the parked monitor so it re-arms on the
    /// new deadline.
    pub fn extend(&self, extra: Duration) {
        let mut expiry = self.inner.expiry.lock().unwrap();
        *expiry += extra;
        drop(expiry);
        self.inner.extended.notify_all();
    }

    /// Remaining time on the lease.
    pub fn remaining(&self) -> Duration {
        self.inner
            .expiry
            .lock()
            .unwrap()
            .saturating_duration_since(Instant::now())
    }
}

impl Drop for LeaseLifetime {
    /// Wake the monitor so a dropped lease releases its thread and state
    /// promptly instead of parking out the remaining TTL. Matches the
    /// pre-existing semantics: an abandoned (never-expired) lease does
    /// not evict — cleanup belongs to expiry.
    fn drop(&mut self) {
        // Flag + notify under the condvar's mutex: the monitor checks the
        // flag under the same lock before parking, so this wakeup cannot
        // slip between its check and its park.
        let _guard = self.inner.expiry.lock().unwrap();
        self.inner
            .handle_dropped
            .store(true, std::sync::atomic::Ordering::SeqCst);
        self.inner.extended.notify_all();
    }
}

impl Lifetime for LeaseLifetime {
    fn attach(&self, factory: Factory) {
        let mut a = self.inner.attached.lock().unwrap();
        assert!(!a.closed, "attach on expired lease");
        a.factories.push(factory);
    }

    fn done(&self) -> bool {
        self.inner.attached.lock().unwrap().closed
    }

    fn close(&self) {
        self.inner.attached.lock().unwrap().close_now();
    }
}

// --------------------------------------------------------------------------

/// Process-long lifetime: objects persist until explicit global close.
pub struct StaticLifetime;

fn static_attached() -> &'static Mutex<Attached> {
    static A: std::sync::OnceLock<Mutex<Attached>> = std::sync::OnceLock::new();
    A.get_or_init(Default::default)
}

impl StaticLifetime {
    /// Evict everything attached to the static lifetime (e.g. at shutdown).
    pub fn close_all() {
        let mut a = static_attached().lock().unwrap();
        a.close_now();
        a.closed = false; // static lifetime is reusable after a sweep
    }
}

impl Lifetime for StaticLifetime {
    fn attach(&self, factory: Factory) {
        static_attached().lock().unwrap().factories.push(factory);
    }

    fn done(&self) -> bool {
        false
    }

    fn close(&self) {
        StaticLifetime::close_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_lifetime_evicts_on_drop() {
        let s = Store::memory("lt");
        let key;
        {
            let lt = ContextLifetime::new();
            let p = s.proxy_with_lifetime(&"v".to_string(), &lt).unwrap();
            key = p.key().to_string();
            assert!(s.exists(&key).unwrap());
            assert!(!lt.done());
        }
        assert!(!s.exists(&key).unwrap());
    }

    #[test]
    fn context_close_is_idempotent() {
        let s = Store::memory("lt");
        let lt = ContextLifetime::new();
        let p = s.proxy_with_lifetime(&1u8, &lt).unwrap();
        lt.close();
        lt.close();
        assert!(lt.done());
        assert!(!s.exists(p.key()).unwrap());
    }

    #[test]
    fn lease_expires_and_cleans_up() {
        let s = Store::memory("lt");
        let lease = LeaseLifetime::new(Duration::from_millis(60));
        let p = s.proxy_with_lifetime(&"x".to_string(), &lease).unwrap();
        assert!(s.exists(p.key()).unwrap());
        std::thread::sleep(Duration::from_millis(160));
        assert!(lease.done());
        assert!(!s.exists(p.key()).unwrap());
    }

    #[test]
    fn lease_extension_delays_expiry() {
        // Listing 4's scenario: 10-unit lease extended by 5.
        let s = Store::memory("lt");
        let lease = LeaseLifetime::new(Duration::from_millis(80));
        let p = s.proxy_with_lifetime(&1u32, &lease).unwrap();
        lease.extend(Duration::from_millis(120));
        std::thread::sleep(Duration::from_millis(120));
        assert!(!lease.done(), "extension must delay expiry");
        assert!(s.exists(p.key()).unwrap());
        std::thread::sleep(Duration::from_millis(150));
        assert!(lease.done());
        assert!(!s.exists(p.key()).unwrap());
    }

    #[test]
    fn static_lifetime_survives_until_sweep() {
        let s = Store::memory("lt");
        let p = s
            .proxy_with_lifetime(&"static".to_string(), &StaticLifetime)
            .unwrap();
        assert!(s.exists(p.key()).unwrap());
        StaticLifetime::close_all();
        assert!(!s.exists(p.key()).unwrap());
    }
}
