//! Shared fixed-size reactor pool: the threads that drive submitted ops.
//!
//! Every fan-out in the repo used to pay a `thread::scope` spawn per shard
//! per call — fine for bulk transfers, ruinous for small batched ops where
//! the spawn costs more than the op. This pool replaces all of those
//! copies: a process-wide fixed set of workers drains a queue of
//! short-lived jobs, and [`fan_out`] / [`fan_out_ops`] are the shared
//! fan-out utilities the shard router, the elastic migration daemon, and
//! the broker producer route through. (The broker *consumer* sweep stays
//! on scoped threads on purpose: it long-polls, and parked jobs are
//! exactly what this pool must not host.)
//!
//! Scheduling rules (what makes the pool deadlock-free):
//!
//! * jobs must be *short-lived and bounded* — one batched op, one
//!   migration batch. Nothing that parks indefinitely belongs here;
//! * a fan-out runs its first job on the caller and collects the rest
//!   with a *helping* join: while its sub-jobs are pending it drains
//!   other queued tasks, so a worker waiting on its own fan-out still
//!   drives the pool — nested fabrics (elastic over sharded over flaky)
//!   keep their parallelism and can never deadlock on their own workers;
//! * the queue has a high-water mark: past it, submissions run inline on
//!   the submitter (backpressure — fast producers degrade to blocking
//!   behaviour instead of queueing unbounded payloads);
//! * channels whose [`submit`](crate::store::Connector::submit) is
//!   natively nonblocking (the pipelined TCP client) bypass the pool
//!   entirely in [`fan_out_ops`] — their in-flight ops live on the wire,
//!   not on a parked worker.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::metrics::telemetry;
use crate::store::Connector;

use super::{pending, Op, OpResult, Pending};

/// Cached registry handles for pool observability: jobs enqueued, queue
/// depth (its high-water mark is the congestion signal), and submissions
/// that degraded to inline runs under backpressure.
struct ReactorMetrics {
    jobs: std::sync::Arc<telemetry::Counter>,
    queue_depth: std::sync::Arc<telemetry::Gauge>,
    inline_runs: std::sync::Arc<telemetry::Counter>,
}

fn reactor_metrics() -> &'static ReactorMetrics {
    static M: OnceLock<ReactorMetrics> = OnceLock::new();
    M.get_or_init(|| ReactorMetrics {
        jobs: telemetry::counter("reactor.jobs"),
        queue_depth: telemetry::gauge("reactor.queue_depth"),
        inline_runs: telemetry::counter("reactor.inline_runs"),
    })
}

/// A unit of pool work.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Queue high-water mark: above this, submissions run inline on the
/// caller instead of enqueueing. That is the pool's backpressure — a
/// producer outrunning the workers degrades to the old blocking behaviour
/// (self-throttling) instead of growing an unbounded queue of payloads.
const MAX_QUEUED: usize = 1024;

/// A typed fan-out job: runs on a worker (or inline), produces a result.
pub type Job<T> = Box<dyn FnOnce() -> Result<T> + Send + 'static>;

/// The shared worker pool. One per process ([`global`]).
pub struct Reactor {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    workers: usize,
}

thread_local! {
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
    /// How many helped tasks are live on this thread's stack (the helping
    /// join runs queued tasks while it waits, which can nest).
    static HELP_DEPTH: Cell<usize> = const { Cell::new(0) };
}

/// Beyond this helping depth a fan-out runs its jobs inline instead of
/// queueing them: a stack-growth safety valve for pathological nesting
/// (deep help-recursion under a packed queue), not a hot path.
const MAX_HELP_DEPTH: usize = 32;

fn pool_size() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(4, 16)
}

/// The process-wide reactor; workers start lazily on first use.
pub fn global() -> &'static Reactor {
    static POOL: OnceLock<Reactor> = OnceLock::new();
    static STARTED: std::sync::Once = std::sync::Once::new();
    let reactor = POOL.get_or_init(|| Reactor {
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        workers: pool_size(),
    });
    STARTED.call_once(|| {
        for i in 0..reactor.workers {
            std::thread::Builder::new()
                .name(format!("ops-reactor-{i}"))
                .spawn(move || worker_loop(reactor))
                .expect("spawn reactor worker");
        }
    });
    reactor
}

fn worker_loop(reactor: &'static Reactor) {
    IN_WORKER.with(|f| f.set(true));
    loop {
        let task = {
            let mut q = reactor.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = reactor.cv.wait(q).unwrap();
            }
        };
        task();
    }
}

fn run_caught<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
        .unwrap_or_else(|_| Err(Error::Connector("reactor job panicked".into())))
}

impl Reactor {
    /// Number of worker threads.
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    /// Whether the calling thread is a reactor worker (used to run nested
    /// fan-outs inline instead of deadlocking on the pool).
    pub fn in_worker() -> bool {
        IN_WORKER.with(|f| f.get())
    }

    /// Run a job on the pool and hand back its completion. Called from a
    /// worker — or with the queue past its high-water mark — the job runs
    /// inline and the handle is already complete (backpressure: the
    /// caller pays instead of the queue growing without bound).
    pub fn spawn<T, F>(&self, f: F) -> Pending<T>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        if Self::in_worker() || self.saturated() {
            reactor_metrics().inline_runs.incr();
            return Pending::ready(run_caught(f));
        }
        let (completer, handle) = pending();
        self.enqueue(Box::new(move || completer.complete(run_caught(f))));
        handle
    }

    /// Run a job on the pool with no completion handle (the migration
    /// daemon's batch jobs). Never runs inline from a worker — a job can
    /// re-enqueue itself (bounded retries) without recursing — but a
    /// saturated queue makes the *submitting* caller run it inline, the
    /// same backpressure as [`Reactor::spawn`].
    pub fn spawn_detached<F: FnOnce() + Send + 'static>(&self, f: F) {
        if !Self::in_worker() && self.saturated() {
            reactor_metrics().inline_runs.incr();
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            return;
        }
        self.enqueue(Box::new(move || {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        }));
    }

    fn saturated(&self) -> bool {
        self.queue.lock().unwrap().len() >= MAX_QUEUED
    }

    fn enqueue(&self, task: Task) {
        let depth = {
            let mut q = self.queue.lock().unwrap();
            q.push_back(task);
            q.len()
        };
        let m = reactor_metrics();
        m.jobs.incr();
        m.queue_depth.set(depth as i64);
        self.cv.notify_one();
    }

    /// Queue a fan-out sub-job. Unlike [`Reactor::spawn`] this enqueues
    /// even from a worker — [`join_helping`](Reactor::join_helping) is
    /// what keeps that deadlock-free — so nested fan-outs keep their
    /// parallelism. Saturation still runs inline (backpressure).
    fn spawn_for_join<T, F>(&self, f: F) -> Pending<T>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        if self.saturated() {
            reactor_metrics().inline_runs.incr();
            return Pending::ready(run_caught(f));
        }
        let (completer, handle) = pending();
        self.enqueue(Box::new(move || completer.complete(run_caught(f))));
        handle
    }

    /// Wait for a fan-out sub-job while *helping*: drain queued tasks
    /// until the handle completes. A worker blocked on its own sub-jobs
    /// keeps executing pool work (possibly those very sub-jobs), so the
    /// pool cannot deadlock on nested fan-outs. Once the queue is
    /// observed empty the sub-job is running (or done) on some thread and
    /// a plain blocking wait is safe.
    fn join_helping<T>(&self, handle: &Pending<T>) -> Result<T> {
        loop {
            if let Some(v) = handle.try_take()? {
                return Ok(v);
            }
            let task = self.queue.lock().unwrap().pop_front();
            match task {
                Some(task) => {
                    // Tasks never unwind (every job body catches), so the
                    // depth always unwinds with the call.
                    HELP_DEPTH.with(|d| d.set(d.get() + 1));
                    task();
                    HELP_DEPTH.with(|d| d.set(d.get() - 1));
                }
                None => return handle.wait(),
            }
        }
    }
}

/// Run a labelled set of jobs concurrently on the shared pool and collect
/// every result. The caller always executes the first job itself (a
/// saturated pool slows the rest, never blocks them) and collects the
/// rest with a helping join, so fan-outs nest — from user threads or from
/// pool workers — without losing parallelism or risking deadlock. Labels
/// never cross threads, so they carry whatever the call site needs to
/// reassemble results; result order is not input order — match by label.
pub fn fan_out<L, T: Send + 'static>(
    jobs: Vec<(L, Job<T>)>,
) -> Vec<(L, Result<T>)> {
    if jobs.is_empty() {
        return Vec::new();
    }
    if HELP_DEPTH.with(|d| d.get()) >= MAX_HELP_DEPTH {
        return jobs
            .into_iter()
            .map(|(label, job)| (label, run_caught(job)))
            .collect();
    }
    let reactor = global();
    let mut jobs = jobs;
    let (first_label, first_job) = jobs.remove(0);
    let handles: Vec<(L, Pending<T>)> = jobs
        .into_iter()
        .map(|(label, job)| (label, reactor.spawn_for_join(job)))
        .collect();
    let mut out = Vec::with_capacity(handles.len() + 1);
    out.push((first_label, run_caught(first_job)));
    for (label, handle) in handles {
        out.push((label, reactor.join_helping(&handle)));
    }
    out
}

/// Fan a set of connector ops out concurrently: the shared-pool twin of a
/// batched multi-channel round. Channels with a nonblocking native
/// [`submit`](crate::store::Connector::submit) go straight onto their
/// pipelined wire (no pool thread consumed); blocking bridges become pool
/// jobs. `Watch` ops always go direct — every channel arms them through
/// its watch plane, and an indefinitely-parked watch must never occupy a
/// pool worker (the pool's contract is short-lived jobs only). Results
/// are labelled like [`fan_out`].
pub fn fan_out_ops(
    ops: Vec<(usize, std::sync::Arc<dyn Connector>, Op)>,
) -> Vec<(usize, Result<OpResult>)> {
    let mut direct: Vec<(usize, Pending<OpResult>)> = Vec::new();
    let mut pooled: Vec<(usize, Job<OpResult>)> = Vec::new();
    for (label, conn, op) in ops {
        if conn.submits_nonblocking() || matches!(op, Op::Watch { .. }) {
            direct.push((label, conn.submit(op)));
        } else {
            pooled.push((label, Box::new(move || conn.submit(op).wait())));
        }
    }
    let mut out = fan_out(pooled);
    for (label, handle) in direct {
        out.push((label, handle.wait()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    #[test]
    fn spawn_runs_job_off_thread() {
        let h = global().spawn(|| {
            let on_worker = std::thread::current()
                .name()
                .map(|n| n.starts_with("ops-reactor-"));
            Ok(on_worker)
        });
        assert_eq!(h.wait().unwrap(), Some(true));
    }

    #[test]
    fn spawn_detached_runs() {
        let hits = Arc::new(AtomicUsize::new(0));
        let h2 = hits.clone();
        global().spawn_detached(move || {
            h2.fetch_add(1, Ordering::SeqCst);
        });
        let t0 = Instant::now();
        while hits.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "detached job lost");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn fan_out_collects_all_labels() {
        let jobs: Vec<(usize, Job<usize>)> = (0..8)
            .map(|i| (i, Box::new(move || Ok(i * i)) as Job<usize>))
            .collect();
        let mut results = fan_out(jobs);
        results.sort_by_key(|(label, _)| *label);
        for (label, res) in results {
            assert_eq!(res.unwrap(), label * label);
        }
    }

    #[test]
    fn fan_out_overlaps_slow_jobs() {
        // 4 jobs x 80ms sequential = 320ms. The bound leaves room for a
        // full extra wave of pool contention from concurrently running
        // tests (the pool is process-global) while still proving overlap.
        let jobs: Vec<(usize, Job<()>)> = (0..4)
            .map(|i| {
                (
                    i,
                    Box::new(move || {
                        std::thread::sleep(Duration::from_millis(80));
                        Ok(())
                    }) as Job<()>,
                )
            })
            .collect();
        let t0 = Instant::now();
        let results = fan_out(jobs);
        let elapsed = t0.elapsed();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|(_, r)| r.is_ok()));
        assert!(
            elapsed < Duration::from_millis(240),
            "fan-out did not overlap: {elapsed:?}"
        );
    }

    #[test]
    fn fan_out_reports_panics_as_errors() {
        let jobs: Vec<(usize, Job<u8>)> = vec![
            (0, Box::new(|| Ok(1))),
            (1, Box::new(|| panic!("injected"))),
        ];
        let mut results = fan_out(jobs);
        results.sort_by_key(|(label, _)| *label);
        assert_eq!(results[0].1.as_ref().unwrap(), &1);
        assert!(results[1].1.is_err());
    }

    #[test]
    fn nested_fan_out_completes_from_worker() {
        // A fan-out from inside a pool worker must finish even though its
        // sub-jobs land on the same pool: the helping join drives them.
        let h = global().spawn(|| {
            assert!(Reactor::in_worker());
            let jobs: Vec<(usize, Job<usize>)> = (0..4)
                .map(|i| (i, Box::new(move || Ok(i + 1)) as Job<usize>))
                .collect();
            let total: usize = fan_out(jobs)
                .into_iter()
                .map(|(_, r)| r.unwrap())
                .sum();
            Ok(total)
        });
        assert_eq!(h.wait().unwrap(), 10);
    }

    #[test]
    fn saturating_nested_fan_outs_make_progress() {
        // More simultaneous fan-outs than workers, each nested one level:
        // the helping join must drive everything to completion without
        // deadlocking the fixed-size pool.
        let outer: Vec<(usize, Job<usize>)> = (0..16)
            .map(|i| {
                (
                    i,
                    Box::new(move || {
                        let inner: Vec<(usize, Job<usize>)> = (0..4)
                            .map(|j| {
                                (j, Box::new(move || Ok(i + j)) as Job<usize>)
                            })
                            .collect();
                        let mut acc = 0;
                        for (_, r) in fan_out(inner) {
                            acc += r?;
                        }
                        Ok(acc)
                    }) as Job<usize>,
                )
            })
            .collect();
        let results = fan_out(outer);
        assert_eq!(results.len(), 16);
        for (i, res) in results {
            assert_eq!(res.unwrap(), 4 * i + 6);
        }
    }

    #[test]
    fn fan_out_ops_mixes_channels() {
        let conns: Vec<Arc<dyn Connector>> =
            (0..3).map(|_| crate::store::MemoryConnector::new()).collect();
        for (i, c) in conns.iter().enumerate() {
            c.put("k", vec![i as u8]).unwrap();
        }
        let ops = conns
            .iter()
            .enumerate()
            .map(|(i, c)| (i, c.clone(), Op::Get { key: "k".into() }))
            .collect();
        let mut results = fan_out_ops(ops);
        results.sort_by_key(|(label, _)| *label);
        for (i, (_, res)) in results.into_iter().enumerate() {
            assert_eq!(
                res.unwrap().into_value().unwrap().map(|b| b.to_vec()),
                Some(vec![i as u8])
            );
        }
    }
}
