//! Nonblocking op submission: the typed operation layer of the connector
//! data plane.
//!
//! The paper's patterns win by *overlapping* wide-area reference
//! resolution with compute, but a call-and-block connector API forces one
//! round trip per blocked thread. This module is the submission/completion
//! redesign: an [`Op`] names one connector operation as data, a
//! [`Pending<T>`] is the condvar-backed completion handle the submitter
//! holds, and [`Connector::submit`](crate::store::Connector::submit)
//! turns any channel into a submission endpoint. Channels with a native
//! pipeline (the TCP KV client) complete handles from a reader thread so
//! N in-flight ops share one round-trip stream; everything else falls
//! back to a blocking bridge, and the shared [`reactor`] pool turns those
//! bridges into overlapped work without per-call thread spawns.
//!
//! Handle semantics (deliberately boring, fully specified):
//!
//! * [`Pending::wait`] blocks until completion and *takes* the result;
//!   a second take reports an error rather than hanging or panicking;
//! * [`Pending::wait_timeout`] / [`Pending::try_take`] are the bounded
//!   and nonblocking variants (`Ok(None)` = not ready yet);
//! * dropping a [`Pending`] while the op is in flight is safe: the
//!   completer's write lands in a slot nobody reads, and nothing leaks;
//! * dropping a [`Completer`] without completing (a dead worker, a torn
//!   connection) completes the handle with an error, so waiters never
//!   park forever.

pub mod reactor;

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::store::{Blob, Connector};

/// One connector operation, as data. The typed twin of the blocking
/// [`Connector`](crate::store::Connector) method set: everything a
/// channel needs to execute the op is owned by the variant, so an `Op`
/// can cross thread and queue boundaries freely.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Store a value ([`Connector::put`]).
    Put { key: String, data: Vec<u8> },
    /// Fetch a value ([`Connector::get`]).
    Get { key: String },
    /// Remove a key, idempotent ([`Connector::evict`]).
    Evict { key: String },
    /// Existence probe ([`Connector::exists`]).
    Exists { key: String },
    /// Batched put ([`Connector::put_many`]).
    PutMany { items: Vec<(String, Vec<u8>)> },
    /// Batched get, positionally aligned ([`Connector::get_many`]).
    GetMany { keys: Vec<String> },
    /// Batched eviction sweep ([`Connector::delete_many`]).
    DeleteMany { keys: Vec<String> },
    /// Batched existence probe ([`Connector::exists_many`]).
    ExistsMany { keys: Vec<String> },
    /// Out-of-band watch ([`Connector::watch`]): completes with
    /// `Value(Some(_))` when the key exists (immediately if it already
    /// does). Unlike every other op, a watch may stay in flight
    /// indefinitely — submission paths route it through the connector's
    /// watch plane instead of parking a thread or a reactor worker on it.
    Watch { key: String },
}

/// Completion value of a submitted [`Op`], mirroring the blocking return
/// types variant-for-variant.
#[derive(Debug, Clone)]
pub enum OpResult {
    /// `Put` / `Evict` / `PutMany` / `DeleteMany` completed.
    Unit,
    /// `Get` result (`None` = missing).
    Value(Option<Blob>),
    /// `GetMany` result, positionally aligned with the request keys.
    Values(Vec<Option<Blob>>),
    /// `Exists` result.
    Bool(bool),
    /// `ExistsMany` result, positionally aligned with the request keys.
    Bools(Vec<bool>),
}

fn shape_err(wanted: &str, got: &OpResult) -> Error {
    Error::Protocol(format!("expected {wanted} completion, got {got:?}"))
}

impl OpResult {
    /// Unwrap a `Put`/`Evict`/`PutMany`/`DeleteMany` completion.
    pub fn into_unit(self) -> Result<()> {
        match self {
            OpResult::Unit => Ok(()),
            other => Err(shape_err("unit", &other)),
        }
    }

    /// Unwrap a `Get` completion.
    pub fn into_value(self) -> Result<Option<Blob>> {
        match self {
            OpResult::Value(v) => Ok(v),
            other => Err(shape_err("value", &other)),
        }
    }

    /// Unwrap a `GetMany` completion.
    pub fn into_values(self) -> Result<Vec<Option<Blob>>> {
        match self {
            OpResult::Values(v) => Ok(v),
            other => Err(shape_err("values", &other)),
        }
    }

    /// Unwrap an `Exists` completion.
    pub fn into_bool(self) -> Result<bool> {
        match self {
            OpResult::Bool(v) => Ok(v),
            other => Err(shape_err("bool", &other)),
        }
    }

    /// Unwrap an `ExistsMany` completion.
    pub fn into_bools(self) -> Result<Vec<bool>> {
        match self {
            OpResult::Bools(v) => Ok(v),
            other => Err(shape_err("bools", &other)),
        }
    }
}

/// Execute an [`Op`] through a channel's blocking methods (the bridge the
/// default [`Connector::submit`](crate::store::Connector::submit) and the
/// reactor pool both ride).
pub fn execute<C: Connector + ?Sized>(conn: &C, op: Op) -> Result<OpResult> {
    Ok(match op {
        Op::Put { key, data } => {
            conn.put(&key, data)?;
            OpResult::Unit
        }
        Op::Get { key } => OpResult::Value(conn.get(&key)?),
        Op::Evict { key } => {
            conn.evict(&key)?;
            OpResult::Unit
        }
        Op::Exists { key } => OpResult::Bool(conn.exists(&key)?),
        Op::PutMany { items } => {
            conn.put_many(items)?;
            OpResult::Unit
        }
        Op::GetMany { keys } => OpResult::Values(conn.get_many(&keys)?),
        Op::DeleteMany { keys } => {
            conn.delete_many(&keys)?;
            OpResult::Unit
        }
        Op::ExistsMany { keys } => OpResult::Bools(conn.exists_many(&keys)?),
        // Blocking bridge for a watch is an unbounded wait — only reached
        // when a caller drives the bridge directly; submission paths route
        // watches through the connector's watch plane instead.
        Op::Watch { key } => OpResult::Value(conn.wait_get(&key, None)?),
    })
}

/// Adapt a raw watch handle ([`Connector::watch`](crate::store::Connector::watch))
/// into an [`OpResult`] completion, so watches compose with every
/// submission consumer (`Store::watch_async`, reactor fan-outs).
pub fn watch_result(handle: Pending<Blob>) -> Pending<OpResult> {
    let (completer, out) = pending();
    handle.on_complete(move |res| {
        completer.complete(res.map(|b| OpResult::Value(Some(b))));
    });
    out
}

/// Submit an op so the *caller* never blocks, whatever the channel
/// offers: channels whose
/// [`submit`](crate::store::Connector::submit) is natively nonblocking
/// (the pipelined TCP client) get the op on the wire directly; blocking
/// bridges are driven by a shared [`reactor`] worker instead of the
/// caller. This is the submission entry point the async [`Store`]
/// (`put_async`/`get_async`) and the fan-out paths build on.
///
/// [`Store`]: crate::store::Store
pub fn submit(conn: &Arc<dyn Connector>, op: Op) -> Pending<OpResult> {
    // Watches may park indefinitely: every connector's `submit` arms them
    // through its watch plane (never a blocking bridge), so they must not
    // be handed to a reactor worker even on blocking channels.
    if conn.submits_nonblocking() || matches!(op, Op::Watch { .. }) {
        conn.submit(op)
    } else {
        let conn = conn.clone();
        reactor::global().spawn(move || conn.submit(op).wait())
    }
}

// ---------------------------------------------------------------------
// Completion handles
// ---------------------------------------------------------------------

/// A registered completion callback plus an optional liveness probe the
/// producer can consult ([`Completer::abandoned`]): when the probe says
/// the subscriber no longer cares (a settled race), long-lived producers
/// like the poll-bridge watch stop working for nobody.
struct Subscription<T> {
    cb: Box<dyn FnOnce(Result<T>) + Send>,
    interested: Option<Box<dyn Fn() -> bool + Send>>,
}

enum Slot<T> {
    /// Submitted, not yet completed.
    InFlight,
    /// Completed; the value waits to be taken.
    Ready(Result<T>),
    /// The value was taken by a waiter.
    Taken,
    /// A callback claimed the completion ([`Pending::on_complete`]); it
    /// runs on the completer's thread and consumes the value.
    Subscribed(Subscription<T>),
}

struct Shared<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

/// Consumer half of a completion: the handle a submitter holds. Condvar
/// backed, zero dependencies. Cheap to create; safe to drop at any point
/// (an in-flight completion lands in a slot nobody reads).
pub struct Pending<T> {
    shared: Arc<Shared<T>>,
}

/// Producer half of a completion. Completing consumes it; dropping it
/// un-completed fails the handle so waiters never park forever.
pub struct Completer<T> {
    shared: Arc<Shared<T>>,
    completed: bool,
}

/// Create a connected completer/handle pair.
pub fn pending<T>() -> (Completer<T>, Pending<T>) {
    let shared = Arc::new(Shared {
        slot: Mutex::new(Slot::InFlight),
        cv: Condvar::new(),
    });
    (
        Completer { shared: shared.clone(), completed: false },
        Pending { shared },
    )
}

fn already_taken() -> Error {
    Error::Config("completion already taken".into())
}

impl<T> Pending<T> {
    /// An already-completed handle (what a blocking bridge returns).
    pub fn ready(result: Result<T>) -> Pending<T> {
        Pending {
            shared: Arc::new(Shared {
                slot: Mutex::new(Slot::Ready(result)),
                cv: Condvar::new(),
            }),
        }
    }

    /// Whether the op has completed (taken counts as completed; a
    /// subscribed callback still waiting does not).
    pub fn is_complete(&self) -> bool {
        !matches!(
            *self.shared.slot.lock().unwrap(),
            Slot::InFlight | Slot::Subscribed(_)
        )
    }

    /// Hand the completion to a callback instead of a waiter: `f` runs
    /// exactly once with the result — immediately on the calling thread if
    /// the op already completed, otherwise on the completer's thread at
    /// completion time (including the failure a dropped completer
    /// injects). Consumes the handle; this is what lets watch handles
    /// compose without parking a thread per handle (racing replica arms,
    /// `when_any` fan-ins, typed adapters).
    ///
    /// Callbacks must be cheap and non-blocking: they run inline on
    /// whatever thread completes the op (a KV reader thread, a storage
    /// engine writer firing its watchers).
    pub fn on_complete(self, f: impl FnOnce(Result<T>) + Send + 'static) {
        self.subscribe(Box::new(f), None);
    }

    /// [`Pending::on_complete`] with a liveness probe: `interested`
    /// answers whether the subscriber still wants the completion. A
    /// long-lived producer ([`Completer::abandoned`]) polls it to stop
    /// producing for a subscriber that can no longer use the value — a
    /// settled [`Race`] arm, for instance. Must be cheap and must not
    /// block (it runs under the handle's slot lock).
    pub fn on_complete_while(
        self,
        f: impl FnOnce(Result<T>) + Send + 'static,
        interested: impl Fn() -> bool + Send + 'static,
    ) {
        self.subscribe(Box::new(f), Some(Box::new(interested)));
    }

    fn subscribe(
        self,
        cb: Box<dyn FnOnce(Result<T>) + Send>,
        interested: Option<Box<dyn Fn() -> bool + Send>>,
    ) {
        let mut slot = self.shared.slot.lock().unwrap();
        match &*slot {
            Slot::InFlight => {
                *slot = Slot::Subscribed(Subscription { cb, interested });
            }
            Slot::Taken | Slot::Subscribed(_) => {} // value already claimed
            Slot::Ready(_) => {
                match std::mem::replace(&mut *slot, Slot::Taken) {
                    Slot::Ready(res) => {
                        drop(slot);
                        cb(res);
                    }
                    _ => unreachable!("matched Ready above"),
                }
            }
        }
    }

    /// Block until completion and take the result. Taking twice reports
    /// an error (the value moved out on the first take).
    pub fn wait(&self) -> Result<T> {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            match &*slot {
                Slot::InFlight => slot = self.shared.cv.wait(slot).unwrap(),
                Slot::Taken | Slot::Subscribed(_) => {
                    return Err(already_taken())
                }
                Slot::Ready(_) => {
                    match std::mem::replace(&mut *slot, Slot::Taken) {
                        Slot::Ready(res) => return res,
                        _ => unreachable!("matched Ready above"),
                    }
                }
            }
        }
    }

    /// Bounded wait: `Ok(None)` if the op is still in flight when the
    /// timeout elapses (the handle stays usable; wait again later).
    pub fn wait_timeout(&self, timeout: Duration) -> Result<Option<T>> {
        let deadline = Instant::now() + timeout;
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            match &*slot {
                Slot::InFlight => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Ok(None);
                    }
                    let (guard, _) = self
                        .shared
                        .cv
                        .wait_timeout(slot, deadline - now)
                        .unwrap();
                    slot = guard;
                }
                Slot::Taken | Slot::Subscribed(_) => {
                    return Err(already_taken())
                }
                Slot::Ready(_) => {
                    match std::mem::replace(&mut *slot, Slot::Taken) {
                        Slot::Ready(res) => return res.map(Some),
                        _ => unreachable!("matched Ready above"),
                    }
                }
            }
        }
    }

    /// Nonblocking take: `Ok(None)` while the op is still in flight.
    pub fn try_take(&self) -> Result<Option<T>> {
        let mut slot = self.shared.slot.lock().unwrap();
        match &*slot {
            Slot::InFlight => Ok(None),
            Slot::Taken | Slot::Subscribed(_) => Err(already_taken()),
            Slot::Ready(_) => match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Ready(res) => res.map(Some),
                _ => unreachable!("matched Ready above"),
            },
        }
    }
}

impl<T> std::fmt::Debug for Pending<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let state = match *self.shared.slot.lock().unwrap() {
            Slot::InFlight => "in-flight",
            Slot::Ready(_) => "ready",
            Slot::Taken => "taken",
            Slot::Subscribed(_) => "subscribed",
        };
        f.debug_struct("Pending").field("state", &state).finish()
    }
}

impl<T> Completer<T> {
    /// Complete the handle and wake every waiter.
    pub fn complete(mut self, result: Result<T>) {
        self.fill(result);
    }

    /// Whether nothing can consume the completion anymore: the handle was
    /// dropped without a waiter, and any subscribed callback's liveness
    /// probe ([`Pending::on_complete_while`]) reports disinterest.
    /// Long-lived producers (the default watch poller, the throttled
    /// bridge) use this to stop working for nobody.
    pub fn abandoned(&self) -> bool {
        let handle_gone = std::sync::Arc::strong_count(&self.shared) == 1;
        match &*self.shared.slot.lock().unwrap() {
            Slot::Subscribed(sub) => match &sub.interested {
                Some(probe) => !probe(),
                // A probe-less subscription counts as live interest.
                None => false,
            },
            _ => handle_gone,
        }
    }

    fn fill(&mut self, result: Result<T>) {
        if self.completed {
            return;
        }
        self.completed = true;
        let mut slot = self.shared.slot.lock().unwrap();
        match std::mem::replace(&mut *slot, Slot::Taken) {
            Slot::InFlight => {
                *slot = Slot::Ready(result);
                drop(slot);
                self.shared.cv.notify_all();
            }
            Slot::Subscribed(sub) => {
                drop(slot);
                (sub.cb)(result);
            }
            // Already settled (defensive; fill guards on `completed`).
            other => *slot = other,
        }
    }
}

impl<T> Drop for Completer<T> {
    /// A completer that dies without completing (worker panic, torn
    /// connection) fails the handle instead of stranding its waiters.
    fn drop(&mut self) {
        self.fill(Err(Error::Connector(
            "operation abandoned: completer dropped before completion".into(),
        )));
    }
}

// ---------------------------------------------------------------------
// Racing fan-in
// ---------------------------------------------------------------------

struct RaceState<T> {
    /// Taken by the first success (or the last failure).
    completer: Option<Completer<T>>,
    /// Arms whose outcome is still pending.
    armed: usize,
    last_err: Option<Error>,
}

/// First-success-wins fan-in over a growable set of completion handles:
/// the watch plane's aggregation primitive. The sharded router arms every
/// replica of a key and completes from whichever fires first; the elastic
/// control plane keeps the race alive across epoch flips by
/// [`add`](Race::add)ing fresh arms mid-flight. The output handle fails
/// only when *every* arm has failed (a dead backend among live ones is
/// not an error), completing with the last failure seen. Thread-free:
/// arms deliver through [`Pending::on_complete`], so a thousand parked
/// races cost no threads and no polling.
pub struct Race<T> {
    state: Arc<Mutex<RaceState<T>>>,
}

impl<T> Clone for Race<T> {
    fn clone(&self) -> Self {
        Race { state: self.state.clone() }
    }
}

/// Create a connected race/handle pair (the fan-in twin of [`pending`]).
/// The handle stays in flight until an arm wins — callers must add at
/// least one arm or the race never settles.
pub fn race<T: Send + 'static>() -> (Race<T>, Pending<T>) {
    let (completer, handle) = pending();
    (
        Race {
            state: Arc::new(Mutex::new(RaceState {
                completer: Some(completer),
                armed: 0,
                last_err: None,
            })),
        },
        handle,
    )
}

impl<T: Send + 'static> Race<T> {
    /// Whether the race has settled (an arm won, or all arms failed).
    pub fn settled(&self) -> bool {
        self.state.lock().unwrap().completer.is_none()
    }

    /// Add one arm (see [`Race::add_all`]).
    pub fn add(&self, handle: Pending<T>) {
        self.add_all(vec![handle]);
    }

    /// Add a batch of arms. The whole batch is registered before any
    /// outcome can settle the race, so an arm that fails synchronously
    /// (a ready-error handle from a dead backend) cannot fail the race
    /// while its siblings are still being armed. Arms added after the
    /// race settled are dropped — their completions land nowhere.
    pub fn add_all(&self, handles: Vec<Pending<T>>) {
        {
            let mut st = self.state.lock().unwrap();
            if st.completer.is_none() {
                return;
            }
            st.armed += handles.len();
        }
        for handle in handles {
            self.subscribe_arm(handle, |v| v);
        }
    }

    /// Add one arm of a different payload type, mapped into the race's
    /// (`when_any`'s index tagging, typed adapters). Same registration
    /// semantics as [`Race::add_all`].
    pub fn add_map<S, F>(&self, handle: Pending<S>, map: F)
    where
        S: Send + 'static,
        F: FnOnce(S) -> T + Send + 'static,
    {
        {
            let mut st = self.state.lock().unwrap();
            if st.completer.is_none() {
                return;
            }
            st.armed += 1;
        }
        self.subscribe_arm(handle, map);
    }

    /// Subscribe one pre-counted arm. The subscription carries a liveness
    /// probe (settled race = no interest), so an arm backed by a
    /// long-lived producer — a poll-bridge watch thread — shuts down once
    /// a sibling has won instead of producing forever for nobody.
    fn subscribe_arm<S, F>(&self, handle: Pending<S>, map: F)
    where
        S: Send + 'static,
        F: FnOnce(S) -> T + Send + 'static,
    {
        let state = self.state.clone();
        let probe = self.state.clone();
        handle.on_complete_while(
            move |res| {
                let winner = {
                    let mut st = state.lock().unwrap();
                    st.armed -= 1;
                    match res {
                        Ok(v) => {
                            st.completer.take().map(|c| (c, Ok(map(v))))
                        }
                        Err(e) => {
                            st.last_err = Some(e);
                            if st.armed == 0 {
                                let err = st
                                    .last_err
                                    .clone()
                                    .expect("error recorded above");
                                st.completer.take().map(|c| (c, Err(err)))
                            } else {
                                None
                            }
                        }
                    }
                };
                // Complete outside the state lock: the output handle may
                // itself be subscribed, chaining into arbitrary callbacks.
                if let Some((completer, res)) = winner {
                    completer.complete(res);
                }
            },
            move || probe.lock().unwrap().completer.is_some(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ready_handle_completes_immediately() {
        let p = Pending::ready(Ok(7u32));
        assert!(p.is_complete());
        assert_eq!(p.wait().unwrap(), 7);
        // Take-after-take errors rather than hanging.
        assert!(p.wait().is_err());
        assert!(p.try_take().is_err());
    }

    #[test]
    fn complete_wakes_waiter() {
        let (completer, handle) = pending::<u64>();
        assert!(!handle.is_complete());
        assert_eq!(handle.try_take().unwrap(), None);
        let waiter = std::thread::spawn(move || handle.wait());
        std::thread::sleep(Duration::from_millis(20));
        completer.complete(Ok(42));
        assert_eq!(waiter.join().unwrap().unwrap(), 42);
    }

    #[test]
    fn wait_timeout_expires_then_succeeds() {
        let (completer, handle) = pending::<u8>();
        assert_eq!(
            handle.wait_timeout(Duration::from_millis(30)).unwrap(),
            None
        );
        completer.complete(Ok(5));
        assert_eq!(
            handle.wait_timeout(Duration::from_millis(30)).unwrap(),
            Some(5)
        );
        assert!(handle.try_take().is_err());
    }

    #[test]
    fn dropped_completer_fails_handle() {
        let (completer, handle) = pending::<u8>();
        drop(completer);
        assert!(handle.wait().is_err());
    }

    #[test]
    fn dropped_handle_is_safe() {
        let (completer, handle) = pending::<Vec<u8>>();
        drop(handle);
        completer.complete(Ok(vec![1; 1024])); // lands nowhere, leaks nothing
    }

    #[test]
    fn error_completion_propagates() {
        let (completer, handle) = pending::<u8>();
        completer.complete(Err(Error::Connector("boom".into())));
        match handle.wait() {
            Err(Error::Connector(m)) => assert!(m.contains("boom")),
            other => panic!("expected connector error, got {other:?}"),
        }
    }

    #[test]
    fn op_result_shapes() {
        assert!(OpResult::Unit.into_unit().is_ok());
        assert!(OpResult::Bool(true).into_bool().unwrap());
        assert!(OpResult::Unit.into_value().is_err());
        assert!(OpResult::Value(None).into_values().is_err());
        assert_eq!(
            OpResult::Bools(vec![true, false]).into_bools().unwrap(),
            vec![true, false]
        );
        assert_eq!(OpResult::Values(Vec::new()).into_values().unwrap(), Vec::new());
    }

    #[test]
    fn execute_bridges_every_op() {
        let conn = crate::store::MemoryConnector::new();
        execute(&*conn, Op::Put { key: "k".into(), data: vec![1, 2] })
            .unwrap()
            .into_unit()
            .unwrap();
        assert_eq!(
            execute(&*conn, Op::Get { key: "k".into() })
                .unwrap()
                .into_value()
                .unwrap()
                .map(|b| b.to_vec()),
            Some(vec![1, 2])
        );
        assert!(execute(&*conn, Op::Exists { key: "k".into() })
            .unwrap()
            .into_bool()
            .unwrap());
        execute(
            &*conn,
            Op::PutMany {
                items: vec![("a".into(), vec![1]), ("b".into(), vec![2])],
            },
        )
        .unwrap()
        .into_unit()
        .unwrap();
        let got = execute(
            &*conn,
            Op::GetMany { keys: vec!["a".into(), "nope".into(), "b".into()] },
        )
        .unwrap()
        .into_values()
        .unwrap();
        assert_eq!(
            got.iter().map(|b| b.as_ref().map(|v| v.to_vec())).collect::<Vec<_>>(),
            vec![Some(vec![1]), None, Some(vec![2])]
        );
        assert_eq!(
            execute(
                &*conn,
                Op::ExistsMany { keys: vec!["a".into(), "ghost".into()] }
            )
            .unwrap()
            .into_bools()
            .unwrap(),
            vec![true, false]
        );
        execute(&*conn, Op::DeleteMany { keys: vec!["a".into(), "b".into()] })
            .unwrap()
            .into_unit()
            .unwrap();
        execute(&*conn, Op::Evict { key: "k".into() })
            .unwrap()
            .into_unit()
            .unwrap();
        assert_eq!(conn.len().unwrap(), 0);
    }

    #[test]
    fn on_complete_fires_now_or_later() {
        // Already-ready handle: callback runs inline.
        let hits = Arc::new(Mutex::new(Vec::new()));
        let h2 = hits.clone();
        Pending::ready(Ok(1u32)).on_complete(move |r| {
            h2.lock().unwrap().push(r.unwrap());
        });
        assert_eq!(*hits.lock().unwrap(), vec![1]);

        // In-flight handle: callback runs on the completer's thread.
        let (completer, handle) = pending::<u32>();
        let h3 = hits.clone();
        handle.on_complete(move |r| h3.lock().unwrap().push(r.unwrap()));
        completer.complete(Ok(2));
        assert_eq!(*hits.lock().unwrap(), vec![1, 2]);

        // A dropped completer still delivers (as an error).
        let (completer, handle) = pending::<u32>();
        let errs = Arc::new(Mutex::new(0));
        let e2 = errs.clone();
        handle.on_complete(move |r| {
            assert!(r.is_err());
            *e2.lock().unwrap() += 1;
        });
        drop(completer);
        assert_eq!(*errs.lock().unwrap(), 1);
    }

    #[test]
    fn abandoned_tracks_handle_and_subscription() {
        let (completer, handle) = pending::<u8>();
        assert!(!completer.abandoned(), "live handle");
        handle.on_complete(|_| {});
        assert!(!completer.abandoned(), "subscribed callback keeps it live");
        completer.complete(Ok(1));

        let (completer, handle) = pending::<u8>();
        drop(handle);
        assert!(completer.abandoned(), "dropped unsubscribed handle");

        // A probe-carrying subscription reports the probe's answer.
        let live = Arc::new(Mutex::new(true));
        let l2 = live.clone();
        let (completer, handle) = pending::<u8>();
        handle.on_complete_while(|_| {}, move || *l2.lock().unwrap());
        assert!(!completer.abandoned(), "probe says interested");
        *live.lock().unwrap() = false;
        assert!(completer.abandoned(), "probe says disinterested");
    }

    #[test]
    fn settled_race_releases_losing_arms() {
        // A race's losing arm must report abandonment to its producer so
        // long-lived pollers shut down instead of producing forever.
        let (group, out) = race::<u8>();
        let (winner_c, winner_h) = pending();
        let (loser_c, loser_h) = pending();
        group.add_all(vec![winner_h, loser_h]);
        assert!(!loser_c.abandoned(), "race still open: arm is wanted");
        winner_c.complete(Ok(1));
        assert!(loser_c.abandoned(), "settled race must release its arms");
        assert_eq!(out.wait().unwrap(), 1);
    }

    #[test]
    fn race_add_map_tags_arms() {
        let (group, out) = race::<(usize, u8)>();
        let (c0, h0) = pending::<u8>();
        let (c1, h1) = pending::<u8>();
        group.add_map(h0, |v| (0, v));
        group.add_map(h1, |v| (1, v));
        c1.complete(Ok(9));
        c0.complete(Ok(7)); // loser lands nowhere
        assert_eq!(out.wait().unwrap(), (1, 9));
    }

    #[test]
    fn race_first_success_wins() {
        let (group, out) = race::<u8>();
        let (c1, h1) = pending();
        let (c2, h2) = pending();
        group.add_all(vec![h1, h2]);
        assert!(!group.settled());
        c1.complete(Ok(7));
        assert!(group.settled());
        c2.complete(Ok(9)); // loser lands nowhere
        assert_eq!(out.wait().unwrap(), 7);
    }

    #[test]
    fn race_fails_only_when_all_arms_fail() {
        let (group, out) = race::<u8>();
        let (c1, h1) = pending();
        let (c2, h2) = pending();
        group.add_all(vec![h1, h2]);
        c1.complete(Err(Error::Connector("one down".into())));
        assert!(!group.settled(), "a surviving arm keeps the race open");
        c2.complete(Err(Error::Connector("all down".into())));
        match out.wait() {
            Err(Error::Connector(m)) => assert!(m.contains("all down")),
            other => panic!("expected connector error, got {other:?}"),
        }
    }

    #[test]
    fn race_batch_arming_survives_synchronous_failures() {
        // A ready-error arm in the same batch as a live one must not
        // settle the race before the live arm is registered.
        let (group, out) = race::<u8>();
        let (c, live) = pending();
        group.add_all(vec![
            Pending::ready(Err(Error::Connector("dead backend".into()))),
            live,
        ]);
        assert!(!group.settled());
        c.complete(Ok(3));
        assert_eq!(out.wait().unwrap(), 3);

        // Arms added after settling are dropped, not errors.
        let (group, out) = race::<u8>();
        group.add(Pending::ready(Ok(1)));
        group.add(Pending::ready(Ok(2)));
        assert_eq!(out.wait().unwrap(), 1);
    }

    #[test]
    fn watch_result_adapts_blob_handles() {
        let (completer, handle) = pending();
        let adapted = watch_result(handle);
        completer.complete(Ok(Arc::new(vec![1u8, 2])));
        assert_eq!(
            adapted
                .wait()
                .unwrap()
                .into_value()
                .unwrap()
                .map(|b| b.to_vec()),
            Some(vec![1, 2])
        );
    }

    #[test]
    fn submit_helper_drives_blocking_channels() {
        let conn = crate::store::MemoryConnector::new();
        let h = submit(&conn, Op::Put { key: "s".into(), data: vec![9] });
        h.wait().unwrap().into_unit().unwrap();
        let h = submit(&conn, Op::Get { key: "s".into() });
        assert_eq!(
            h.wait().unwrap().into_value().unwrap().map(|b| b.to_vec()),
            Some(vec![9])
        );
    }
}
