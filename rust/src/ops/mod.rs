//! Nonblocking op submission: the typed operation layer of the connector
//! data plane.
//!
//! The paper's patterns win by *overlapping* wide-area reference
//! resolution with compute, but a call-and-block connector API forces one
//! round trip per blocked thread. This module is the submission/completion
//! redesign: an [`Op`] names one connector operation as data, a
//! [`Pending<T>`] is the condvar-backed completion handle the submitter
//! holds, and [`Connector::submit`](crate::store::Connector::submit)
//! turns any channel into a submission endpoint. Channels with a native
//! pipeline (the TCP KV client) complete handles from a reader thread so
//! N in-flight ops share one round-trip stream; everything else falls
//! back to a blocking bridge, and the shared [`reactor`] pool turns those
//! bridges into overlapped work without per-call thread spawns.
//!
//! Handle semantics (deliberately boring, fully specified):
//!
//! * [`Pending::wait`] blocks until completion and *takes* the result;
//!   a second take reports an error rather than hanging or panicking;
//! * [`Pending::wait_timeout`] / [`Pending::try_take`] are the bounded
//!   and nonblocking variants (`Ok(None)` = not ready yet);
//! * dropping a [`Pending`] while the op is in flight is safe: the
//!   completer's write lands in a slot nobody reads, and nothing leaks;
//! * dropping a [`Completer`] without completing (a dead worker, a torn
//!   connection) completes the handle with an error, so waiters never
//!   park forever.

pub mod reactor;

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::store::{Blob, Connector};

/// One connector operation, as data. The typed twin of the blocking
/// [`Connector`](crate::store::Connector) method set: everything a
/// channel needs to execute the op is owned by the variant, so an `Op`
/// can cross thread and queue boundaries freely.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Store a value ([`Connector::put`]).
    Put { key: String, data: Vec<u8> },
    /// Fetch a value ([`Connector::get`]).
    Get { key: String },
    /// Remove a key, idempotent ([`Connector::evict`]).
    Evict { key: String },
    /// Existence probe ([`Connector::exists`]).
    Exists { key: String },
    /// Batched put ([`Connector::put_many`]).
    PutMany { items: Vec<(String, Vec<u8>)> },
    /// Batched get, positionally aligned ([`Connector::get_many`]).
    GetMany { keys: Vec<String> },
    /// Batched eviction sweep ([`Connector::delete_many`]).
    DeleteMany { keys: Vec<String> },
    /// Batched existence probe ([`Connector::exists_many`]).
    ExistsMany { keys: Vec<String> },
}

/// Completion value of a submitted [`Op`], mirroring the blocking return
/// types variant-for-variant.
#[derive(Debug, Clone)]
pub enum OpResult {
    /// `Put` / `Evict` / `PutMany` / `DeleteMany` completed.
    Unit,
    /// `Get` result (`None` = missing).
    Value(Option<Blob>),
    /// `GetMany` result, positionally aligned with the request keys.
    Values(Vec<Option<Blob>>),
    /// `Exists` result.
    Bool(bool),
    /// `ExistsMany` result, positionally aligned with the request keys.
    Bools(Vec<bool>),
}

fn shape_err(wanted: &str, got: &OpResult) -> Error {
    Error::Protocol(format!("expected {wanted} completion, got {got:?}"))
}

impl OpResult {
    /// Unwrap a `Put`/`Evict`/`PutMany`/`DeleteMany` completion.
    pub fn into_unit(self) -> Result<()> {
        match self {
            OpResult::Unit => Ok(()),
            other => Err(shape_err("unit", &other)),
        }
    }

    /// Unwrap a `Get` completion.
    pub fn into_value(self) -> Result<Option<Blob>> {
        match self {
            OpResult::Value(v) => Ok(v),
            other => Err(shape_err("value", &other)),
        }
    }

    /// Unwrap a `GetMany` completion.
    pub fn into_values(self) -> Result<Vec<Option<Blob>>> {
        match self {
            OpResult::Values(v) => Ok(v),
            other => Err(shape_err("values", &other)),
        }
    }

    /// Unwrap an `Exists` completion.
    pub fn into_bool(self) -> Result<bool> {
        match self {
            OpResult::Bool(v) => Ok(v),
            other => Err(shape_err("bool", &other)),
        }
    }

    /// Unwrap an `ExistsMany` completion.
    pub fn into_bools(self) -> Result<Vec<bool>> {
        match self {
            OpResult::Bools(v) => Ok(v),
            other => Err(shape_err("bools", &other)),
        }
    }
}

/// Execute an [`Op`] through a channel's blocking methods (the bridge the
/// default [`Connector::submit`](crate::store::Connector::submit) and the
/// reactor pool both ride).
pub fn execute<C: Connector + ?Sized>(conn: &C, op: Op) -> Result<OpResult> {
    Ok(match op {
        Op::Put { key, data } => {
            conn.put(&key, data)?;
            OpResult::Unit
        }
        Op::Get { key } => OpResult::Value(conn.get(&key)?),
        Op::Evict { key } => {
            conn.evict(&key)?;
            OpResult::Unit
        }
        Op::Exists { key } => OpResult::Bool(conn.exists(&key)?),
        Op::PutMany { items } => {
            conn.put_many(items)?;
            OpResult::Unit
        }
        Op::GetMany { keys } => OpResult::Values(conn.get_many(&keys)?),
        Op::DeleteMany { keys } => {
            conn.delete_many(&keys)?;
            OpResult::Unit
        }
        Op::ExistsMany { keys } => OpResult::Bools(conn.exists_many(&keys)?),
    })
}

/// Submit an op so the *caller* never blocks, whatever the channel
/// offers: channels whose
/// [`submit`](crate::store::Connector::submit) is natively nonblocking
/// (the pipelined TCP client) get the op on the wire directly; blocking
/// bridges are driven by a shared [`reactor`] worker instead of the
/// caller. This is the submission entry point the async [`Store`]
/// (`put_async`/`get_async`) and the fan-out paths build on.
///
/// [`Store`]: crate::store::Store
pub fn submit(conn: &Arc<dyn Connector>, op: Op) -> Pending<OpResult> {
    if conn.submits_nonblocking() {
        conn.submit(op)
    } else {
        let conn = conn.clone();
        reactor::global().spawn(move || conn.submit(op).wait())
    }
}

// ---------------------------------------------------------------------
// Completion handles
// ---------------------------------------------------------------------

enum Slot<T> {
    /// Submitted, not yet completed.
    InFlight,
    /// Completed; the value waits to be taken.
    Ready(Result<T>),
    /// The value was taken by a waiter.
    Taken,
}

struct Shared<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

/// Consumer half of a completion: the handle a submitter holds. Condvar
/// backed, zero dependencies. Cheap to create; safe to drop at any point
/// (an in-flight completion lands in a slot nobody reads).
pub struct Pending<T> {
    shared: Arc<Shared<T>>,
}

/// Producer half of a completion. Completing consumes it; dropping it
/// un-completed fails the handle so waiters never park forever.
pub struct Completer<T> {
    shared: Arc<Shared<T>>,
    completed: bool,
}

/// Create a connected completer/handle pair.
pub fn pending<T>() -> (Completer<T>, Pending<T>) {
    let shared = Arc::new(Shared {
        slot: Mutex::new(Slot::InFlight),
        cv: Condvar::new(),
    });
    (
        Completer { shared: shared.clone(), completed: false },
        Pending { shared },
    )
}

fn already_taken() -> Error {
    Error::Config("completion already taken".into())
}

impl<T> Pending<T> {
    /// An already-completed handle (what a blocking bridge returns).
    pub fn ready(result: Result<T>) -> Pending<T> {
        Pending {
            shared: Arc::new(Shared {
                slot: Mutex::new(Slot::Ready(result)),
                cv: Condvar::new(),
            }),
        }
    }

    /// Whether the op has completed (taken counts as completed).
    pub fn is_complete(&self) -> bool {
        !matches!(*self.shared.slot.lock().unwrap(), Slot::InFlight)
    }

    /// Block until completion and take the result. Taking twice reports
    /// an error (the value moved out on the first take).
    pub fn wait(&self) -> Result<T> {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            match &*slot {
                Slot::InFlight => slot = self.shared.cv.wait(slot).unwrap(),
                Slot::Taken => return Err(already_taken()),
                Slot::Ready(_) => {
                    match std::mem::replace(&mut *slot, Slot::Taken) {
                        Slot::Ready(res) => return res,
                        _ => unreachable!("matched Ready above"),
                    }
                }
            }
        }
    }

    /// Bounded wait: `Ok(None)` if the op is still in flight when the
    /// timeout elapses (the handle stays usable; wait again later).
    pub fn wait_timeout(&self, timeout: Duration) -> Result<Option<T>> {
        let deadline = Instant::now() + timeout;
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            match &*slot {
                Slot::InFlight => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Ok(None);
                    }
                    let (guard, _) = self
                        .shared
                        .cv
                        .wait_timeout(slot, deadline - now)
                        .unwrap();
                    slot = guard;
                }
                Slot::Taken => return Err(already_taken()),
                Slot::Ready(_) => {
                    match std::mem::replace(&mut *slot, Slot::Taken) {
                        Slot::Ready(res) => return res.map(Some),
                        _ => unreachable!("matched Ready above"),
                    }
                }
            }
        }
    }

    /// Nonblocking take: `Ok(None)` while the op is still in flight.
    pub fn try_take(&self) -> Result<Option<T>> {
        let mut slot = self.shared.slot.lock().unwrap();
        match &*slot {
            Slot::InFlight => Ok(None),
            Slot::Taken => Err(already_taken()),
            Slot::Ready(_) => match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Ready(res) => res.map(Some),
                _ => unreachable!("matched Ready above"),
            },
        }
    }
}

impl<T> std::fmt::Debug for Pending<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let state = match *self.shared.slot.lock().unwrap() {
            Slot::InFlight => "in-flight",
            Slot::Ready(_) => "ready",
            Slot::Taken => "taken",
        };
        f.debug_struct("Pending").field("state", &state).finish()
    }
}

impl<T> Completer<T> {
    /// Complete the handle and wake every waiter.
    pub fn complete(mut self, result: Result<T>) {
        self.fill(result);
    }

    fn fill(&mut self, result: Result<T>) {
        if self.completed {
            return;
        }
        self.completed = true;
        let mut slot = self.shared.slot.lock().unwrap();
        if matches!(*slot, Slot::InFlight) {
            *slot = Slot::Ready(result);
        }
        drop(slot);
        self.shared.cv.notify_all();
    }
}

impl<T> Drop for Completer<T> {
    /// A completer that dies without completing (worker panic, torn
    /// connection) fails the handle instead of stranding its waiters.
    fn drop(&mut self) {
        self.fill(Err(Error::Connector(
            "operation abandoned: completer dropped before completion".into(),
        )));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ready_handle_completes_immediately() {
        let p = Pending::ready(Ok(7u32));
        assert!(p.is_complete());
        assert_eq!(p.wait().unwrap(), 7);
        // Take-after-take errors rather than hanging.
        assert!(p.wait().is_err());
        assert!(p.try_take().is_err());
    }

    #[test]
    fn complete_wakes_waiter() {
        let (completer, handle) = pending::<u64>();
        assert!(!handle.is_complete());
        assert_eq!(handle.try_take().unwrap(), None);
        let waiter = std::thread::spawn(move || handle.wait());
        std::thread::sleep(Duration::from_millis(20));
        completer.complete(Ok(42));
        assert_eq!(waiter.join().unwrap().unwrap(), 42);
    }

    #[test]
    fn wait_timeout_expires_then_succeeds() {
        let (completer, handle) = pending::<u8>();
        assert_eq!(
            handle.wait_timeout(Duration::from_millis(30)).unwrap(),
            None
        );
        completer.complete(Ok(5));
        assert_eq!(
            handle.wait_timeout(Duration::from_millis(30)).unwrap(),
            Some(5)
        );
        assert!(handle.try_take().is_err());
    }

    #[test]
    fn dropped_completer_fails_handle() {
        let (completer, handle) = pending::<u8>();
        drop(completer);
        assert!(handle.wait().is_err());
    }

    #[test]
    fn dropped_handle_is_safe() {
        let (completer, handle) = pending::<Vec<u8>>();
        drop(handle);
        completer.complete(Ok(vec![1; 1024])); // lands nowhere, leaks nothing
    }

    #[test]
    fn error_completion_propagates() {
        let (completer, handle) = pending::<u8>();
        completer.complete(Err(Error::Connector("boom".into())));
        match handle.wait() {
            Err(Error::Connector(m)) => assert!(m.contains("boom")),
            other => panic!("expected connector error, got {other:?}"),
        }
    }

    #[test]
    fn op_result_shapes() {
        assert!(OpResult::Unit.into_unit().is_ok());
        assert!(OpResult::Bool(true).into_bool().unwrap());
        assert!(OpResult::Unit.into_value().is_err());
        assert!(OpResult::Value(None).into_values().is_err());
        assert_eq!(
            OpResult::Bools(vec![true, false]).into_bools().unwrap(),
            vec![true, false]
        );
        assert_eq!(OpResult::Values(Vec::new()).into_values().unwrap(), Vec::new());
    }

    #[test]
    fn execute_bridges_every_op() {
        let conn = crate::store::MemoryConnector::new();
        execute(&*conn, Op::Put { key: "k".into(), data: vec![1, 2] })
            .unwrap()
            .into_unit()
            .unwrap();
        assert_eq!(
            execute(&*conn, Op::Get { key: "k".into() })
                .unwrap()
                .into_value()
                .unwrap()
                .map(|b| b.to_vec()),
            Some(vec![1, 2])
        );
        assert!(execute(&*conn, Op::Exists { key: "k".into() })
            .unwrap()
            .into_bool()
            .unwrap());
        execute(
            &*conn,
            Op::PutMany {
                items: vec![("a".into(), vec![1]), ("b".into(), vec![2])],
            },
        )
        .unwrap()
        .into_unit()
        .unwrap();
        let got = execute(
            &*conn,
            Op::GetMany { keys: vec!["a".into(), "nope".into(), "b".into()] },
        )
        .unwrap()
        .into_values()
        .unwrap();
        assert_eq!(
            got.iter().map(|b| b.as_ref().map(|v| v.to_vec())).collect::<Vec<_>>(),
            vec![Some(vec![1]), None, Some(vec![2])]
        );
        assert_eq!(
            execute(
                &*conn,
                Op::ExistsMany { keys: vec!["a".into(), "ghost".into()] }
            )
            .unwrap()
            .into_bools()
            .unwrap(),
            vec![true, false]
        );
        execute(&*conn, Op::DeleteMany { keys: vec!["a".into(), "b".into()] })
            .unwrap()
            .into_unit()
            .unwrap();
        execute(&*conn, Op::Evict { key: "k".into() })
            .unwrap()
            .into_unit()
            .unwrap();
        assert_eq!(conn.len().unwrap(), 0);
    }

    #[test]
    fn submit_helper_drives_blocking_channels() {
        let conn = crate::store::MemoryConnector::new();
        let h = submit(&conn, Op::Put { key: "s".into(), data: vec![9] });
        h.wait().unwrap().into_unit().unwrap();
        let h = submit(&conn, Op::Get { key: "s".into() });
        assert_eq!(
            h.wait().unwrap().into_value().unwrap().map(|b| b.to_vec()),
            Some(vec![9])
        );
    }
}
