//! Durability plane: segmented write-ahead logs and point-in-time
//! snapshots.
//!
//! Everything above this module is RAM-resident; this module is what
//! survives a crash. Two primitives compose into per-engine recovery:
//!
//! * [`Wal`] — a segmented append-only log. Records are framed as
//!   `[len u32 LE][crc32 u32 LE][payload]` and appended to fixed-size
//!   segment files named by the sequence number of their first record
//!   (`{base:020}.wal`). Appends buffer in userspace; durability comes
//!   from **group commit**: [`Wal::commit`] fsyncs once and covers every
//!   record appended up to that point, so N threads acking concurrently
//!   pay ~1 fsync. The fsync cadence is a [`FsyncPolicy`].
//! * [`snapshot`] — point-in-time state images written atomically
//!   (temp file + rename + dir fsync). A snapshot records the WAL
//!   sequence number it covers; segments entirely below that horizon are
//!   reclaimed by [`Wal::truncate_below`].
//!
//! Recovery is `load_latest_snapshot` + [`Wal::replay`] of the tail.
//! Replay is **torn-tail safe**: a record whose length field runs past
//! the end of the file, or whose CRC does not match, marks the end of
//! the log — the tail is physically truncated (and any later segments
//! deleted) so subsequent appends continue from the last durable record.
//! Dropped bytes are counted in the `recovery.truncated_records`
//! counter.
//!
//! On-disk layout under a server's [`DurabilityOptions::data_dir`]:
//!
//! ```text
//! <data_dir>/
//!   kv/
//!     wal/00000000000000000001.wal      segmented KV mutation log
//!     snap/00000000000000004096.snap    latest point-in-time image
//!   broker/
//!     commits.ckpt                      committed-offset checkpoint
//!     topics/<hex(topic)>/p<partition>/
//!       00000000000000000000.wal        offset-indexed log segments
//! ```
//!
//! Engines opt in via [`DurabilityOptions`] (surfaced as
//! [`crate::net::ServerBuilder::data_dir`]). The write path appends
//! under the engine lock (so WAL order equals apply order) and commits
//! after releasing it (so fsyncs don't serialize unrelated readers).
//! WAL/snapshot I/O errors on the write path are **fail-stop**: the
//! engine panics rather than ack a write it could not log.
//!
//! Telemetry (all visible in `/metrics`): `wal.appends`, `wal.bytes`,
//! `wal.rotations`, `wal.fsyncs`, `wal.fsync_us` (histogram),
//! `snapshot.writes`, `snapshot.duration_us` (histogram),
//! `recovery.replayed_records`, `recovery.truncated_records`.

pub mod snapshot;
pub mod wal;

pub use snapshot::{load_latest_snapshot, write_snapshot};
pub use wal::{ReplayStats, Wal};

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use crate::metrics::telemetry::{self, Counter, Histogram};

/// When an acknowledged write is guaranteed to have reached the disk.
///
/// | policy | durability on crash | cost |
/// |---|---|---|
/// | [`EveryOp`](FsyncPolicy::EveryOp) | every acked op survives | ~1 group-commit fsync per ack wave |
/// | [`EveryN`](FsyncPolicy::EveryN) | at most N-1 acked ops lost | amortized: 1 fsync per N appends |
/// | [`Off`](FsyncPolicy::Off) | OS page-cache flush cadence | no fsync on the write path |
///
/// All policies share the same *consistency* guarantee: replay stops at
/// the first torn record, so recovery always yields a prefix of the
/// acked history — never a corrupted or reordered state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Group-commit fsync before every ack. Concurrent committers
    /// piggyback on one `fdatasync`.
    EveryOp,
    /// Fsync once at least every N appended records. The window of
    /// acked-but-volatile records is bounded by N.
    EveryN(u64),
    /// Never fsync from the write path (segment rotation still syncs the
    /// closing segment). Crash durability is whatever the OS flushed.
    Off,
}

impl Default for FsyncPolicy {
    fn default() -> Self {
        FsyncPolicy::EveryN(256)
    }
}

/// Configuration for the durability plane of one server / engine.
///
/// Construct with [`DurabilityOptions::new`] and refine with the builder
/// methods; pass to [`crate::net::ServerBuilder::durability`] (or use
/// the [`crate::net::ServerBuilder::data_dir`] shorthand for defaults).
#[derive(Debug, Clone)]
pub struct DurabilityOptions {
    /// Root directory for all persistent state of this server.
    pub data_dir: PathBuf,
    /// Fsync cadence for the write path.
    pub fsync: FsyncPolicy,
    /// Segment rotation threshold in bytes.
    pub segment_bytes: u64,
    /// KV: take a snapshot (and reclaim WAL segments below it) every
    /// this-many logged mutations. `0` disables automatic snapshots.
    pub snapshot_every_ops: u64,
    /// Broker: per-partition retention — keep at most this many *closed*
    /// segments (the active segment never counts). `0` = unlimited.
    pub retain_segments: usize,
    /// Broker: per-partition retention — drop oldest closed segments
    /// while the partition's on-disk bytes exceed this. `0` = unlimited.
    pub retain_bytes: u64,
}

impl DurabilityOptions {
    /// Durability rooted at `data_dir` with default tuning: fsync every
    /// 256 records, 8 MiB segments, KV snapshot every 65536 mutations,
    /// unlimited broker retention.
    pub fn new(data_dir: impl Into<PathBuf>) -> Self {
        DurabilityOptions {
            data_dir: data_dir.into(),
            fsync: FsyncPolicy::default(),
            segment_bytes: 8 * 1024 * 1024,
            snapshot_every_ops: 65_536,
            retain_segments: 0,
            retain_bytes: 0,
        }
    }

    /// Set the fsync policy.
    pub fn fsync(mut self, policy: FsyncPolicy) -> Self {
        self.fsync = policy;
        self
    }

    /// Set the segment rotation threshold (bytes). Clamped to ≥ 4 KiB.
    pub fn segment_bytes(mut self, bytes: u64) -> Self {
        self.segment_bytes = bytes.max(4096);
        self
    }

    /// Snapshot the KV map every `ops` logged mutations (`0` disables).
    pub fn snapshot_every_ops(mut self, ops: u64) -> Self {
        self.snapshot_every_ops = ops;
        self
    }

    /// Broker retention: keep at most `n` closed segments per partition.
    pub fn retain_segments(mut self, n: usize) -> Self {
        self.retain_segments = n;
        self
    }

    /// Broker retention: cap per-partition on-disk bytes.
    pub fn retain_bytes(mut self, bytes: u64) -> Self {
        self.retain_bytes = bytes;
        self
    }
}

/// What recovery found when a durable engine opened its data dir.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecoveryStats {
    /// WAL horizon of the snapshot the state was seeded from, if any.
    pub snapshot_seq: Option<u64>,
    /// WAL records replayed on top of the snapshot (or from scratch).
    pub replayed_records: u64,
    /// Torn/corrupt tail records dropped during replay.
    pub truncated_records: u64,
}

/// CRC-32 (IEEE 802.3, reflected) over `data`. Table-driven, built once.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// Cached handles for the durability-plane metrics (registry lookups are
/// lock-guarded; the hot path goes through this struct instead).
pub(crate) struct PersistMetrics {
    pub appends: Arc<Counter>,
    pub bytes: Arc<Counter>,
    pub rotations: Arc<Counter>,
    pub fsyncs: Arc<Counter>,
    pub fsync_us: Arc<Histogram>,
    pub snapshots: Arc<Counter>,
    pub snapshot_us: Arc<Histogram>,
    pub replayed: Arc<Counter>,
    pub truncated: Arc<Counter>,
}

pub(crate) fn metrics() -> &'static PersistMetrics {
    static M: OnceLock<PersistMetrics> = OnceLock::new();
    M.get_or_init(|| PersistMetrics {
        appends: telemetry::counter("wal.appends"),
        bytes: telemetry::counter("wal.bytes"),
        rotations: telemetry::counter("wal.rotations"),
        fsyncs: telemetry::counter("wal.fsyncs"),
        fsync_us: telemetry::histogram("wal.fsync_us"),
        snapshots: telemetry::counter("snapshot.writes"),
        snapshot_us: telemetry::histogram("snapshot.duration_us"),
        replayed: telemetry::counter("recovery.replayed_records"),
        truncated: telemetry::counter("recovery.truncated_records"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    #[test]
    fn options_builder() {
        let o = DurabilityOptions::new("/tmp/x")
            .fsync(FsyncPolicy::EveryOp)
            .segment_bytes(1)
            .snapshot_every_ops(10)
            .retain_segments(3)
            .retain_bytes(1 << 20);
        assert_eq!(o.fsync, FsyncPolicy::EveryOp);
        assert_eq!(o.segment_bytes, 4096); // clamped
        assert_eq!(o.snapshot_every_ops, 10);
        assert_eq!(o.retain_segments, 3);
        assert_eq!(o.retain_bytes, 1 << 20);
    }
}
