//! Atomic point-in-time snapshots.
//!
//! A snapshot is one file `{seq:020}.snap` whose name carries the WAL
//! sequence horizon it covers: every logged mutation with seq ≤ that
//! horizon is folded into the image, so recovery loads the newest valid
//! snapshot and replays only WAL records after it, and
//! [`super::Wal::truncate_below`] may reclaim segments at or below the
//! horizon.
//!
//! File format: `b"PXSNAP1\n"` magic, `seq: u64 LE`, `len: u64 LE`,
//! `payload`, `crc32(payload): u32 LE`. Writes are crash-atomic: the
//! bytes land in a temp file which is fsynced, renamed into place, and
//! the directory fsynced — a crash mid-write leaves the previous
//! snapshot untouched. [`load_latest_snapshot`] validates magic, length
//! and CRC, and falls back to the next-older snapshot if the newest is
//! damaged.

use std::fs::{self, File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::{crc32, metrics};
use crate::Result;

const MAGIC: &[u8; 8] = b"PXSNAP1\n";

fn snap_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("{seq:020}.snap"))
}

fn snap_seq(path: &Path) -> Option<u64> {
    let stem = path.file_name()?.to_str()?.strip_suffix(".snap")?;
    if stem.len() != 20 || !stem.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    stem.parse().ok()
}

/// Write a snapshot covering WAL horizon `seq` atomically, then prune
/// older snapshot files (the newest valid image is all recovery needs;
/// one older generation is kept as a fallback against a bad disk).
pub fn write_snapshot(dir: &Path, seq: u64, payload: &[u8]) -> Result<PathBuf> {
    let m = metrics();
    let t0 = Instant::now();
    fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(".snap-{}.tmp", std::process::id()));
    {
        let mut f = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&seq.to_le_bytes())?;
        f.write_all(&(payload.len() as u64).to_le_bytes())?;
        f.write_all(payload)?;
        f.write_all(&crc32(payload).to_le_bytes())?;
        f.sync_all()?;
    }
    let path = snap_path(dir, seq);
    fs::rename(&tmp, &path)?;
    File::open(dir)?.sync_all()?;
    // Keep the new image plus one older generation.
    let mut seqs: Vec<u64> = fs::read_dir(dir)?
        .filter_map(|e| snap_seq(&e.ok()?.path()))
        .filter(|s| *s < seq)
        .collect();
    seqs.sort_unstable();
    for old in seqs.iter().rev().skip(1) {
        fs::remove_file(snap_path(dir, *old))?;
    }
    m.snapshots.incr();
    m.snapshot_us.record_duration(t0.elapsed());
    Ok(path)
}

/// Load the newest valid snapshot in `dir`, returning `(seq, payload)`.
/// Corrupt or truncated images are skipped in favor of older ones;
/// `None` means no usable snapshot exists (recover from the WAL alone).
pub fn load_latest_snapshot(dir: &Path) -> Result<Option<(u64, Vec<u8>)>> {
    if !dir.exists() {
        return Ok(None);
    }
    let mut seqs: Vec<u64> = fs::read_dir(dir)?
        .filter_map(|e| snap_seq(&e.ok()?.path()))
        .collect();
    seqs.sort_unstable();
    for seq in seqs.into_iter().rev() {
        let mut buf = Vec::new();
        File::open(snap_path(dir, seq))?.read_to_end(&mut buf)?;
        if let Some(payload) = validate(&buf, seq) {
            return Ok(Some((seq, payload)));
        }
    }
    Ok(None)
}

fn validate(buf: &[u8], seq: u64) -> Option<Vec<u8>> {
    let head = MAGIC.len() + 8 + 8;
    if buf.len() < head + 4 || &buf[..MAGIC.len()] != MAGIC {
        return None;
    }
    let file_seq =
        u64::from_le_bytes(buf[MAGIC.len()..MAGIC.len() + 8].try_into().ok()?);
    let len = u64::from_le_bytes(
        buf[MAGIC.len() + 8..MAGIC.len() + 16].try_into().ok()?,
    ) as usize;
    if file_seq != seq || buf.len() != head + len + 4 {
        return None;
    }
    let payload = &buf[head..head + len];
    let crc = u32::from_le_bytes(buf[head + len..].try_into().ok()?);
    if crc32(payload) != crc {
        return None;
    }
    Some(payload.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "pallas-snap-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_and_prune() {
        let dir = tmpdir("rt");
        assert!(load_latest_snapshot(&dir).unwrap().is_none());
        write_snapshot(&dir, 10, b"ten").unwrap();
        write_snapshot(&dir, 20, b"twenty").unwrap();
        write_snapshot(&dir, 30, b"thirty").unwrap();
        let (seq, payload) = load_latest_snapshot(&dir).unwrap().unwrap();
        assert_eq!((seq, payload.as_slice()), (30, b"thirty".as_slice()));
        // Newest + one fallback generation survive pruning.
        let n = fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                snap_seq(&e.as_ref().unwrap().path()).is_some()
            })
            .count();
        assert_eq!(n, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_newest_falls_back_to_older() {
        let dir = tmpdir("fallback");
        write_snapshot(&dir, 5, b"good-old").unwrap();
        let newest = write_snapshot(&dir, 9, b"good-new").unwrap();
        // Flip a payload byte in the newest image.
        let mut buf = fs::read(&newest).unwrap();
        let off = MAGIC.len() + 16;
        buf[off] ^= 0xFF;
        fs::write(&newest, &buf).unwrap();
        let (seq, payload) = load_latest_snapshot(&dir).unwrap().unwrap();
        assert_eq!((seq, payload.as_slice()), (5, b"good-old".as_slice()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_image_rejected() {
        let dir = tmpdir("trunc");
        let p = write_snapshot(&dir, 7, b"payload-bytes").unwrap();
        let bytes = fs::metadata(&p).unwrap().len();
        OpenOptions::new()
            .write(true)
            .open(&p)
            .unwrap()
            .set_len(bytes - 2)
            .unwrap();
        assert!(load_latest_snapshot(&dir).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}
