//! Segmented append-only write-ahead log.
//!
//! A [`Wal`] is a directory of fixed-size segment files. Each segment is
//! named `{base:020}.wal` where `base` is the sequence number of its
//! first record; records are dense within a segment, so any record's
//! sequence number is derivable from its position. Record framing is
//! `[len u32 LE][crc32 u32 LE][payload]`.
//!
//! The write path is two-phase so callers can hold their engine lock
//! only for ordering:
//!
//! 1. [`Wal::append`] — buffer the framed record, assign the next
//!    sequence number. Called *under* the caller's engine lock so log
//!    order equals apply order.
//! 2. [`Wal::commit`] — make everything up to a sequence number durable
//!    according to the [`FsyncPolicy`]. Called *after* releasing the
//!    engine lock, before acking the client. Group commit: one fsync
//!    covers every record flushed so far, and concurrent committers
//!    whose records were covered by another thread's fsync return
//!    without syscalls.
//!
//! [`Wal::replay`] is a static pass over the directory used before
//! opening: it validates every frame, applies valid records in order,
//! and **physically truncates** the first torn/corrupt record and
//! everything after it (including later segment files) so the reopened
//! log continues from the last durable record.

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use super::{crc32, metrics, FsyncPolicy};
use crate::{Error, Result};

/// Frame header: `len: u32` + `crc: u32`.
const HEADER: u64 = 8;
/// Upper bound on a single record payload (matches the KV value cap with
/// headroom); a length field above this is treated as corruption.
const MAX_RECORD: u32 = 1 << 30;

fn segment_path(dir: &Path, base: u64) -> PathBuf {
    dir.join(format!("{base:020}.wal"))
}

/// Parse `{base:020}.wal` back to its base sequence number.
fn segment_base(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    let stem = name.strip_suffix(".wal")?;
    if stem.len() != 20 || !stem.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    stem.parse().ok()
}

/// Sorted list of `(base_seq, path, file_bytes)` for every segment in
/// `dir`.
fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf, u64)>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if let Some(base) = segment_base(&path) {
            let bytes = fs::metadata(&path)?.len();
            out.push((base, path, bytes));
        }
    }
    out.sort_by_key(|(base, _, _)| *base);
    Ok(out)
}

fn fsync_dir(dir: &Path) -> Result<()> {
    // Persist directory entries (new/renamed/removed segment files).
    File::open(dir)?.sync_all()?;
    Ok(())
}

/// Outcome of [`Wal::replay`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplayStats {
    /// Sequence number the next append will receive (one past the last
    /// valid record; `from_seq` if the log held nothing at or after it).
    pub next_seq: u64,
    /// Records applied (at or after `from_seq`).
    pub replayed: u64,
    /// Torn or corrupt records dropped from the tail (including any
    /// records stranded in segments after the corruption point).
    pub truncated: u64,
}

struct Segment {
    base: u64,
    path: PathBuf,
    bytes: u64,
}

struct WalInner {
    dir: PathBuf,
    writer: BufWriter<File>,
    /// Base sequence number of the active segment.
    seg_base: u64,
    /// Bytes written to the active segment (buffered included).
    seg_bytes: u64,
    /// Sequence number the next append receives.
    next_seq: u64,
    /// Closed (rotated-out) segments, oldest first.
    closed: Vec<Segment>,
    /// Records appended since the last fsync.
    unsynced: u64,
}

/// Segmented append-only log with group-commit durability.
///
/// All methods take `&self`; the log is internally synchronized and is
/// shared across engine threads behind an `Arc`.
pub struct Wal {
    inner: Mutex<WalInner>,
    /// Durability watermark: every seq `< synced` is on disk. Held
    /// across the fsync so committers whose records are already covered
    /// return immediately and concurrent committers serialize into one
    /// fsync per wave.
    synced: Mutex<u64>,
    fsync: FsyncPolicy,
    segment_bytes: u64,
}

impl Wal {
    /// Replay every valid record with sequence ≥ `from_seq` in order,
    /// calling `apply(seq, payload)` for each.
    ///
    /// Corruption handling: the first frame that is torn (header or
    /// payload runs past end-of-file), oversized, or CRC-mismatched ends
    /// the log. The containing file is truncated to the last valid
    /// frame and any later segment files are deleted — they are beyond
    /// the corruption point and unreachable. Dropped records count into
    /// [`ReplayStats::truncated`] and `recovery.truncated_records`.
    pub fn replay(
        dir: &Path,
        from_seq: u64,
        mut apply: impl FnMut(u64, &[u8]),
    ) -> Result<ReplayStats> {
        let m = metrics();
        let segments = list_segments(dir)?;
        let mut stats = ReplayStats { next_seq: from_seq, ..Default::default() };
        let mut corrupt_at: Option<usize> = None;
        for (idx, (base, path, _)) in segments.iter().enumerate() {
            let mut buf = Vec::new();
            File::open(path)?.read_to_end(&mut buf)?;
            let mut off = 0usize;
            let mut seq = *base;
            let mut valid_end = 0usize;
            let mut torn = false;
            while buf.len() - off >= HEADER as usize {
                let len =
                    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
                let crc =
                    u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap());
                let start = off + HEADER as usize;
                if len > MAX_RECORD || buf.len() - start < len as usize {
                    torn = true;
                    break;
                }
                let payload = &buf[start..start + len as usize];
                if crc32(payload) != crc {
                    torn = true;
                    break;
                }
                if seq >= from_seq {
                    apply(seq, payload);
                    stats.replayed += 1;
                }
                seq += 1;
                off = start + len as usize;
                valid_end = off;
            }
            let trailing = buf.len() - valid_end;
            if torn || trailing > 0 {
                if trailing > 0 {
                    // Partial frame bytes (or a whole bad record) at the
                    // tail: count one dropped record and cut it off so
                    // future appends extend a clean log.
                    stats.truncated += 1;
                    OpenOptions::new()
                        .write(true)
                        .open(path)?
                        .set_len(valid_end as u64)?;
                    fsync_dir(dir)?;
                }
                if torn {
                    corrupt_at = Some(idx);
                    stats.next_seq = stats.next_seq.max(seq);
                    break;
                }
            }
            stats.next_seq = stats.next_seq.max(seq);
        }
        if let Some(idx) = corrupt_at {
            // Segments past the corruption point are unreachable; delete
            // them so the reopened log is contiguous.
            for (base, path, bytes) in &segments[idx + 1..] {
                stats.truncated +=
                    estimate_records(*base, *bytes, &segments[idx + 1..]);
                fs::remove_file(path)?;
            }
            if idx + 1 < segments.len() {
                fsync_dir(dir)?;
            }
        }
        m.replayed.add(stats.replayed);
        m.truncated.add(stats.truncated);
        Ok(stats)
    }

    /// Open (or create) the log in `dir` for appending. `next_seq` is
    /// the sequence number the next append must receive — pass
    /// [`ReplayStats::next_seq`] from the preceding replay. Appends
    /// continue in the last segment if it has room, else a new segment
    /// is created.
    pub fn open(
        dir: &Path,
        next_seq: u64,
        segment_bytes: u64,
        fsync: FsyncPolicy,
    ) -> Result<Wal> {
        fs::create_dir_all(dir)?;
        let mut segments = list_segments(dir)?;
        let (seg_base, seg_bytes, file) = match segments.last() {
            Some((base, path, bytes)) if *bytes < segment_bytes => {
                let f = OpenOptions::new().append(true).open(path)?;
                let (base, bytes) = (*base, *bytes);
                segments.pop();
                (base, bytes, f)
            }
            _ => {
                let path = segment_path(dir, next_seq);
                let f = OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&path)?;
                fsync_dir(dir)?;
                (next_seq, 0, f)
            }
        };
        let closed = segments
            .into_iter()
            .map(|(base, path, bytes)| Segment { base, path, bytes })
            .collect();
        Ok(Wal {
            inner: Mutex::new(WalInner {
                dir: dir.to_path_buf(),
                writer: BufWriter::new(file),
                seg_base,
                seg_bytes,
                next_seq,
                closed,
                unsynced: 0,
            }),
            // Everything already in the files was read back by replay,
            // so every seq < next_seq is durable at open.
            synced: Mutex::new(next_seq),
            fsync,
            segment_bytes,
        })
    }

    /// Append one record, returning its sequence number.
    ///
    /// Call under the engine lock that orders mutations, so the log
    /// order matches the apply order. The record is buffered — it is not
    /// durable until a [`commit`](Wal::commit) (or rotation) covers it.
    pub fn append(&self, payload: &[u8]) -> Result<u64> {
        let m = metrics();
        let mut g = self.inner.lock().unwrap();
        let seq = g.next_seq;
        let len = payload.len() as u32;
        if len > MAX_RECORD {
            return Err(Error::Config(format!(
                "wal record too large: {len} bytes"
            )));
        }
        g.writer.write_all(&len.to_le_bytes())?;
        g.writer.write_all(&crc32(payload).to_le_bytes())?;
        g.writer.write_all(payload)?;
        g.next_seq += 1;
        g.seg_bytes += HEADER + payload.len() as u64;
        g.unsynced += 1;
        m.appends.incr();
        m.bytes.add(HEADER + payload.len() as u64);
        if g.seg_bytes >= self.segment_bytes {
            self.rotate(&mut g)?;
        }
        Ok(seq)
    }

    /// Close the active segment and start a new one. The closing
    /// segment is flushed and fsynced so closed segments are always
    /// fully durable (this keeps [`commit`](Wal::commit)'s bookkeeping
    /// honest: a group fsync of the active file covers everything).
    fn rotate(&self, g: &mut WalInner) -> Result<()> {
        g.writer.flush()?;
        g.writer.get_ref().sync_data()?;
        let new_base = g.next_seq;
        let old_path = segment_path(&g.dir, g.seg_base);
        let new_path = segment_path(&g.dir, new_base);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&new_path)?;
        fsync_dir(&g.dir)?;
        let old = Segment {
            base: g.seg_base,
            path: old_path,
            bytes: g.seg_bytes,
        };
        g.closed.push(old);
        g.writer = BufWriter::new(file);
        g.seg_base = new_base;
        g.seg_bytes = 0;
        g.unsynced = 0;
        metrics().rotations.incr();
        Ok(())
    }

    /// Make the record with sequence `seq` durable per the policy.
    /// Call after releasing the engine lock, before acking the client.
    pub fn commit(&self, seq: u64) -> Result<()> {
        match self.fsync {
            FsyncPolicy::Off => Ok(()),
            FsyncPolicy::EveryOp => self.sync_up_to(seq + 1),
            FsyncPolicy::EveryN(n) => {
                let due = self.inner.lock().unwrap().unsynced >= n.max(1);
                if due {
                    self.sync_up_to(seq + 1)
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Flush buffers and fsync the active segment unconditionally (e.g.
    /// before taking a snapshot or shutting down cleanly).
    pub fn sync(&self) -> Result<()> {
        let target = self.inner.lock().unwrap().next_seq;
        self.sync_up_to(target)
    }

    /// Group commit: ensure every seq `< target_excl` is on disk. One
    /// thread performs the fsync for the whole wave; threads whose
    /// records are already covered return without syscalls.
    fn sync_up_to(&self, target_excl: u64) -> Result<()> {
        let m = metrics();
        let mut synced = self.synced.lock().unwrap();
        if *synced >= target_excl {
            return Ok(());
        }
        // Snapshot the active file and the buffered frontier under the
        // inner lock: every seq < upto either sits in this file or in a
        // closed segment (fsynced at rotation), so one sync_data covers it.
        let (file, upto) = {
            let mut g = self.inner.lock().unwrap();
            g.writer.flush()?;
            g.unsynced = 0;
            (g.writer.get_ref().try_clone()?, g.next_seq)
        };
        let t0 = Instant::now();
        file.sync_data()?;
        m.fsyncs.incr();
        m.fsync_us.record_duration(t0.elapsed());
        *synced = upto;
        Ok(())
    }

    /// Sequence number of the first record still present (base of the
    /// oldest segment).
    pub fn first_seq(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.closed.first().map(|s| s.base).unwrap_or(g.seg_base)
    }

    /// Sequence number the next append will receive.
    pub fn next_seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Reclaim closed segments whose records are *all* ≤ `horizon`
    /// (i.e. covered by a snapshot at `horizon`). Returns the number of
    /// segments removed.
    pub fn truncate_below(&self, horizon: u64) -> Result<usize> {
        let mut g = self.inner.lock().unwrap();
        let mut removed = 0;
        while !g.closed.is_empty() {
            // closed[0] spans [base, next_base): deletable when its last
            // record (next_base - 1) is ≤ horizon.
            let next_base =
                g.closed.get(1).map(|s| s.base).unwrap_or(g.seg_base);
            if next_base > horizon.saturating_add(1) {
                break;
            }
            let seg = g.closed.remove(0);
            fs::remove_file(&seg.path)?;
            removed += 1;
        }
        if removed > 0 {
            fsync_dir(&g.dir)?;
        }
        Ok(removed)
    }

    /// Broker retention: drop oldest closed segments while over either
    /// cap (`0` = unlimited). The active segment never drops. Returns
    /// bytes freed.
    pub fn retain(&self, max_segments: usize, max_bytes: u64) -> Result<u64> {
        let mut g = self.inner.lock().unwrap();
        let mut freed = 0u64;
        loop {
            let total: u64 =
                g.seg_bytes + g.closed.iter().map(|s| s.bytes).sum::<u64>();
            let over_count = max_segments > 0 && g.closed.len() > max_segments;
            let over_bytes = max_bytes > 0 && total > max_bytes;
            if g.closed.is_empty() || (!over_count && !over_bytes) {
                break;
            }
            let seg = g.closed.remove(0);
            fs::remove_file(&seg.path)?;
            freed += seg.bytes;
        }
        if freed > 0 {
            fsync_dir(&g.dir)?;
        }
        Ok(freed)
    }
}

/// Rough record count for a segment being discarded during replay (we
/// never parsed it); assume average record size from the sibling set,
/// falling back to "at least one".
fn estimate_records(_base: u64, bytes: u64, _rest: &[(u64, PathBuf, u64)]) -> u64 {
    if bytes == 0 {
        0
    } else {
        1.max(bytes / 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "pallas-wal-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn replay_all(dir: &Path) -> (Vec<(u64, Vec<u8>)>, ReplayStats) {
        let mut got = Vec::new();
        let stats =
            Wal::replay(dir, 0, |seq, p| got.push((seq, p.to_vec()))).unwrap();
        (got, stats)
    }

    #[test]
    fn append_replay_roundtrip() {
        let dir = tmpdir("roundtrip");
        let wal =
            Wal::open(&dir, 0, 1 << 20, FsyncPolicy::EveryOp).unwrap();
        for i in 0..100u32 {
            let seq = wal.append(format!("rec-{i}").as_bytes()).unwrap();
            assert_eq!(seq, i as u64);
            wal.commit(seq).unwrap();
        }
        drop(wal);
        let (got, stats) = replay_all(&dir);
        assert_eq!(stats.replayed, 100);
        assert_eq!(stats.truncated, 0);
        assert_eq!(stats.next_seq, 100);
        assert_eq!(got.len(), 100);
        assert_eq!(got[42], (42, b"rec-42".to_vec()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_and_reopen_continue_sequence() {
        let dir = tmpdir("rotate");
        // Tiny segments force many rotations.
        let wal = Wal::open(&dir, 0, 4096, FsyncPolicy::Off).unwrap();
        let payload = vec![7u8; 512];
        for _ in 0..64 {
            wal.append(&payload).unwrap();
        }
        wal.sync().unwrap();
        drop(wal);
        assert!(list_segments(&dir).unwrap().len() > 1);
        // Reopen and keep appending: sequence numbers must continue.
        let (got, stats) = replay_all(&dir);
        assert_eq!(got.len(), 64);
        let wal =
            Wal::open(&dir, stats.next_seq, 4096, FsyncPolicy::Off).unwrap();
        assert_eq!(wal.append(b"more").unwrap(), 64);
        wal.sync().unwrap();
        drop(wal);
        let (got, stats) = replay_all(&dir);
        assert_eq!(stats.next_seq, 65);
        assert_eq!(got.last().unwrap(), &(64, b"more".to_vec()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_truncated_and_appendable() {
        let dir = tmpdir("torn");
        let wal =
            Wal::open(&dir, 0, 1 << 20, FsyncPolicy::EveryOp).unwrap();
        for i in 0..10u32 {
            wal.append(format!("keep-{i}").as_bytes()).unwrap();
        }
        wal.sync().unwrap();
        drop(wal);
        // Tear the tail: chop the last record mid-payload.
        let (_, path, bytes) = list_segments(&dir).unwrap().pop().unwrap();
        OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(bytes - 3)
            .unwrap();
        let (got, stats) = replay_all(&dir);
        assert_eq!(stats.replayed, 9);
        assert_eq!(stats.truncated, 1);
        assert_eq!(stats.next_seq, 9);
        assert_eq!(got.len(), 9);
        // The torn bytes were physically removed: appends after reopen
        // replay cleanly.
        let wal =
            Wal::open(&dir, stats.next_seq, 1 << 20, FsyncPolicy::EveryOp)
                .unwrap();
        let seq = wal.append(b"after-tear").unwrap();
        assert_eq!(seq, 9);
        wal.commit(seq).unwrap();
        drop(wal);
        let (got, stats) = replay_all(&dir);
        assert_eq!(stats.truncated, 0);
        assert_eq!(got.len(), 10);
        assert_eq!(got[9], (9, b"after-tear".to_vec()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_crc_stops_replay() {
        let dir = tmpdir("crc");
        let wal =
            Wal::open(&dir, 0, 1 << 20, FsyncPolicy::EveryOp).unwrap();
        for i in 0..5u32 {
            wal.append(format!("r{i}").as_bytes()).unwrap();
        }
        wal.sync().unwrap();
        drop(wal);
        // Flip a payload byte of record 2 (each frame is 8 + 2 bytes).
        let (_, path, _) = list_segments(&dir).unwrap().pop().unwrap();
        let mut buf = fs::read(&path).unwrap();
        let frame = 8 + 2;
        buf[2 * frame + 8] ^= 0xFF;
        fs::write(&path, &buf).unwrap();
        let (got, stats) = replay_all(&dir);
        // Records 0 and 1 survive; 2..5 are after the corruption point.
        assert_eq!(got.len(), 2);
        assert!(stats.truncated >= 1);
        assert_eq!(stats.next_seq, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncate_below_reclaims_snapshotted_segments() {
        let dir = tmpdir("reclaim");
        let wal = Wal::open(&dir, 0, 4096, FsyncPolicy::Off).unwrap();
        let payload = vec![1u8; 512];
        for _ in 0..64 {
            wal.append(&payload).unwrap();
        }
        wal.sync().unwrap();
        let before = list_segments(&dir).unwrap().len();
        assert!(before > 2);
        // Snapshot at seq 40 → every segment whose records are all ≤ 40
        // goes away; replay from 41 still works.
        let removed = wal.truncate_below(40).unwrap();
        assert!(removed > 0);
        assert!(wal.first_seq() > 0);
        drop(wal);
        let mut seqs = Vec::new();
        let stats = Wal::replay(&dir, 41, |s, _| seqs.push(s)).unwrap();
        assert_eq!(stats.next_seq, 64);
        assert_eq!(seqs, (41..64).collect::<Vec<_>>());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn retain_drops_oldest_segments() {
        let dir = tmpdir("retain");
        let wal = Wal::open(&dir, 0, 4096, FsyncPolicy::Off).unwrap();
        let payload = vec![2u8; 512];
        for _ in 0..64 {
            wal.append(&payload).unwrap();
        }
        wal.sync().unwrap();
        let freed = wal.retain(2, 0).unwrap();
        assert!(freed > 0);
        let first = wal.first_seq();
        assert!(first > 0);
        drop(wal);
        // Remaining records replay from the new first seq.
        let mut seqs = Vec::new();
        Wal::replay(&dir, first, |s, _| seqs.push(s)).unwrap();
        assert_eq!(seqs.first().copied(), Some(first));
        assert_eq!(seqs.last().copied(), Some(63));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_concurrent_appenders() {
        let dir = tmpdir("group");
        let wal = std::sync::Arc::new(
            Wal::open(&dir, 0, 1 << 20, FsyncPolicy::EveryOp).unwrap(),
        );
        std::thread::scope(|s| {
            for t in 0..4 {
                let wal = wal.clone();
                s.spawn(move || {
                    for i in 0..50 {
                        let seq = wal
                            .append(format!("t{t}-{i}").as_bytes())
                            .unwrap();
                        wal.commit(seq).unwrap();
                    }
                });
            }
        });
        assert_eq!(wal.next_seq(), 200);
        drop(wal);
        let (got, stats) = replay_all(&dir);
        assert_eq!(stats.replayed, 200);
        // Sequences are dense and ordered.
        for (i, (seq, _)) in got.iter().enumerate() {
            assert_eq!(*seq, i as u64);
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
