//! Pipelined KV client: N in-flight requests share one socket.
//!
//! The original client held a mutex across every write+read pair, so a
//! connection served exactly one round trip at a time — redis-py's default
//! behaviour, and the bottleneck the paper's overlapped-resolution
//! patterns exist to avoid. This client splits submission from
//! completion: a writer serializes requests onto the socket *in order*
//! (the queue push and the frame write happen under one lock, so queue
//! order always equals wire order), and a dedicated reader thread matches
//! FIFO responses back to per-request completion handles
//! ([`Pending`](crate::ops::Pending)). N submitters now share one
//! round-trip stream instead of paying N serialized round trips.
//!
//! The blocking API (`get`/`set`/...) survives unchanged as submit+wait,
//! so existing callers see identical semantics — they just stop queueing
//! behind each other's wire time. Server-side blocking ops (`WaitGet`,
//! `BRPop`) still park the response stream for their duration, exactly as
//! the old mutex did; callers that care use a dedicated connection (see
//! [`TcpKvConnector::wait_get`](crate::store::TcpKvConnector)).
//!
//! Failure is eager and total: when the connection dies (server gone,
//! torn frame, local shutdown) every in-flight handle completes with the
//! error and later submissions fail fast. Dropping the client shuts the
//! socket down and joins the reader thread — no thread leak, no handle
//! left parked.

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::codec::Bytes;
use crate::error::{Error, Result};
use crate::kv::protocol::{read_frame, write_frame, Request, Response};
use crate::kv::state::PubSubMsg;
use crate::ops::{pending, Completer, Op, OpResult, Pending};

/// How a raw wire [`Response`] completes a submitted request.
enum Sink {
    /// Complete with the raw response (the request/response API).
    Resp(Completer<Response>),
    /// Convert by op shape and complete a typed [`OpResult`] handle.
    Op { kind: OpKind, completer: Completer<OpResult> },
}

/// Expected response shape of a submitted [`Op`].
#[derive(Clone, Copy)]
enum OpKind {
    Unit,
    Value,
    Values,
    Bool,
    Bools,
}

fn convert(kind: OpKind, resp: Response) -> Result<OpResult> {
    match (kind, resp) {
        (_, Response::Error(msg)) => Err(Error::Protocol(msg)),
        (OpKind::Unit, Response::Ok) | (OpKind::Unit, Response::Int(_)) => {
            Ok(OpResult::Unit)
        }
        (OpKind::Value, Response::Value(v)) => {
            Ok(OpResult::Value(v.map(|b| Arc::new(b.0))))
        }
        (OpKind::Values, Response::Values(v)) => Ok(OpResult::Values(
            v.into_iter().map(|o| o.map(|b| Arc::new(b.0))).collect(),
        )),
        (OpKind::Bool, Response::Int(v)) => Ok(OpResult::Bool(v == 1)),
        (OpKind::Bools, Response::Bools(v)) => Ok(OpResult::Bools(v)),
        (_, other) => {
            Err(Error::Protocol(format!("unexpected response {other:?}")))
        }
    }
}

fn op_request(op: Op) -> (Request, OpKind) {
    match op {
        Op::Put { key, data } => {
            (Request::Set { key, value: Bytes(data) }, OpKind::Unit)
        }
        Op::Get { key } => (Request::Get { key }, OpKind::Value),
        Op::Evict { key } => (Request::Del { key }, OpKind::Unit),
        Op::Exists { key } => (Request::Exists { key }, OpKind::Bool),
        Op::PutMany { items } => (
            Request::MPut {
                items: items.into_iter().map(|(k, v)| (k, Bytes(v))).collect(),
            },
            OpKind::Unit,
        ),
        Op::GetMany { keys } => (Request::MGet { keys }, OpKind::Values),
        Op::DeleteMany { keys } => (Request::MDel { keys }, OpKind::Unit),
        Op::ExistsMany { keys } => (Request::MExists { keys }, OpKind::Bools),
    }
}

fn complete_sink(sink: Sink, result: Result<Response>) {
    match sink {
        Sink::Resp(c) => c.complete(result),
        Sink::Op { kind, completer } => {
            completer.complete(result.and_then(|resp| convert(kind, resp)))
        }
    }
}

/// In-flight completions, FIFO-matched to responses by the reader.
struct PendingQueue {
    sinks: VecDeque<Sink>,
    /// Set once the connection died; later submissions fail fast with it.
    dead: Option<Error>,
}

fn fail_all(queue: &Mutex<PendingQueue>, err: Error) {
    let mut q = queue.lock().unwrap();
    if q.dead.is_none() {
        q.dead = Some(err.clone());
    }
    for sink in q.sinks.drain(..) {
        complete_sink(sink, Err(err.clone()));
    }
}

fn reader_loop(stream: TcpStream, queue: Arc<Mutex<PendingQueue>>) {
    let mut reader = std::io::BufReader::with_capacity(1 << 18, stream);
    loop {
        match read_frame::<_, Response>(&mut reader) {
            Ok(Some(resp)) => {
                let sink = queue.lock().unwrap().sinks.pop_front();
                match sink {
                    Some(sink) => complete_sink(sink, Ok(resp)),
                    None => {
                        // A response with no matching request breaks the
                        // FIFO invariant; nothing after it can be trusted.
                        fail_all(
                            &queue,
                            Error::Protocol(
                                "unsolicited response frame".into(),
                            ),
                        );
                        return;
                    }
                }
            }
            Ok(None) => {
                fail_all(
                    &queue,
                    Error::Connector("kv server closed connection".into()),
                );
                return;
            }
            Err(e) => {
                fail_all(&queue, e);
                return;
            }
        }
    }
}

/// Thread-safe pipelined request/response client.
pub struct KvClient {
    writer: Mutex<std::io::BufWriter<TcpStream>>,
    queue: Arc<Mutex<PendingQueue>>,
    /// Kept for shutdown: unblocks the parked reader on drop.
    stream: TcpStream,
    reader: Option<std::thread::JoinHandle<()>>,
    pub addr: SocketAddr,
}

impl KvClient {
    pub fn connect(addr: SocketAddr) -> Result<KvClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let queue = Arc::new(Mutex::new(PendingQueue {
            sinks: VecDeque::new(),
            dead: None,
        }));
        // Clone both halves before spawning the reader, so an error here
        // can never leave a reader thread parked on a live socket.
        let writer_stream = stream.try_clone()?;
        let reader_stream = stream.try_clone()?;
        let reader_queue = queue.clone();
        let reader = std::thread::Builder::new()
            .name(format!("kv-pipe-{}", addr.port()))
            .spawn(move || reader_loop(reader_stream, reader_queue))
            .map_err(|e| {
                Error::Connector(format!("spawn kv pipeline reader: {e}"))
            })?;
        Ok(KvClient {
            writer: Mutex::new(std::io::BufWriter::with_capacity(
                1 << 18,
                writer_stream,
            )),
            queue,
            stream,
            reader: Some(reader),
            addr,
        })
    }

    /// Requests submitted but not yet completed (diagnostics).
    pub fn in_flight(&self) -> usize {
        self.queue.lock().unwrap().sinks.len()
    }

    /// Serialize one request onto the shared socket and register its
    /// completion sink. The writer lock spans the queue push and the
    /// frame write so queue order always equals wire order — the FIFO
    /// invariant the reader's response matching relies on.
    fn submit_sink(&self, req: &Request, sink: Sink) {
        let mut writer = self.writer.lock().unwrap();
        {
            let mut q = self.queue.lock().unwrap();
            if let Some(e) = &q.dead {
                let err = e.clone();
                drop(q);
                complete_sink(sink, Err(err));
                return;
            }
            q.sinks.push_back(sink);
        }
        if let Err(e) = write_frame(&mut *writer, req) {
            drop(writer);
            fail_all(&self.queue, e);
        }
    }

    /// Submit a raw request; the handle completes when its response
    /// arrives. Responses are matched FIFO, so a submission is also an
    /// ordering point: later requests on this client execute after it.
    ///
    /// `Subscribe` is rejected: it flips the server connection into push
    /// mode, which breaks the FIFO request/response contract the whole
    /// pipeline is matched by (and would poison every other user of this
    /// client). Subscriptions get their own connection — [`KvSubscriber`].
    pub fn submit(&self, req: Request) -> Pending<Response> {
        if matches!(req, Request::Subscribe { .. }) {
            return Pending::ready(Err(Error::Config(
                "Subscribe is push-mode; use KvSubscriber".into(),
            )));
        }
        let (completer, handle) = pending();
        self.submit_sink(&req, Sink::Resp(completer));
        handle
    }

    /// Submit a typed connector op (the native path behind
    /// [`Connector::submit`](crate::store::Connector::submit) for TCP
    /// channels).
    pub fn submit_op(&self, op: Op) -> Pending<OpResult> {
        let (completer, handle) = pending();
        let (req, kind) = op_request(op);
        self.submit_sink(&req, Sink::Op { kind, completer });
        handle
    }

    /// Blocking round trip: submit and wait.
    fn call(&self, req: Request) -> Result<Response> {
        match self.submit(req).wait()? {
            Response::Error(msg) => Err(Error::Protocol(msg)),
            resp => Ok(resp),
        }
    }

    fn expect_ok(&self, req: Request) -> Result<()> {
        match self.call(req)? {
            Response::Ok => Ok(()),
            other => Err(Error::Protocol(format!("expected Ok, got {other:?}"))),
        }
    }

    fn expect_int(&self, req: Request) -> Result<i64> {
        match self.call(req)? {
            Response::Int(v) => Ok(v),
            other => Err(Error::Protocol(format!("expected Int, got {other:?}"))),
        }
    }

    fn expect_value(&self, req: Request) -> Result<Option<Bytes>> {
        match self.call(req)? {
            Response::Value(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Value, got {other:?}")))
            }
        }
    }

    pub fn ping(&self) -> Result<()> {
        self.expect_ok(Request::Ping)
    }

    pub fn set(&self, key: &str, value: Bytes) -> Result<()> {
        self.expect_ok(Request::Set { key: key.into(), value })
    }

    pub fn set_nx(&self, key: &str, value: Bytes) -> Result<bool> {
        Ok(self.expect_int(Request::SetNx { key: key.into(), value })? == 1)
    }

    pub fn get(&self, key: &str) -> Result<Option<Bytes>> {
        self.expect_value(Request::Get { key: key.into() })
    }

    /// Batched set: one round trip for the whole batch.
    pub fn mput(&self, items: Vec<(String, Bytes)>) -> Result<()> {
        self.expect_ok(Request::MPut { items })
    }

    pub fn mget(&self, keys: &[String]) -> Result<Vec<Option<Bytes>>> {
        match self.call(Request::MGet { keys: keys.to_vec() })? {
            Response::Values(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Values, got {other:?}")))
            }
        }
    }

    /// Blocking get; `None` timeout waits forever. Server-side blocking:
    /// this parks the shared response stream until it resolves (use a
    /// dedicated connection for long waits).
    pub fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Bytes>> {
        self.expect_value(Request::WaitGet {
            key: key.into(),
            timeout_ms: timeout.map(|d| d.as_millis() as u64).unwrap_or(0),
        })
    }

    pub fn del(&self, key: &str) -> Result<bool> {
        Ok(self.expect_int(Request::Del { key: key.into() })? == 1)
    }

    /// Batched delete: one round trip; returns how many keys existed.
    pub fn mdel(&self, keys: &[String]) -> Result<i64> {
        self.expect_int(Request::MDel { keys: keys.to_vec() })
    }

    pub fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.expect_int(Request::Exists { key: key.into() })? == 1)
    }

    /// Batched existence check: one round trip, positionally aligned.
    pub fn mexists(&self, keys: &[String]) -> Result<Vec<bool>> {
        match self.call(Request::MExists { keys: keys.to_vec() })? {
            Response::Bools(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Bools, got {other:?}")))
            }
        }
    }

    pub fn incr(&self, key: &str, by: i64) -> Result<i64> {
        self.expect_int(Request::Incr { key: key.into(), by })
    }

    pub fn keys(&self, prefix: &str) -> Result<Vec<String>> {
        match self.call(Request::Keys { prefix: prefix.into() })? {
            Response::KeysList(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Keys, got {other:?}")))
            }
        }
    }

    pub fn publish(&self, channel: &str, payload: Bytes) -> Result<i64> {
        self.expect_int(Request::Publish { channel: channel.into(), payload })
    }

    pub fn lpush(&self, list: &str, value: Bytes) -> Result<()> {
        self.expect_ok(Request::LPush { list: list.into(), value })
    }

    pub fn brpop(
        &self,
        list: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Bytes>> {
        self.expect_value(Request::BRPop {
            list: list.into(),
            timeout_ms: timeout.map(|d| d.as_millis() as u64).unwrap_or(0),
        })
    }

    pub fn flush_all(&self) -> Result<()> {
        self.expect_ok(Request::FlushAll)
    }

    pub fn stats(&self) -> Result<(u64, u64, u64)> {
        match self.call(Request::Stats)? {
            Response::StatsReply { keys, bytes, ops } => Ok((keys, bytes, ops)),
            other => {
                Err(Error::Protocol(format!("expected Stats, got {other:?}")))
            }
        }
    }
}

impl Drop for KvClient {
    /// Shut the socket down (unparking the reader mid-`read_frame`) and
    /// reap the reader thread; any still-pending handles complete with a
    /// connection error on the way out.
    fn drop(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        if let Some(handle) = self.reader.take() {
            let _ = handle.join();
        }
    }
}

/// Dedicated subscription connection (push mode), like a Redis subscriber.
pub struct KvSubscriber {
    reader: Mutex<std::io::BufReader<TcpStream>>,
}

impl KvSubscriber {
    pub fn connect(addr: SocketAddr, channels: &[String]) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let mut writer = std::io::BufWriter::new(stream.try_clone()?);
        let mut reader = std::io::BufReader::with_capacity(1 << 18, stream);
        write_frame(
            &mut writer,
            &Request::Subscribe { channels: channels.to_vec() },
        )?;
        match read_frame::<_, Response>(&mut reader)? {
            Some(Response::Ok) => Ok(KvSubscriber {
                reader: Mutex::new(reader),
            }),
            other => Err(Error::Protocol(format!(
                "subscribe handshake failed: {other:?}"
            ))),
        }
    }

    /// Next pushed message. `Ok(None)` on timeout; error if disconnected.
    pub fn next(&self, timeout: Option<Duration>) -> Result<Option<PubSubMsg>> {
        let mut reader = self.reader.lock().unwrap();
        reader
            .get_ref()
            .set_read_timeout(timeout)
            .map_err(Error::from)?;
        match read_frame::<_, Response>(&mut *reader) {
            Ok(Some(Response::Message { channel, payload })) => {
                Ok(Some(PubSubMsg { channel, payload }))
            }
            Ok(Some(other)) => Err(Error::Protocol(format!(
                "unexpected push frame: {other:?}"
            ))),
            Ok(None) => Err(Error::StreamClosed("subscription ended".into())),
            Err(Error::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::KvServer;

    #[test]
    fn pipelined_submissions_complete_in_order() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        // Submit a window of writes then a read of each key *before*
        // waiting on anything: FIFO execution means every read sees its
        // write.
        let puts: Vec<_> = (0..32)
            .map(|i| {
                client.submit_op(Op::Put {
                    key: format!("p-{i}"),
                    data: vec![i as u8],
                })
            })
            .collect();
        let gets: Vec<_> = (0..32)
            .map(|i| client.submit_op(Op::Get { key: format!("p-{i}") }))
            .collect();
        for p in puts {
            p.wait().unwrap().into_unit().unwrap();
        }
        for (i, g) in gets.into_iter().enumerate() {
            assert_eq!(
                g.wait().unwrap().into_value().unwrap().map(|b| b.to_vec()),
                Some(vec![i as u8])
            );
        }
        assert_eq!(client.in_flight(), 0);
    }

    #[test]
    fn concurrent_threads_share_one_connection() {
        let server = KvServer::spawn().unwrap();
        let client = Arc::new(KvClient::connect(server.addr).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for i in 0..32 {
                        let key = format!("t{t}-{i}");
                        c.set(&key, Bytes(vec![t as u8, i as u8])).unwrap();
                        assert_eq!(
                            c.get(&key).unwrap(),
                            Some(Bytes(vec![t as u8, i as u8]))
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (keys, _, _) = client.stats().unwrap();
        assert_eq!(keys, 128);
    }

    #[test]
    fn server_death_fails_in_flight_and_later_ops() {
        let mut server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.ping().unwrap();
        // Park an op server-side, then kill the server under it.
        let parked = client.submit(Request::WaitGet {
            key: "never-set".into(),
            timeout_ms: 30_000,
        });
        std::thread::sleep(Duration::from_millis(30));
        server.shutdown();
        assert!(parked.wait().is_err(), "in-flight op must fail");
        // The pipe is dead: later submissions fail fast, without parking.
        let t0 = std::time::Instant::now();
        assert!(client.submit_op(Op::Get { key: "k".into() }).wait().is_err());
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(client.ping().is_err());
    }

    #[test]
    fn subscribe_is_rejected_not_pipelined() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let res = client
            .submit(Request::Subscribe { channels: vec!["c".into()] })
            .wait();
        assert!(res.is_err(), "push-mode request must not enter the pipe");
        // The pipe is unharmed: ordinary traffic keeps flowing.
        client.ping().unwrap();
    }

    #[test]
    fn drop_with_in_flight_op_reaps_reader() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let parked = client.submit(Request::WaitGet {
            key: "never-set".into(),
            timeout_ms: 30_000,
        });
        std::thread::sleep(Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        drop(client); // shuts the socket down and joins the reader
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "drop must not wait out the parked op"
        );
        assert!(parked.wait().is_err(), "orphaned handle completes with error");
    }
}
