//! Pipelined KV client: N in-flight requests share one socket.
//!
//! The original client held a mutex across every write+read pair, so a
//! connection served exactly one round trip at a time — redis-py's default
//! behaviour, and the bottleneck the paper's overlapped-resolution
//! patterns exist to avoid. This client splits submission from
//! completion: a writer serializes requests onto the socket *in order*
//! (the queue push and the frame write happen under one lock, so queue
//! order always equals wire order), and a dedicated reader thread matches
//! FIFO responses back to per-request completion handles
//! ([`Pending`](crate::ops::Pending)). N submitters now share one
//! round-trip stream instead of paying N serialized round trips.
//!
//! The blocking API (`get`/`set`/...) survives unchanged as submit+wait,
//! so existing callers see identical semantics — they just stop queueing
//! behind each other's wire time.
//!
//! Wire behaviour is tunable through [`ClientOptions`]
//! ([`KvClient::connect_with`]):
//!
//! * **Pipeline window** — a cap on FIFO in-flight ops. Submitters block
//!   (with any coalesced frames flushed first, so the window can drain)
//!   until a response frees a slot; `0` means unbounded, the historical
//!   behaviour.
//! * **Flush policy** — [`FlushPolicy::Immediate`] flushes the socket per
//!   frame; [`FlushPolicy::Coalesce`] buffers frames until `max_buffer`
//!   bytes accumulate or `max_delay` elapses (a background flusher thread
//!   enforces the deadline), batching many small requests into one
//!   syscall/packet. Blocking callers pay at most `max_delay` extra
//!   latency; pipelined bursts get fewer, larger writes.
//! * **Connect / write timeouts** — bound how long dialing and a stalled
//!   socket write may take.
//!
//! Long waits ride the out-of-band **watch plane**: [`KvClient::watch`]
//! arms a server-side watch under a client-chosen id and hands back a
//! completion handle; the reader thread routes the eventual
//! `Notify { id, .. }` push by that id instead of FIFO position, so a
//! parked watch shares the pipelined connection with ordinary traffic
//! without stalling it. [`KvClient::wait_get`] is built on it — no
//! dedicated connection, no server-side parking of the response stream.
//! (The wire-level `WaitGet`/`BRPop` requests still park FIFO when issued
//! raw; nothing in the client's own API submits them anymore except
//! `brpop`.)
//!
//! Failure is eager and total: when the connection dies (server gone,
//! torn frame, local shutdown) every in-flight handle *and every armed
//! watch* completes with the error and later submissions fail fast — a
//! watch whose server dies fails promptly instead of hanging. Dropping
//! the client shuts the socket down and joins the reader (and flusher)
//! threads — no thread leak, no handle left parked.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::codec::{
    get_varint, put_varint, Buf, Bytes, Decode, Encode, Reader,
};
use crate::error::{Error, Result};
use crate::kv::protocol::{
    decode_response_owned, read_frame, read_frame_raw, write_frame,
    write_frame_unflushed, Request, Response,
};
use crate::kv::state::PubSubMsg;
use crate::metrics::telemetry::{self, TelemetrySnapshot};
use crate::ops::{pending, Completer, Op, OpResult, Pending};

/// When a socket write should actually be flushed to the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FlushPolicy {
    /// Flush after every frame — lowest latency per op, one syscall per
    /// request. The default.
    #[default]
    Immediate,
    /// Buffer frames and flush when `max_buffer` bytes accumulate or
    /// `max_delay` elapses since the first unflushed byte, whichever
    /// comes first. Pipelined bursts coalesce into few large writes; a
    /// lone blocking op pays at most `max_delay` extra latency.
    Coalesce {
        /// Flush once this many buffered bytes accumulate.
        max_buffer: usize,
        /// Flush no later than this after the first unflushed frame.
        max_delay: Duration,
    },
}

/// Wire-behaviour tuning for [`KvClient::connect_with`]. The default is
/// byte-compatible with the historical client: unbounded pipeline window,
/// immediate flushes, OS-default timeouts.
///
/// Options are codec-encodable so connector descriptors
/// ([`crate::store::ConnectorDesc`]) can carry them inside proxies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClientOptions {
    /// Max FIFO requests in flight; submitters block when full. `0`
    /// (default) means unbounded.
    pub pipeline_window: usize,
    /// Write-coalescing policy (default: flush per frame).
    pub flush: FlushPolicy,
    /// Bound on dialing the server (default: OS connect timeout).
    pub connect_timeout: Option<Duration>,
    /// Bound on a single blocked socket write (default: none).
    pub write_timeout: Option<Duration>,
}

impl ClientOptions {
    /// Preset for pipelined bulk traffic: coalesce up to 64 KiB or
    /// 200 µs of frames per flush, unbounded window, no timeouts.
    pub fn coalescing() -> ClientOptions {
        ClientOptions {
            flush: FlushPolicy::Coalesce {
                max_buffer: 64 * 1024,
                max_delay: Duration::from_micros(200),
            },
            ..ClientOptions::default()
        }
    }
}

impl Encode for FlushPolicy {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            FlushPolicy::Immediate => put_varint(buf, 0),
            FlushPolicy::Coalesce { max_buffer, max_delay } => {
                put_varint(buf, 1);
                max_buffer.encode(buf);
                (max_delay.as_micros() as u64).encode(buf);
            }
        }
    }
}

impl Decode for FlushPolicy {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => FlushPolicy::Immediate,
            1 => FlushPolicy::Coalesce {
                max_buffer: Decode::decode(r)?,
                max_delay: Duration::from_micros(u64::decode(r)?),
            },
            t => {
                return Err(Error::Codec(format!("bad flush policy tag {t}")))
            }
        })
    }
}

impl Encode for ClientOptions {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.pipeline_window.encode(buf);
        self.flush.encode(buf);
        self.connect_timeout
            .map(|d| d.as_micros() as u64)
            .encode(buf);
        self.write_timeout.map(|d| d.as_micros() as u64).encode(buf);
    }
}

impl Decode for ClientOptions {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ClientOptions {
            pipeline_window: Decode::decode(r)?,
            flush: Decode::decode(r)?,
            connect_timeout: Option::<u64>::decode(r)?
                .map(Duration::from_micros),
            write_timeout: Option::<u64>::decode(r)?
                .map(Duration::from_micros),
        })
    }
}

/// Cached registry handles for the client's hot path (looked up once per
/// process). `in_flight` aggregates across every client in the process via
/// deltas; its high-water mark is the observed pipeline depth. The ratio
/// `ops / flushes` is the achieved write-coalescing factor.
struct ClientMetrics {
    ops: Arc<telemetry::Counter>,
    op_us: Arc<telemetry::Histogram>,
    in_flight: Arc<telemetry::Gauge>,
    flushes: Arc<telemetry::Counter>,
}

fn client_metrics() -> &'static ClientMetrics {
    static M: OnceLock<ClientMetrics> = OnceLock::new();
    M.get_or_init(|| ClientMetrics {
        ops: telemetry::counter("kv.client.ops"),
        op_us: telemetry::histogram("kv.client.op_us"),
        in_flight: telemetry::gauge("kv.client.in_flight"),
        flushes: telemetry::counter("kv.client.flushes"),
    })
}

/// How a raw wire [`Response`] completes a submitted request.
enum Sink {
    /// Complete with the raw response (the request/response API).
    Resp(Completer<Response>),
    /// Convert by op shape and complete a typed [`OpResult`] handle.
    Op { kind: OpKind, completer: Completer<OpResult> },
    /// FIFO ack of a `Watch` registration. `Ok` means armed (the real
    /// completion arrives out-of-band as a `Notify`); an error ack fails
    /// and removes the registered watch handle.
    WatchAck { id: u64 },
}

/// Expected response shape of a submitted [`Op`].
#[derive(Clone, Copy)]
enum OpKind {
    Unit,
    Value,
    Values,
    Bool,
    Bools,
}

fn convert(kind: OpKind, resp: Response) -> Result<OpResult> {
    match (kind, resp) {
        (_, Response::Error(msg)) => Err(Error::Protocol(msg)),
        (OpKind::Unit, Response::Ok) | (OpKind::Unit, Response::Int(_)) => {
            Ok(OpResult::Unit)
        }
        (OpKind::Value, Response::Value(v)) => {
            Ok(OpResult::Value(v.map(Buf::into_blob)))
        }
        (OpKind::Values, Response::Values(v)) => Ok(OpResult::Values(
            v.into_iter().map(|o| o.map(Buf::into_blob)).collect(),
        )),
        (OpKind::Bool, Response::Int(v)) => Ok(OpResult::Bool(v == 1)),
        (OpKind::Bools, Response::Bools(v)) => Ok(OpResult::Bools(v)),
        (_, other) => {
            Err(Error::Protocol(format!("unexpected response {other:?}")))
        }
    }
}

fn op_request(op: Op) -> (Request, OpKind) {
    match op {
        Op::Put { key, data } => {
            (Request::Set { key, value: Bytes(data) }, OpKind::Unit)
        }
        Op::Get { key } => (Request::Get { key }, OpKind::Value),
        Op::Evict { key } => (Request::Del { key }, OpKind::Unit),
        Op::Exists { key } => (Request::Exists { key }, OpKind::Bool),
        Op::PutMany { items } => (
            Request::MPut {
                items: items.into_iter().map(|(k, v)| (k, Bytes(v))).collect(),
            },
            OpKind::Unit,
        ),
        Op::GetMany { keys } => (Request::MGet { keys }, OpKind::Values),
        Op::DeleteMany { keys } => (Request::MDel { keys }, OpKind::Unit),
        Op::ExistsMany { keys } => (Request::MExists { keys }, OpKind::Bools),
        // Watches never enter the FIFO request/response pipe; submit_op
        // routes them through the watch plane before reaching here.
        Op::Watch { .. } => unreachable!("Watch routes through KvClient::watch"),
    }
}

fn complete_sink(queue: &QueueSync, sink: Sink, result: Result<Response>) {
    match sink {
        Sink::Resp(c) => c.complete(result),
        Sink::Op { kind, completer } => {
            completer.complete(result.and_then(|resp| convert(kind, resp)))
        }
        Sink::WatchAck { id } => {
            let failed = match result {
                Ok(Response::Error(msg)) => Some(Error::Protocol(msg)),
                Ok(_) => None, // armed; Notify will route by id
                Err(e) => Some(e),
            };
            if let Some(e) = failed {
                let watch = queue.q.lock().unwrap().watches.remove(&id);
                if let Some(c) = watch {
                    c.complete(Err(e));
                }
            }
        }
    }
}

/// One FIFO queue entry: the completion sink plus what the reader needs
/// to record the op's client span and slow-op entry when the response
/// lands (the span covers the full submit-to-complete round trip, so it
/// is recorded at completion time, not submit time).
struct PendingOp {
    started: Instant,
    /// Wall-clock span start in epoch microseconds; 0 when untraced.
    start_us: u64,
    /// `(trace_id, span_id, parent_span)` of the submit-side trace
    /// context; `None` when the op was untraced.
    trace: Option<(u64, u64, u64)>,
    /// Stable op label for metrics and the slow-op log.
    name: &'static str,
    sink: Sink,
}

/// In-flight completions: FIFO sinks matched by queue position, watch
/// completers routed out-of-band by id.
struct PendingQueue {
    /// FIFO pending ops, matched to responses by queue position.
    sinks: VecDeque<PendingOp>,
    /// Armed watches awaiting their `Notify` push.
    watches: HashMap<u64, Completer<Arc<Vec<u8>>>>,
    /// Set once the connection died; later submissions fail fast with it.
    dead: Option<Error>,
}

/// The pending queue plus the condvar that window-limited submitters park
/// on. `window == 0` (unbounded) lets the reader skip the per-response
/// notify entirely.
struct QueueSync {
    q: Mutex<PendingQueue>,
    cv: Condvar,
    window: usize,
}

fn fail_all(queue: &QueueSync, err: Error) {
    // Drain under the lock, complete outside it: completions may run
    // subscribed callbacks that take arbitrary locks of their own.
    let (sinks, watches) = {
        let mut q = queue.q.lock().unwrap();
        if q.dead.is_none() {
            q.dead = Some(err.clone());
        }
        (
            q.sinks.drain(..).collect::<Vec<_>>(),
            q.watches.drain().collect::<Vec<_>>(),
        )
    };
    // Submitters parked on a full window must observe `dead` and bail.
    queue.cv.notify_all();
    client_metrics().in_flight.add(-(sinks.len() as i64));
    for op in sinks {
        complete_sink(queue, op.sink, Err(err.clone()));
    }
    for (_, completer) in watches {
        completer.complete(Err(err.clone()));
    }
}

fn reader_loop(stream: TcpStream, queue: Arc<QueueSync>) {
    // Slow-op entries attribute to the server this pipe talks to.
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let mut reader = std::io::BufReader::with_capacity(1 << 18, stream);
    loop {
        // Read the raw body, then decode owned: value payloads become
        // windows over the frame's single allocation, so a bulk GET
        // reply is read off the socket once and never copied again.
        let body = match read_frame_raw(&mut reader) {
            Ok(Some(body)) => body,
            Ok(None) => {
                fail_all(
                    &queue,
                    Error::Connector("kv server closed connection".into()),
                );
                return;
            }
            Err(e) => {
                fail_all(&queue, e);
                return;
            }
        };
        match decode_response_owned(body) {
            Ok(Response::Notify { id, value }) => {
                // Out-of-band: routed by watch id, never FIFO-matched —
                // this is what keeps a parked watch from stalling the
                // shared response stream. An unknown id is a watch that
                // was disarmed after firing raced the wire; drop it.
                let watch = queue.q.lock().unwrap().watches.remove(&id);
                if let Some(completer) = watch {
                    completer.complete(Ok(value.into_blob()));
                }
            }
            Ok(resp) => {
                let sink = queue.q.lock().unwrap().sinks.pop_front();
                match sink {
                    Some(op) => {
                        if queue.window > 0 {
                            queue.cv.notify_all(); // a window slot freed
                        }
                        let m = client_metrics();
                        m.in_flight.add(-1);
                        let dur = op.started.elapsed();
                        m.op_us.record_duration(dur);
                        // Span + slow-op land before the completion so a
                        // caller that blocked on this op observes them.
                        let (trace_id, span_id) = match op.trace {
                            Some((trace_id, span_id, parent)) => {
                                telemetry::span_event(
                                    trace_id,
                                    span_id,
                                    parent,
                                    "kv.client",
                                    op.name,
                                    op.start_us,
                                    dur.as_micros() as u64,
                                );
                                (trace_id, span_id)
                            }
                            None => (0, 0),
                        };
                        telemetry::record_slow_op(
                            op.name, dur, trace_id, span_id, &peer,
                        );
                        complete_sink(&queue, op.sink, Ok(resp));
                    }
                    None => {
                        // A response with no matching request breaks the
                        // FIFO invariant; nothing after it can be trusted.
                        fail_all(
                            &queue,
                            Error::Protocol(
                                "unsolicited response frame".into(),
                            ),
                        );
                        return;
                    }
                }
            }
            Err(e) => {
                fail_all(&queue, e);
                return;
            }
        }
    }
}

/// Deadline state shared with the background flusher thread (coalescing
/// policy only).
struct FlushShared {
    state: Mutex<FlushState>,
    cv: Condvar,
}

struct FlushState {
    /// When the oldest unflushed frame was buffered; `None` = clean.
    dirty_since: Option<Instant>,
    stop: bool,
}

/// Enforces `FlushPolicy::Coalesce::max_delay`: waits for the buffer to
/// turn dirty, sleeps out the deadline, flushes. Inline threshold flushes
/// clear `dirty_since` so a quiet client costs zero wakeups.
fn flusher_loop(
    shared: Arc<FlushShared>,
    writer: Arc<Mutex<std::io::BufWriter<TcpStream>>>,
    queue: Arc<QueueSync>,
    max_delay: Duration,
) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.stop {
            return;
        }
        match st.dirty_since {
            None => st = shared.cv.wait(st).unwrap(),
            Some(dirtied) => {
                let due = dirtied + max_delay;
                let now = Instant::now();
                if now < due {
                    // Park until the deadline (or a stop/inline-flush
                    // notification), then re-check everything.
                    st = shared.cv.wait_timeout(st, due - now).unwrap().0;
                    continue;
                }
                st.dirty_since = None;
                drop(st);
                let res = {
                    let mut w = writer.lock().unwrap();
                    if w.buffer().is_empty() {
                        Ok(())
                    } else {
                        let r = w.flush();
                        if r.is_ok() {
                            client_metrics().flushes.incr();
                        }
                        r
                    }
                };
                if let Err(e) = res {
                    fail_all(&queue, e.into());
                    return;
                }
                st = shared.state.lock().unwrap();
            }
        }
    }
}

/// Thread-safe pipelined request/response client.
pub struct KvClient {
    writer: Arc<Mutex<std::io::BufWriter<TcpStream>>>,
    queue: Arc<QueueSync>,
    options: ClientOptions,
    flush: Option<Arc<FlushShared>>,
    flusher: Option<std::thread::JoinHandle<()>>,
    next_watch: AtomicU64,
    /// Kept for shutdown: unblocks the parked reader on drop.
    stream: TcpStream,
    reader: Option<std::thread::JoinHandle<()>>,
    pub addr: SocketAddr,
}

impl KvClient {
    /// Connect with default options (unbounded window, immediate flush).
    pub fn connect(addr: SocketAddr) -> Result<KvClient> {
        KvClient::connect_with(addr, ClientOptions::default())
    }

    /// Connect with explicit wire-behaviour options; see [`ClientOptions`].
    pub fn connect_with(
        addr: SocketAddr,
        options: ClientOptions,
    ) -> Result<KvClient> {
        let stream = match options.connect_timeout {
            Some(t) => TcpStream::connect_timeout(&addr, t)?,
            None => TcpStream::connect(addr)?,
        };
        stream.set_nodelay(true)?;
        // SO_SNDTIMEO rides the shared fd: it bounds writes from every
        // clone but leaves reads (SO_RCVTIMEO) untouched.
        stream.set_write_timeout(options.write_timeout)?;
        let queue = Arc::new(QueueSync {
            q: Mutex::new(PendingQueue {
                sinks: VecDeque::new(),
                watches: HashMap::new(),
                dead: None,
            }),
            cv: Condvar::new(),
            window: options.pipeline_window,
        });
        // Clone both halves before spawning the reader, so an error here
        // can never leave a reader thread parked on a live socket.
        let writer_stream = stream.try_clone()?;
        let reader_stream = stream.try_clone()?;
        let reader_queue = queue.clone();
        let reader = std::thread::Builder::new()
            .name(format!("kv-pipe-{}", addr.port()))
            .spawn(move || reader_loop(reader_stream, reader_queue))
            .map_err(|e| {
                Error::Connector(format!("spawn kv pipeline reader: {e}"))
            })?;
        let writer = Arc::new(Mutex::new(std::io::BufWriter::with_capacity(
            1 << 18,
            writer_stream,
        )));
        let (flush, flusher) = match options.flush {
            FlushPolicy::Immediate => (None, None),
            FlushPolicy::Coalesce { max_delay, .. } => {
                let shared = Arc::new(FlushShared {
                    state: Mutex::new(FlushState {
                        dirty_since: None,
                        stop: false,
                    }),
                    cv: Condvar::new(),
                });
                let (s, w, q) = (shared.clone(), writer.clone(), queue.clone());
                let handle = std::thread::Builder::new()
                    .name(format!("kv-flush-{}", addr.port()))
                    .spawn(move || flusher_loop(s, w, q, max_delay))
                    .map_err(|e| {
                        Error::Connector(format!("spawn kv flusher: {e}"))
                    })?;
                (Some(shared), Some(handle))
            }
        };
        Ok(KvClient {
            writer,
            queue,
            options,
            flush,
            flusher,
            next_watch: AtomicU64::new(0),
            stream,
            reader: Some(reader),
            addr,
        })
    }

    /// The options this client was connected with.
    pub fn options(&self) -> &ClientOptions {
        &self.options
    }

    /// Requests submitted but not yet completed (diagnostics). Armed
    /// watches do not count: they are out-of-band, not queue entries.
    pub fn in_flight(&self) -> usize {
        self.queue.q.lock().unwrap().sinks.len()
    }

    /// Watches armed and not yet fired (diagnostics).
    pub fn watches_armed(&self) -> usize {
        self.queue.q.lock().unwrap().watches.len()
    }

    /// Flush any coalesced frames now (clearing the flusher deadline) and
    /// count it. Caller holds the writer lock.
    fn flush_now(
        &self,
        writer: &mut std::io::BufWriter<TcpStream>,
    ) -> Result<()> {
        if let Some(fs) = &self.flush {
            fs.state.lock().unwrap().dirty_since = None;
        }
        if !writer.buffer().is_empty() {
            writer.flush()?;
            client_metrics().flushes.incr();
        }
        Ok(())
    }

    /// Write one frame under the active flush policy: immediate flush, or
    /// buffer until the threshold trips (deadline handled by the flusher).
    fn write_policy(
        &self,
        writer: &mut std::io::BufWriter<TcpStream>,
        wire: &Request,
    ) -> Result<()> {
        write_frame_unflushed(writer, wire)?;
        match self.options.flush {
            FlushPolicy::Immediate => self.flush_now(writer),
            FlushPolicy::Coalesce { max_buffer, .. } => {
                if writer.buffer().len() >= max_buffer {
                    self.flush_now(writer)
                } else {
                    if let Some(fs) = &self.flush {
                        let mut st = fs.state.lock().unwrap();
                        if st.dirty_since.is_none() {
                            st.dirty_since = Some(Instant::now());
                            fs.cv.notify_all();
                        }
                    }
                    Ok(())
                }
            }
        }
    }

    /// Serialize one request onto the shared socket and register its
    /// completion sink. The writer lock spans the queue push and the
    /// frame write so queue order always equals wire order — the FIFO
    /// invariant the reader's response matching relies on.
    ///
    /// When the pipeline window is full, the submitter first flushes any
    /// coalesced frames (so the server can actually drain the window) and
    /// then parks on the queue condvar until a response frees a slot.
    /// Holding the writer lock while parked is deliberate: it pauses
    /// every other submitter on this client too — the window is a
    /// connection-level bound, not a per-thread one.
    ///
    /// When a trace is current on the calling thread (see
    /// [`telemetry::start_trace`]), the request is wrapped in a
    /// [`Request::Traced`] envelope carrying the trace id and a fresh
    /// client span, so the server's span lands on the same trace. Watch
    /// and unwatch stay bare — their completions are out-of-band and the
    /// server rejects them inside envelopes. The untraced path pays one
    /// thread-local read and no clone.
    fn submit_sink(&self, req: &Request, sink: Sink) {
        let m = client_metrics();
        m.ops.incr();
        let name = req.name();
        let mut trace = None;
        let mut start_us = 0;
        let traced = match telemetry::current_trace() {
            Some(ctx)
                if !matches!(
                    req,
                    Request::Watch { .. }
                        | Request::Unwatch { .. }
                        | Request::Subscribe { .. }
                        | Request::Traced { .. }
                ) =>
            {
                // The client span is *recorded* when the response lands
                // (reader side) so it carries the real round-trip
                // duration; only its identity is minted here.
                let span = telemetry::next_span_id();
                trace = Some((ctx.trace_id, span, ctx.span_id));
                start_us = telemetry::now_us();
                Some(Request::Traced {
                    trace_id: ctx.trace_id,
                    span_id: span,
                    inner: Box::new(req.clone()),
                })
            }
            _ => None,
        };
        let wire = traced.as_ref().unwrap_or(req);
        let mut writer = self.writer.lock().unwrap();
        let mut q = self.queue.q.lock().unwrap();
        let window = self.queue.window;
        if window > 0 && q.sinks.len() >= window && q.dead.is_none() {
            drop(q);
            if let Err(e) = self.flush_now(&mut writer) {
                fail_all(&self.queue, e);
            }
            q = self.queue.q.lock().unwrap();
            while q.sinks.len() >= window && q.dead.is_none() {
                q = self.queue.cv.wait(q).unwrap();
            }
        }
        if let Some(e) = &q.dead {
            let err = e.clone();
            drop(q);
            drop(writer);
            complete_sink(&self.queue, sink, Err(err));
            return;
        }
        q.sinks.push_back(PendingOp {
            started: Instant::now(),
            start_us,
            trace,
            name,
            sink,
        });
        m.in_flight.add(1);
        drop(q);
        if let Err(e) = self.write_policy(&mut writer, wire) {
            drop(writer);
            fail_all(&self.queue, e);
        }
    }

    /// Submit a raw request; the handle completes when its response
    /// arrives. Responses are matched FIFO, so a submission is also an
    /// ordering point: later requests on this client execute after it.
    ///
    /// `Subscribe` is rejected: it flips the server connection into push
    /// mode, which breaks the FIFO request/response contract the whole
    /// pipeline is matched by (and would poison every other user of this
    /// client). Subscriptions get their own connection — [`KvSubscriber`].
    pub fn submit(&self, req: Request) -> Pending<Response> {
        if matches!(req, Request::Subscribe { .. }) {
            return Pending::ready(Err(Error::Config(
                "Subscribe is push-mode; use KvSubscriber".into(),
            )));
        }
        let (completer, handle) = pending();
        self.submit_sink(&req, Sink::Resp(completer));
        handle
    }

    /// Submit a typed connector op (the native path behind
    /// [`Connector::submit`](crate::store::Connector::submit) for TCP
    /// channels). `Watch` ops route through the out-of-band watch plane —
    /// they complete from a `Notify` push, never from the FIFO stream.
    pub fn submit_op(&self, op: Op) -> Pending<OpResult> {
        if let Op::Watch { key } = op {
            return crate::ops::watch_result(self.watch(&key));
        }
        let (completer, handle) = pending();
        let (req, kind) = op_request(op);
        self.submit_sink(&req, Sink::Op { kind, completer });
        handle
    }

    /// Arm an out-of-band watch: the handle completes with the value when
    /// (or as soon as) the key exists. The `Notify` push is routed by
    /// watch id, so a parked watch shares this pipelined connection with
    /// ordinary traffic without stalling the FIFO response stream.
    pub fn watch(&self, key: &str) -> Pending<Arc<Vec<u8>>> {
        self.watch_with_id(key).1
    }

    /// [`KvClient::watch`] exposing the id, for callers that may need to
    /// [`KvClient::unwatch`] (timeout paths).
    pub fn watch_with_id(&self, key: &str) -> (u64, Pending<Arc<Vec<u8>>>) {
        let id = self.next_watch.fetch_add(1, Ordering::Relaxed);
        let (completer, handle) = pending();
        let req = Request::Watch { key: key.into(), id };
        // Same lock discipline as `submit_sink`, plus the watch-map
        // insert: registered before the frame is on the wire, so even a
        // Notify that races back instantly finds its completer.
        let mut writer = self.writer.lock().unwrap();
        let mut q = self.queue.q.lock().unwrap();
        let window = self.queue.window;
        if window > 0 && q.sinks.len() >= window && q.dead.is_none() {
            drop(q);
            if let Err(e) = self.flush_now(&mut writer) {
                fail_all(&self.queue, e);
            }
            q = self.queue.q.lock().unwrap();
            while q.sinks.len() >= window && q.dead.is_none() {
                q = self.queue.cv.wait(q).unwrap();
            }
        }
        if let Some(e) = &q.dead {
            let err = e.clone();
            drop(q);
            drop(writer);
            completer.complete(Err(err));
            return (id, handle);
        }
        q.watches.insert(id, completer);
        q.sinks.push_back(PendingOp {
            started: Instant::now(),
            start_us: 0,
            trace: None,
            name: "watch",
            sink: Sink::WatchAck { id },
        });
        client_metrics().in_flight.add(1);
        drop(q);
        if let Err(e) = self.write_policy(&mut writer, &req) {
            drop(writer);
            fail_all(&self.queue, e);
        }
        (id, handle)
    }

    /// Disarm a watch. `Ok(true)` means it was still armed server-side
    /// and will never fire (the local handle is reaped and fails);
    /// `Ok(false)` means it already fired — its `Notify` is delivered or
    /// in flight, so the handle still completes.
    pub fn unwatch(&self, key: &str, id: u64) -> Result<bool> {
        let removed =
            self.expect_int(Request::Unwatch { key: key.into(), id })? == 1;
        if removed {
            self.queue.q.lock().unwrap().watches.remove(&id);
        }
        Ok(removed)
    }

    /// Blocking round trip: submit and wait.
    fn call(&self, req: Request) -> Result<Response> {
        match self.submit(req).wait()? {
            Response::Error(msg) => Err(Error::Protocol(msg)),
            resp => Ok(resp),
        }
    }

    fn expect_ok(&self, req: Request) -> Result<()> {
        match self.call(req)? {
            Response::Ok => Ok(()),
            other => Err(Error::Protocol(format!("expected Ok, got {other:?}"))),
        }
    }

    fn expect_int(&self, req: Request) -> Result<i64> {
        match self.call(req)? {
            Response::Int(v) => Ok(v),
            other => Err(Error::Protocol(format!("expected Int, got {other:?}"))),
        }
    }

    fn expect_value(&self, req: Request) -> Result<Option<Buf>> {
        match self.call(req)? {
            Response::Value(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Value, got {other:?}")))
            }
        }
    }

    pub fn ping(&self) -> Result<()> {
        self.expect_ok(Request::Ping)
    }

    pub fn set(&self, key: &str, value: Bytes) -> Result<()> {
        self.expect_ok(Request::Set { key: key.into(), value })
    }

    pub fn set_nx(&self, key: &str, value: Bytes) -> Result<bool> {
        Ok(self.expect_int(Request::SetNx { key: key.into(), value })? == 1)
    }

    pub fn get(&self, key: &str) -> Result<Option<Bytes>> {
        Ok(self.get_view(key)?.map(|b| Bytes(b.into_vec())))
    }

    /// Zero-copy get: the returned [`Buf`] is a window over the response
    /// frame's own allocation — the value is read off the socket once and
    /// never copied again. [`KvClient::get`] is this plus a flatten into
    /// owned [`Bytes`] for callers that need a `Vec`.
    pub fn get_view(&self, key: &str) -> Result<Option<Buf>> {
        self.expect_value(Request::Get { key: key.into() })
    }

    /// Batched set: one round trip for the whole batch.
    pub fn mput(&self, items: Vec<(String, Bytes)>) -> Result<()> {
        self.expect_ok(Request::MPut { items })
    }

    pub fn mget(&self, keys: &[String]) -> Result<Vec<Option<Bytes>>> {
        Ok(self
            .mget_view(keys)?
            .into_iter()
            .map(|o| o.map(|b| Bytes(b.into_vec())))
            .collect())
    }

    /// Zero-copy batched get: every present value is a window over the
    /// one response-frame allocation the batch arrived in.
    pub fn mget_view(&self, keys: &[String]) -> Result<Vec<Option<Buf>>> {
        match self.call(Request::MGet { keys: keys.to_vec() })? {
            Response::Values(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Values, got {other:?}")))
            }
        }
    }

    /// Blocking get; `None` timeout waits forever. Rides the out-of-band
    /// watch plane: the wait parks client-side on a watch handle while
    /// the shared pipelined connection keeps serving other traffic — no
    /// dedicated connection, no server-side parking of the response
    /// stream (the old `WaitGet` caveat is gone).
    pub fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Bytes>> {
        let (id, handle) = self.watch_with_id(key);
        let Some(timeout) = timeout else {
            return Ok(Some(Bytes(handle.wait()?.to_vec())));
        };
        if let Some(v) = handle.wait_timeout(timeout)? {
            return Ok(Some(Bytes(v.to_vec())));
        }
        if self.unwatch(key, id)? {
            return Ok(None); // disarmed before firing: a genuine timeout
        }
        // The watch fired concurrently with the timeout: its Notify is
        // delivered or in flight (a dead connection fails the handle).
        Ok(Some(Bytes(handle.wait()?.to_vec())))
    }

    pub fn del(&self, key: &str) -> Result<bool> {
        Ok(self.expect_int(Request::Del { key: key.into() })? == 1)
    }

    /// Batched delete: one round trip; returns how many keys existed.
    pub fn mdel(&self, keys: &[String]) -> Result<i64> {
        self.expect_int(Request::MDel { keys: keys.to_vec() })
    }

    pub fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.expect_int(Request::Exists { key: key.into() })? == 1)
    }

    /// Batched existence check: one round trip, positionally aligned.
    pub fn mexists(&self, keys: &[String]) -> Result<Vec<bool>> {
        match self.call(Request::MExists { keys: keys.to_vec() })? {
            Response::Bools(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Bools, got {other:?}")))
            }
        }
    }

    pub fn incr(&self, key: &str, by: i64) -> Result<i64> {
        self.expect_int(Request::Incr { key: key.into(), by })
    }

    pub fn keys(&self, prefix: &str) -> Result<Vec<String>> {
        match self.call(Request::Keys { prefix: prefix.into() })? {
            Response::KeysList(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Keys, got {other:?}")))
            }
        }
    }

    pub fn publish(&self, channel: &str, payload: Bytes) -> Result<i64> {
        self.expect_int(Request::Publish { channel: channel.into(), payload })
    }

    pub fn lpush(&self, list: &str, value: Bytes) -> Result<()> {
        self.expect_ok(Request::LPush { list: list.into(), value })
    }

    pub fn brpop(
        &self,
        list: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Bytes>> {
        Ok(self
            .expect_value(Request::BRPop {
                list: list.into(),
                timeout_ms: timeout.map(|d| d.as_millis() as u64).unwrap_or(0),
            })?
            .map(|b| Bytes(b.into_vec())))
    }

    pub fn flush_all(&self) -> Result<()> {
        self.expect_ok(Request::FlushAll)
    }

    pub fn stats(&self) -> Result<(u64, u64, u64)> {
        match self.call(Request::Stats)? {
            Response::StatsReply { keys, bytes, ops } => Ok((keys, bytes, ops)),
            other => {
                Err(Error::Protocol(format!("expected Stats, got {other:?}")))
            }
        }
    }

    /// Fetch the server *process's* full telemetry snapshot over the wire
    /// (counters, gauges, histograms, recent trace events). One round
    /// trip; rides the shared pipeline like any other request.
    pub fn telemetry(&self) -> Result<TelemetrySnapshot> {
        match self.call(Request::Telemetry)? {
            Response::Telemetry { data } => TelemetrySnapshot::from_bytes(&data.0),
            other => Err(Error::Protocol(format!(
                "expected Telemetry, got {other:?}"
            ))),
        }
    }
}

impl Drop for KvClient {
    /// Stop and join the flusher (flushing any buffered frames on the way
    /// out), shut the socket down (unparking the reader mid-`read_frame`),
    /// and reap the reader thread; any still-pending handles complete with
    /// a connection error on the way out.
    fn drop(&mut self) {
        if let Some(fs) = &self.flush {
            fs.state.lock().unwrap().stop = true;
            fs.cv.notify_all();
        }
        if let Some(handle) = self.flusher.take() {
            let _ = handle.join();
        }
        if let Ok(mut w) = self.writer.lock() {
            let _ = w.flush();
        }
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        if let Some(handle) = self.reader.take() {
            let _ = handle.join();
        }
    }
}

/// Dedicated subscription connection (push mode), like a Redis subscriber.
pub struct KvSubscriber {
    reader: Mutex<std::io::BufReader<TcpStream>>,
}

impl KvSubscriber {
    pub fn connect(addr: SocketAddr, channels: &[String]) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let mut writer = std::io::BufWriter::new(stream.try_clone()?);
        let mut reader = std::io::BufReader::with_capacity(1 << 18, stream);
        write_frame(
            &mut writer,
            &Request::Subscribe { channels: channels.to_vec() },
        )?;
        match read_frame::<_, Response>(&mut reader)? {
            Some(Response::Ok) => Ok(KvSubscriber {
                reader: Mutex::new(reader),
            }),
            other => Err(Error::Protocol(format!(
                "subscribe handshake failed: {other:?}"
            ))),
        }
    }

    /// Next pushed message. `Ok(None)` on timeout; error if disconnected.
    pub fn next(&self, timeout: Option<Duration>) -> Result<Option<PubSubMsg>> {
        let mut reader = self.reader.lock().unwrap();
        reader
            .get_ref()
            .set_read_timeout(timeout)
            .map_err(Error::from)?;
        match read_frame::<_, Response>(&mut *reader) {
            Ok(Some(Response::Message { channel, payload })) => {
                Ok(Some(PubSubMsg { channel, payload }))
            }
            Ok(Some(other)) => Err(Error::Protocol(format!(
                "unexpected push frame: {other:?}"
            ))),
            Ok(None) => Err(Error::StreamClosed("subscription ended".into())),
            Err(Error::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::ServerBuilder;

    #[test]
    fn pipelined_submissions_complete_in_order() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        // Submit a window of writes then a read of each key *before*
        // waiting on anything: FIFO execution means every read sees its
        // write.
        let puts: Vec<_> = (0..32)
            .map(|i| {
                client.submit_op(Op::Put {
                    key: format!("p-{i}"),
                    data: vec![i as u8],
                })
            })
            .collect();
        let gets: Vec<_> = (0..32)
            .map(|i| client.submit_op(Op::Get { key: format!("p-{i}") }))
            .collect();
        for p in puts {
            p.wait().unwrap().into_unit().unwrap();
        }
        for (i, g) in gets.into_iter().enumerate() {
            assert_eq!(
                g.wait().unwrap().into_value().unwrap().map(|b| b.to_vec()),
                Some(vec![i as u8])
            );
        }
        assert_eq!(client.in_flight(), 0);
    }

    #[test]
    fn concurrent_threads_share_one_connection() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = Arc::new(KvClient::connect(server.addr).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for i in 0..32 {
                        let key = format!("t{t}-{i}");
                        c.set(&key, Bytes(vec![t as u8, i as u8])).unwrap();
                        assert_eq!(
                            c.get(&key).unwrap(),
                            Some(Bytes(vec![t as u8, i as u8]))
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (keys, _, _) = client.stats().unwrap();
        assert_eq!(keys, 128);
    }

    #[test]
    fn coalescing_client_batches_writes() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client =
            KvClient::connect_with(server.addr, ClientOptions::coalescing())
                .unwrap();
        // A pipelined burst: many small frames coalesce into few flushes,
        // and every op still completes with the right value.
        let puts: Vec<_> = (0..100)
            .map(|i| {
                client.submit_op(Op::Put {
                    key: format!("c-{i}"),
                    data: vec![i as u8],
                })
            })
            .collect();
        for p in puts {
            p.wait().unwrap().into_unit().unwrap();
        }
        assert_eq!(client.get("c-7").unwrap(), Some(Bytes(vec![7])));
        // A lone blocking op must not hang: the flusher's deadline (200µs)
        // pushes it out without another submission arriving.
        assert_eq!(client.get("c-42").unwrap(), Some(Bytes(vec![42])));
        assert_eq!(client.in_flight(), 0);
    }

    #[test]
    fn pipeline_window_bounds_in_flight() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let opts =
            ClientOptions { pipeline_window: 4, ..ClientOptions::default() };
        let client = KvClient::connect_with(server.addr, opts).unwrap();
        // 64 nonblocking submissions through a window of 4: submitters
        // park when full, every op completes, and the queue never exceeds
        // the window.
        let mut handles = Vec::new();
        for i in 0..64 {
            handles.push(client.submit_op(Op::Put {
                key: format!("w-{i}"),
                data: vec![i as u8],
            }));
            assert!(client.in_flight() <= 4, "window must bound the queue");
        }
        for h in handles {
            h.wait().unwrap().into_unit().unwrap();
        }
        let (keys, _, _) = client.stats().unwrap();
        assert_eq!(keys, 64);
    }

    #[test]
    fn client_options_roundtrip_codec() {
        for opts in [
            ClientOptions::default(),
            ClientOptions::coalescing(),
            ClientOptions {
                pipeline_window: 32,
                flush: FlushPolicy::Coalesce {
                    max_buffer: 4096,
                    max_delay: Duration::from_millis(2),
                },
                connect_timeout: Some(Duration::from_secs(1)),
                write_timeout: Some(Duration::from_millis(250)),
            },
        ] {
            let back = ClientOptions::from_bytes(&opts.to_bytes()).unwrap();
            assert_eq!(opts, back);
        }
    }

    #[test]
    fn server_death_fails_in_flight_and_later_ops() {
        let mut server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.ping().unwrap();
        // Park an op server-side, then kill the server under it.
        let parked = client.submit(Request::WaitGet {
            key: "never-set".into(),
            timeout_ms: 30_000,
        });
        std::thread::sleep(Duration::from_millis(30));
        server.shutdown();
        assert!(parked.wait().is_err(), "in-flight op must fail");
        // The pipe is dead: later submissions fail fast, without parking.
        let t0 = std::time::Instant::now();
        assert!(client.submit_op(Op::Get { key: "k".into() }).wait().is_err());
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(client.ping().is_err());
    }

    #[test]
    fn watch_completes_out_of_band() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let handle = client.watch("later");
        assert!(!handle.is_complete());
        assert_eq!(client.watches_armed(), 1);
        // The armed watch does not occupy the FIFO pipe.
        client.ping().unwrap();
        assert_eq!(client.in_flight(), 0);
        let setter = KvClient::connect(server.addr).unwrap();
        setter.set("later", Bytes(vec![4, 2])).unwrap();
        assert_eq!(handle.wait().unwrap().to_vec(), vec![4, 2]);
        assert_eq!(client.watches_armed(), 0);
    }

    #[test]
    fn watch_existing_key_fires_immediately() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.set("here", Bytes(vec![7])).unwrap();
        let handle = client.watch("here");
        assert_eq!(handle.wait().unwrap().to_vec(), vec![7]);
    }

    #[test]
    fn wait_get_timeout_leaves_pipe_usable() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let t0 = std::time::Instant::now();
        let got = client
            .wait_get("never", Some(Duration::from_millis(40)))
            .unwrap();
        assert!(got.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(40));
        // Timeout disarmed the watch on both sides; the pipe still works.
        assert_eq!(client.watches_armed(), 0);
        client.set("k", Bytes(vec![1])).unwrap();
        assert_eq!(client.get("k").unwrap(), Some(Bytes(vec![1])));
        assert_eq!(
            server.state().watch_count(),
            0,
            "server-side registry must not leak timed-out watches"
        );
    }

    #[test]
    fn wait_get_wakes_without_parking_the_pipe() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let addr = server.addr;
        let client = Arc::new(KvClient::connect(addr).unwrap());
        let waiter = {
            let c = client.clone();
            std::thread::spawn(move || {
                c.wait_get("slow", Some(Duration::from_secs(5))).unwrap()
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        // The same connection keeps serving while the wait is parked.
        client.set("other", Bytes(vec![1])).unwrap();
        assert!(client.get("other").unwrap().is_some());
        client.set("slow", Bytes(vec![9])).unwrap();
        assert_eq!(waiter.join().unwrap(), Some(Bytes(vec![9])));
    }

    #[test]
    fn server_death_fails_armed_watches_promptly() {
        let mut server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let handle = client.watch("never-set");
        std::thread::sleep(Duration::from_millis(30));
        server.shutdown();
        let t0 = std::time::Instant::now();
        assert!(handle.wait().is_err(), "armed watch must fail, not hang");
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert_eq!(client.watches_armed(), 0);
    }

    #[test]
    fn subscribe_is_rejected_not_pipelined() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let res = client
            .submit(Request::Subscribe { channels: vec!["c".into()] })
            .wait();
        assert!(res.is_err(), "push-mode request must not enter the pipe");
        // The pipe is unharmed: ordinary traffic keeps flowing.
        client.ping().unwrap();
    }

    #[test]
    fn traced_ops_share_a_trace_id_with_server_spans() {
        let _g = crate::metrics::telemetry::test_enabled_guard();
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let trace = telemetry::start_trace("client-unit");
        let trace_id = trace.ctx().trace_id;
        client.set("traced-k", Bytes(vec![1])).unwrap();
        assert_eq!(client.get("traced-k").unwrap(), Some(Bytes(vec![1])));
        drop(trace);
        // Server and client share this process's registry in tests, but
        // the snapshot arrives over the wire like any remote one would.
        let snap = client.telemetry().unwrap();
        let spans: Vec<_> = snap
            .events
            .iter()
            .filter(|e| e.trace_id == trace_id)
            .collect();
        let client_spans: Vec<_> = spans
            .iter()
            .filter(|e| e.subsystem == "kv.client")
            .collect();
        let server_spans: Vec<_> = spans
            .iter()
            .filter(|e| e.subsystem == "kv.server")
            .collect();
        assert!(client_spans.len() >= 2, "set + get client spans: {spans:?}");
        assert!(server_spans.len() >= 2, "set + get server spans: {spans:?}");
        // Every server span descends from a client span of the same trace.
        for s in &server_spans {
            assert!(
                client_spans.iter().any(|c| c.span_id == s.parent_span),
                "server span {s:?} not parented on a client span"
            );
        }
        // Untraced ops stay bare: no new spans after the guard dropped.
        client.ping().unwrap();
        let snap2 = client.telemetry().unwrap();
        let n_after = snap2
            .events
            .iter()
            .filter(|e| e.trace_id == trace_id)
            .count();
        assert_eq!(n_after, spans.len());
    }

    #[test]
    fn telemetry_snapshot_counts_frames() {
        let _g = crate::metrics::telemetry::test_enabled_guard();
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.set("m", Bytes(vec![1])).unwrap();
        let snap = client.telemetry().unwrap();
        assert!(snap.counter("kv.server.frames_in") >= 2);
        assert!(snap.counter("kv.server.frames_out") >= 1);
        assert!(snap.counter("kv.client.ops") >= 2);
        let h = snap.histogram("kv.server.op_us").expect("server op histogram");
        assert!(h.count >= 1);
    }

    #[test]
    fn drop_with_in_flight_op_reaps_reader() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        let parked = client.submit(Request::WaitGet {
            key: "never-set".into(),
            timeout_ms: 30_000,
        });
        std::thread::sleep(Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        drop(client); // shuts the socket down and joins the reader
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "drop must not wait out the parked op"
        );
        assert!(parked.wait().is_err(), "orphaned handle completes with error");
    }
}
