//! Blocking KV client. One request in flight per connection (guarded by a
//! mutex), mirroring redis-py's default connection behaviour that the
//! paper's deployments used.

use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

use crate::codec::Bytes;
use crate::error::{Error, Result};
use crate::kv::protocol::{read_frame, write_frame, Request, Response};
use crate::kv::state::PubSubMsg;

struct Conn {
    reader: std::io::BufReader<TcpStream>,
    writer: std::io::BufWriter<TcpStream>,
}

/// Thread-safe request/response client.
pub struct KvClient {
    conn: Mutex<Conn>,
    pub addr: SocketAddr,
}

impl KvClient {
    pub fn connect(addr: SocketAddr) -> Result<KvClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(KvClient {
            conn: Mutex::new(Conn {
                reader: std::io::BufReader::with_capacity(1 << 18, stream.try_clone()?),
                writer: std::io::BufWriter::with_capacity(1 << 18, stream),
            }),
            addr,
        })
    }

    fn call(&self, req: Request) -> Result<Response> {
        let mut conn = self.conn.lock().unwrap();
        write_frame(&mut conn.writer, &req)?;
        match read_frame::<_, Response>(&mut conn.reader)? {
            Some(Response::Error(msg)) => Err(Error::Protocol(msg)),
            Some(resp) => Ok(resp),
            None => Err(Error::Connector("kv server closed connection".into())),
        }
    }

    fn expect_ok(&self, req: Request) -> Result<()> {
        match self.call(req)? {
            Response::Ok => Ok(()),
            other => Err(Error::Protocol(format!("expected Ok, got {other:?}"))),
        }
    }

    fn expect_int(&self, req: Request) -> Result<i64> {
        match self.call(req)? {
            Response::Int(v) => Ok(v),
            other => Err(Error::Protocol(format!("expected Int, got {other:?}"))),
        }
    }

    fn expect_value(&self, req: Request) -> Result<Option<Bytes>> {
        match self.call(req)? {
            Response::Value(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Value, got {other:?}")))
            }
        }
    }

    pub fn ping(&self) -> Result<()> {
        self.expect_ok(Request::Ping)
    }

    pub fn set(&self, key: &str, value: Bytes) -> Result<()> {
        self.expect_ok(Request::Set { key: key.into(), value })
    }

    pub fn set_nx(&self, key: &str, value: Bytes) -> Result<bool> {
        Ok(self.expect_int(Request::SetNx { key: key.into(), value })? == 1)
    }

    pub fn get(&self, key: &str) -> Result<Option<Bytes>> {
        self.expect_value(Request::Get { key: key.into() })
    }

    /// Batched set: one round trip for the whole batch.
    pub fn mput(&self, items: Vec<(String, Bytes)>) -> Result<()> {
        self.expect_ok(Request::MPut { items })
    }

    pub fn mget(&self, keys: &[String]) -> Result<Vec<Option<Bytes>>> {
        match self.call(Request::MGet { keys: keys.to_vec() })? {
            Response::Values(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Values, got {other:?}")))
            }
        }
    }

    /// Blocking get; `None` timeout waits forever.
    pub fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Bytes>> {
        self.expect_value(Request::WaitGet {
            key: key.into(),
            timeout_ms: timeout.map(|d| d.as_millis() as u64).unwrap_or(0),
        })
    }

    pub fn del(&self, key: &str) -> Result<bool> {
        Ok(self.expect_int(Request::Del { key: key.into() })? == 1)
    }

    /// Batched delete: one round trip; returns how many keys existed.
    pub fn mdel(&self, keys: &[String]) -> Result<i64> {
        self.expect_int(Request::MDel { keys: keys.to_vec() })
    }

    pub fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.expect_int(Request::Exists { key: key.into() })? == 1)
    }

    /// Batched existence check: one round trip, positionally aligned.
    pub fn mexists(&self, keys: &[String]) -> Result<Vec<bool>> {
        match self.call(Request::MExists { keys: keys.to_vec() })? {
            Response::Bools(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Bools, got {other:?}")))
            }
        }
    }

    pub fn incr(&self, key: &str, by: i64) -> Result<i64> {
        self.expect_int(Request::Incr { key: key.into(), by })
    }

    pub fn keys(&self, prefix: &str) -> Result<Vec<String>> {
        match self.call(Request::Keys { prefix: prefix.into() })? {
            Response::KeysList(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("expected Keys, got {other:?}")))
            }
        }
    }

    pub fn publish(&self, channel: &str, payload: Bytes) -> Result<i64> {
        self.expect_int(Request::Publish { channel: channel.into(), payload })
    }

    pub fn lpush(&self, list: &str, value: Bytes) -> Result<()> {
        self.expect_ok(Request::LPush { list: list.into(), value })
    }

    pub fn brpop(
        &self,
        list: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Bytes>> {
        self.expect_value(Request::BRPop {
            list: list.into(),
            timeout_ms: timeout.map(|d| d.as_millis() as u64).unwrap_or(0),
        })
    }

    pub fn flush_all(&self) -> Result<()> {
        self.expect_ok(Request::FlushAll)
    }

    pub fn stats(&self) -> Result<(u64, u64, u64)> {
        match self.call(Request::Stats)? {
            Response::StatsReply { keys, bytes, ops } => Ok((keys, bytes, ops)),
            other => {
                Err(Error::Protocol(format!("expected Stats, got {other:?}")))
            }
        }
    }
}

/// Dedicated subscription connection (push mode), like a Redis subscriber.
pub struct KvSubscriber {
    reader: Mutex<std::io::BufReader<TcpStream>>,
}

impl KvSubscriber {
    pub fn connect(addr: SocketAddr, channels: &[String]) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let mut writer = std::io::BufWriter::new(stream.try_clone()?);
        let mut reader = std::io::BufReader::with_capacity(1 << 18, stream);
        write_frame(
            &mut writer,
            &Request::Subscribe { channels: channels.to_vec() },
        )?;
        match read_frame::<_, Response>(&mut reader)? {
            Some(Response::Ok) => Ok(KvSubscriber {
                reader: Mutex::new(reader),
            }),
            other => Err(Error::Protocol(format!(
                "subscribe handshake failed: {other:?}"
            ))),
        }
    }

    /// Next pushed message. `Ok(None)` on timeout; error if disconnected.
    pub fn next(&self, timeout: Option<Duration>) -> Result<Option<PubSubMsg>> {
        let mut reader = self.reader.lock().unwrap();
        reader
            .get_ref()
            .set_read_timeout(timeout)
            .map_err(Error::from)?;
        match read_frame::<_, Response>(&mut *reader) {
            Ok(Some(Response::Message { channel, payload })) => {
                Ok(Some(PubSubMsg { channel, payload }))
            }
            Ok(Some(other)) => Err(Error::Protocol(format!(
                "unexpected push frame: {other:?}"
            ))),
            Ok(None) => Err(Error::StreamClosed("subscription ended".into())),
            Err(Error::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }
}
