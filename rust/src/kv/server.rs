//! TCP front-end for the KV engine, with two ingress modes behind the
//! unified [`ServerBuilder`]:
//!
//! - **Event loop** (default on Linux): a small [`EventLoopPool`]
//!   multiplexes every connection. [`KvEventService`] handles one frame
//!   at a time on a loop thread; fast ops reply inline, genuinely
//!   blocking ops (`WaitGet` on a missing key, `BRPop` on an empty
//!   list) first *probe* the engine — the zero-timeout attempt IS the
//!   op, so a present value replies without parking — and only the
//!   empty case defers to a short-lived helper thread that completes
//!   through the connection's [`ConnHandle`]. Watch `Notify` frames are
//!   pushed into the owning loop from whichever thread stores the key.
//! - **Threaded**: one blocking OS thread per connection (portable
//!   fallback and baseline). A connection's writer is shared between
//!   its request loop and the watch callbacks it arms, interleaving
//!   FIFO responses and out-of-band pushes under one lock.
//!
//! Both modes run the same request core ([`handle_request`] /
//! [`respond`]), and watches a connection leaves armed are disarmed
//! when it closes.

use std::collections::HashMap;
use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::codec::{Buf, Bytes, Decode, Encode};
use crate::error::Result;
use crate::kv::protocol::{
    read_frame, write_frame_reusing, Request, Response,
};
use crate::kv::state::{KvState, PubSubMsg};
use crate::metrics::telemetry;
use crate::net::{
    ConnHandle, EventLoopPool, FrameOutcome, Ingress, NoState, ServerBuilder,
    Service, WireFrame,
};

/// Cached registry handles for the server's hot-path metrics (one lookup
/// per process, not per frame).
struct ServerMetrics {
    connections: Arc<telemetry::Gauge>,
    frames_in: Arc<telemetry::Counter>,
    frames_out: Arc<telemetry::Counter>,
    notify_pushes: Arc<telemetry::Counter>,
    op_us: Arc<telemetry::Histogram>,
    wake_us: Arc<telemetry::Histogram>,
}

fn server_metrics() -> &'static ServerMetrics {
    static M: OnceLock<ServerMetrics> = OnceLock::new();
    M.get_or_init(|| ServerMetrics {
        connections: telemetry::gauge("kv.server.connections"),
        frames_in: telemetry::counter("kv.server.frames_in"),
        frames_out: telemetry::counter("kv.server.frames_out"),
        notify_pushes: telemetry::counter("kv.server.notify_pushes"),
        op_us: telemetry::histogram("kv.server.op_us"),
        wake_us: telemetry::histogram("watch.wake_to_notify_us"),
    })
}

/// The running ingress machinery behind a [`KvServer`].
enum IngressHandle {
    Threaded {
        accept_thread: Option<std::thread::JoinHandle<()>>,
        /// Live connection sockets, force-closed on shutdown.
        conns: Arc<Mutex<Vec<TcpStream>>>,
    },
    Event(EventLoopPool),
}

/// A running KV server. Dropping the handle shuts it down.
pub struct KvServer {
    pub addr: SocketAddr,
    state: KvState,
    stop: Arc<AtomicBool>,
    ingress: IngressHandle,
    /// The HTTP admin plane, when the builder asked for one.
    admin: Option<EventLoopPool>,
}

impl KvServer {
    /// Bind to 127.0.0.1 on an ephemeral port and start serving.
    #[deprecated(note = "use ServerBuilder::new().spawn_kv()")]
    pub fn spawn() -> Result<KvServer> {
        ServerBuilder::new().spawn_kv()
    }

    /// Serve an externally created state.
    #[deprecated(note = "use ServerBuilder::new().with_state(state).spawn()")]
    pub fn spawn_with_state(state: KvState) -> Result<KvServer> {
        ServerBuilder::new().with_state(state).spawn()
    }

    /// The shared engine (for embedded access / gauges).
    pub fn state(&self) -> &KvState {
        &self.state
    }

    /// Where the HTTP admin plane listens, when one was requested via
    /// [`ServerBuilder::admin_addr`].
    pub fn admin_addr(&self) -> Option<SocketAddr> {
        self.admin.as_ref().map(|p| p.addr)
    }

    /// Stop accepting, close live connections, and wind down.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(pool) = &mut self.admin {
            pool.shutdown();
        }
        match &mut self.ingress {
            IngressHandle::Threaded { accept_thread, conns } => {
                // Unblock the blocking accept; the loop re-checks `stop`.
                let _ = TcpStream::connect(self.addr);
                for conn in conns.lock().unwrap().drain(..) {
                    let _ = conn.shutdown(std::net::Shutdown::Both);
                }
                if let Some(h) = accept_thread.take() {
                    let _ = h.join();
                }
            }
            IngressHandle::Event(pool) => pool.shutdown(),
        }
    }
}

impl Drop for KvServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl ServerBuilder<KvState> {
    /// Spawn a KV server serving this builder's state.
    pub fn spawn(self) -> Result<KvServer> {
        spawn_kv_server(self)
    }
}

impl ServerBuilder<NoState> {
    /// Spawn a KV server with fresh state — or, when
    /// [`ServerBuilder::data_dir`] / `durability` was set, a durable
    /// engine recovered from that directory (snapshot + WAL replay).
    pub fn spawn_kv(self) -> Result<KvServer> {
        let state = match &self.durability {
            Some(opts) => KvState::open_durable(opts)?,
            None => KvState::new(),
        };
        self.with_state(state).spawn()
    }
}

fn spawn_kv_server(b: ServerBuilder<KvState>) -> Result<KvServer> {
    let stop = Arc::new(AtomicBool::new(false));
    // The admin plane spawns first: a bad admin address fails the whole
    // spawn before any data-plane thread starts. Both ingress modes keep
    // the connections gauge live, so `/conns` reads it directly.
    let admin = match b.admin {
        Some(addr) => Some(crate::net::http::spawn_admin(
            addr,
            "kv",
            Arc::new(|| server_metrics().connections.get().max(0) as usize),
        )?),
        None => None,
    };
    match b.ingress {
        Ingress::EventLoop => {
            let service = Arc::new(KvEventService {
                state: b.state.clone(),
                stop: stop.clone(),
                zero_copy: b.zero_copy,
                armed: Arc::new(Mutex::new(HashMap::new())),
            });
            let pool = EventLoopPool::spawn(
                b.bind,
                b.event_loops,
                b.max_connections,
                service,
                "kv",
            )?;
            Ok(KvServer {
                addr: pool.addr,
                state: b.state,
                stop,
                ingress: IngressHandle::Event(pool),
                admin,
            })
        }
        Ingress::Threaded => spawn_threaded(b, stop, admin),
    }
}

fn spawn_threaded(
    b: ServerBuilder<KvState>,
    stop: Arc<AtomicBool>,
    admin: Option<EventLoopPool>,
) -> Result<KvServer> {
    let listener = TcpListener::bind(b.bind)?;
    let addr = listener.local_addr()?;
    let state = b.state;
    let max_connections = b.max_connections;
    let stop2 = stop.clone();
    let state2 = state.clone();
    let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let conns2 = conns.clone();
    let active = Arc::new(AtomicUsize::new(0));
    // Blocking accept (no busy-wait): `shutdown` sets the stop flag and
    // pokes the listener with a throwaway connection to unblock it.
    let accept_thread = std::thread::Builder::new()
        .name(format!("kv-accept-{}", addr.port()))
        .spawn(move || loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    if max_connections > 0
                        && active.load(Ordering::Relaxed) >= max_connections
                    {
                        drop(stream); // over the cap
                        continue;
                    }
                    active.fetch_add(1, Ordering::Relaxed);
                    if let Ok(clone) = stream.try_clone() {
                        conns2.lock().unwrap().push(clone);
                    }
                    let st = state2.clone();
                    let stop3 = stop2.clone();
                    let active2 = active.clone();
                    std::thread::Builder::new()
                        .name("kv-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(stream, st, stop3);
                            active2.fetch_sub(1, Ordering::Relaxed);
                        })
                        .expect("spawn kv-conn");
                }
                Err(_) => {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                }
            }
        })
        .expect("spawn kv-accept");
    Ok(KvServer {
        addr,
        state,
        stop,
        ingress: IngressHandle::Threaded {
            accept_thread: Some(accept_thread),
            conns,
        },
        admin,
    })
}

fn handle_request(state: &KvState, req: Request) -> Response {
    match req {
        Request::Get { key } => Response::Value(state.get_buf(&key)),
        Request::Set { key, value } => {
            if let Err(e) = KvState::check_value_size(&value) {
                return Response::Error(e.to_string());
            }
            telemetry::data_metrics().value_bytes_in.add(value.0.len() as u64);
            state.set(&key, value);
            Response::Ok
        }
        Request::SetNx { key, value } => {
            telemetry::data_metrics().value_bytes_in.add(value.0.len() as u64);
            Response::Int(i64::from(state.set_nx(&key, value)))
        }
        Request::Del { key } => Response::Int(i64::from(state.del(&key))),
        Request::MDel { keys } => Response::Int(state.mdel(&keys)),
        Request::MExists { keys } => Response::Bools(state.mexists(&keys)),
        Request::Exists { key } => Response::Int(i64::from(state.exists(&key))),
        Request::MGet { keys } => Response::Values(state.mget_buf(&keys)),
        Request::MPut { items } => {
            for (_, value) in &items {
                if let Err(e) = KvState::check_value_size(value) {
                    return Response::Error(e.to_string());
                }
            }
            let total: usize = items.iter().map(|(_, v)| v.0.len()).sum();
            telemetry::data_metrics().value_bytes_in.add(total as u64);
            state.mset(items);
            Response::Ok
        }
        Request::WaitGet { key, timeout_ms } => {
            let timeout = if timeout_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(timeout_ms))
            };
            Response::Value(
                state.wait_get_shared(&key, timeout).map(Buf::from_arc),
            )
        }
        Request::Incr { key, by } => Response::Int(state.incr(&key, by)),
        Request::Keys { prefix } => Response::KeysList(state.keys(&prefix)),
        Request::Publish { channel, payload } => {
            Response::Int(state.publish(&channel, payload))
        }
        Request::LPush { list, value } => {
            state.lpush(&list, value);
            Response::Ok
        }
        Request::BRPop { list, timeout_ms } => {
            let timeout = if timeout_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(timeout_ms))
            };
            Response::Value(
                state.brpop(&list, timeout).map(|v| Buf::from_vec(v.0)),
            )
        }
        Request::FlushAll => {
            state.flush_all();
            Response::Ok
        }
        Request::Stats => {
            let (keys, bytes, ops) = state.stats();
            Response::StatsReply { keys, bytes, ops }
        }
        Request::Ping => Response::Ok,
        Request::Telemetry => Response::Telemetry {
            data: Bytes(telemetry::snapshot().to_bytes()),
        },
        Request::Subscribe { .. }
        | Request::Watch { .. }
        | Request::Unwatch { .. }
        | Request::Traced { .. } => {
            unreachable!("push-mode/envelope requests handled by the caller")
        }
    }
}

/// Execute one non-push request — bare or in a `Traced` envelope —
/// recording op latency and trace spans. Push-mode requests
/// (`Subscribe`/`Watch`/`Unwatch`) are the ingress's job; a `Traced`
/// envelope carrying one is rejected rather than silently untraced.
fn respond(state: &KvState, req: Request) -> Response {
    match req {
        Request::Traced { trace_id, span_id, inner } => match *inner {
            Request::Subscribe { .. }
            | Request::Watch { .. }
            | Request::Unwatch { .. }
            | Request::Traced { .. } => Response::Error(
                "traced envelope cannot carry push-mode or nested requests"
                    .into(),
            ),
            inner => {
                let name = inner.name();
                let span = telemetry::next_span_id();
                let start = Instant::now();
                let start_us = telemetry::now_us();
                let resp = handle_request(state, inner);
                let dur = start.elapsed();
                server_metrics().op_us.record_duration(dur);
                // The server span parents on the client's envelope span
                // id, linking this process into the cross-node tree.
                telemetry::span_event(
                    trace_id,
                    span,
                    span_id,
                    "kv.server",
                    name,
                    start_us,
                    dur.as_micros() as u64,
                );
                telemetry::record_slow_op(name, dur, trace_id, span, "kv");
                resp
            }
        },
        other => {
            let name = other.name();
            let start = Instant::now();
            let resp = handle_request(state, other);
            let dur = start.elapsed();
            server_metrics().op_us.record_duration(dur);
            telemetry::record_slow_op(name, dur, 0, 0, "kv");
            resp
        }
    }
}

/// Is this a request the event loop must never execute inline (it can
/// park), directly or under a `Traced` envelope?
fn is_blocking(req: &Request) -> bool {
    match req {
        Request::WaitGet { .. } | Request::BRPop { .. } => true,
        Request::Traced { inner, .. } => {
            matches!(**inner, Request::WaitGet { .. } | Request::BRPop { .. })
        }
        _ => false,
    }
}

/// Flatten a response for the reactor's outbox. Zero-copy mode emits a
/// segmented frame whose payload segments alias the engine's buffers;
/// copy mode re-encodes everything into one flat buffer (the
/// pre-zero-copy behaviour, kept as a bench baseline) and charges the
/// payload bytes to `data.bytes_copied`.
fn encode_reply(resp: Response, zero_copy: bool) -> WireFrame {
    let out = resp.payload_len() as u64;
    let dm = telemetry::data_metrics();
    if out > 0 {
        dm.value_bytes_out.add(out);
    }
    if zero_copy {
        resp.into_frame()
    } else {
        if out > 0 {
            dm.bytes_copied.add(out);
        }
        WireFrame::from_vec(resp.to_bytes())
    }
}

// ---------------------------------------------------------------------------
// Event-driven ingress
// ---------------------------------------------------------------------------

/// KV protocol logic on the reactor: one [`Service::on_frame`] call per
/// complete request frame, on a loop thread.
struct KvEventService {
    state: KvState,
    stop: Arc<AtomicBool>,
    /// Reply framing mode; see [`ServerBuilder::zero_copy`].
    zero_copy: bool,
    /// conn id -> (client watch id -> (key, registry token)), shared with
    /// the fire callbacks so a fired watch prunes its own entry.
    #[allow(clippy::type_complexity)]
    armed: Arc<Mutex<HashMap<u64, HashMap<u64, (String, u64)>>>>,
}

impl KvEventService {
    /// Run a blocking request on a helper thread; the reply re-enters the
    /// loop via [`ConnHandle::complete`], which also replays any frames
    /// the connection pipelined behind it.
    fn defer(&self, conn: &ConnHandle, req: Request) -> FrameOutcome {
        let state = self.state.clone();
        let handle = conn.clone();
        let zero_copy = self.zero_copy;
        let spawned = std::thread::Builder::new()
            .name("kv-park".into())
            .spawn(move || {
                let resp = respond(&state, req);
                server_metrics().frames_out.incr();
                handle.complete(encode_reply(resp, zero_copy));
            });
        match spawned {
            Ok(_) => FrameOutcome::Deferred,
            Err(_) => FrameOutcome::Close,
        }
    }
}

impl Service for KvEventService {
    fn on_open(&self, _conn: &ConnHandle) {
        server_metrics().connections.add(1);
    }

    fn on_frame(&self, conn: &ConnHandle, body: Vec<u8>) -> FrameOutcome {
        let m = server_metrics();
        m.frames_in.incr();
        let req = match Request::from_bytes(&body) {
            Ok(req) => req,
            Err(_) => return FrameOutcome::Close,
        };
        match req {
            Request::Subscribe { channels } => {
                // Push mode: ack, then hand the raw stream to a pump
                // thread — subscriber frames no longer interleave with
                // request traffic, so the loop is done with this socket.
                let rx = self.state.subscribe(&channels);
                let stop = self.stop.clone();
                m.frames_out.incr();
                FrameOutcome::Handoff {
                    reply: Response::Ok.to_bytes().into(),
                    take: Box::new(move |stream| {
                        let _ = std::thread::Builder::new()
                            .name("kv-sub".into())
                            .spawn(move || pump_subscriber(stream, rx, stop));
                    }),
                }
            }
            Request::Watch { key, id } => {
                // The Ok ack holds FIFO position; the Notify push is
                // out-of-band. An immediate fire (key already present)
                // queues the Notify in the loop's inbox, which drains
                // after this reply is buffered — ack still lands first.
                let push = conn.clone();
                let armed = self.armed.clone();
                let conn_id = conn.conn_id();
                let zero_copy = self.zero_copy;
                let token = self.state.watch(
                    &key,
                    Box::new(move |v| {
                        let fired = Instant::now();
                        if let Some(per) =
                            armed.lock().unwrap().get_mut(&conn_id)
                        {
                            per.remove(&id);
                        }
                        let m = server_metrics();
                        // The engine hands the stored allocation over;
                        // the push rides it as a shared window.
                        let frame = encode_reply(
                            Response::Notify { id, value: Buf::from_arc(v) },
                            zero_copy,
                        );
                        push.push_frame(
                            frame,
                            Some((fired, m.wake_us.clone())),
                        );
                        m.frames_out.incr();
                        m.notify_pushes.incr();
                    }),
                );
                if let Some(token) = token {
                    self.armed
                        .lock()
                        .unwrap()
                        .entry(conn_id)
                        .or_default()
                        .insert(id, (key, token));
                }
                m.frames_out.incr();
                FrameOutcome::Reply(Response::Ok.to_bytes().into())
            }
            Request::Unwatch { key, id } => {
                let entry = self
                    .armed
                    .lock()
                    .unwrap()
                    .get_mut(&conn.conn_id())
                    .and_then(|per| per.remove(&id));
                let removed = match entry {
                    Some((key, token)) => self.state.unwatch(&key, token),
                    None => {
                        let _ = key;
                        false
                    }
                };
                m.frames_out.incr();
                FrameOutcome::Reply(
                    Response::Int(i64::from(removed)).to_bytes().into(),
                )
            }
            Request::WaitGet { key, timeout_ms } => {
                // Probe: an atomic get — a present value answers without
                // parking, only a miss pays for a helper thread.
                let start = Instant::now();
                if let Some(v) = self.state.get_buf(&key) {
                    m.op_us.record_duration(start.elapsed());
                    m.frames_out.incr();
                    return FrameOutcome::Reply(encode_reply(
                        Response::Value(Some(v)),
                        self.zero_copy,
                    ));
                }
                self.defer(conn, Request::WaitGet { key, timeout_ms })
            }
            Request::BRPop { list, timeout_ms } => {
                // Probe: a zero-deadline brpop IS the op — it pops
                // atomically when non-empty and never parks.
                let start = Instant::now();
                if let Some(v) =
                    self.state.brpop(&list, Some(Duration::ZERO))
                {
                    m.op_us.record_duration(start.elapsed());
                    m.frames_out.incr();
                    return FrameOutcome::Reply(encode_reply(
                        Response::Value(Some(Buf::from_vec(v.0))),
                        self.zero_copy,
                    ));
                }
                self.defer(conn, Request::BRPop { list, timeout_ms })
            }
            req if is_blocking(&req) => self.defer(conn, req),
            other => {
                m.frames_out.incr();
                FrameOutcome::Reply(encode_reply(
                    respond(&self.state, other),
                    self.zero_copy,
                ))
            }
        }
    }

    fn on_close(&self, conn_id: u64) {
        server_metrics().connections.add(-1);
        // Disarm whatever the connection left armed, so dead peers never
        // leak registry entries.
        let per = self.armed.lock().unwrap().remove(&conn_id);
        if let Some(per) = per {
            for (key, token) in per.into_values() {
                self.state.unwatch(&key, token);
            }
        }
    }
}

/// Forward published messages to a handed-off subscriber socket until
/// the peer hangs up or the server stops.
fn pump_subscriber(
    stream: TcpStream,
    rx: std::sync::mpsc::Receiver<PubSubMsg>,
    stop: Arc<AtomicBool>,
) {
    let _ = stream.set_write_timeout(Some(WRITE_STALL_CAP));
    let mut writer = BufWriter::with_capacity(1 << 18, stream);
    // One encode buffer for the life of the subscription, not per push.
    let mut scratch = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(msg) => {
                let push = Response::Message {
                    channel: msg.channel,
                    payload: msg.payload,
                };
                if write_frame_reusing(&mut writer, &push, &mut scratch)
                    .is_err()
                {
                    return; // subscriber gone
                }
                server_metrics().frames_out.incr();
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

// ---------------------------------------------------------------------------
// Threaded ingress
// ---------------------------------------------------------------------------

/// The write half of a threaded connection: socket buffer plus a
/// reusable encode scratch, so steady-state frames cost zero fresh
/// allocations instead of one `Vec` each.
struct ConnWriter {
    writer: BufWriter<TcpStream>,
    scratch: Vec<u8>,
}

/// The sharable write half of a threaded connection: FIFO responses from
/// the request loop and out-of-band `Notify` pushes from watch callbacks
/// interleave at frame granularity under one lock.
type SharedWriter = Arc<Mutex<ConnWriter>>;

/// Cap on how long any single frame write may block on a peer's socket
/// buffer. Notify pushes run on the *storing* connection's thread, so
/// without a bound one watcher that stopped reading could wedge unrelated
/// writers; with it, the wedged peer's pushes start erroring (and its
/// connection dies) while writers stall at most this long.
const WRITE_STALL_CAP: Duration = Duration::from_secs(5);

/// Watches one threaded connection armed, shared with its fire callbacks
/// so a fired watch prunes its own entry: client watch id -> (key,
/// registry token).
type ArmedWatches = Arc<Mutex<HashMap<u64, (String, u64)>>>;

/// Write one FIFO/push frame and count it. The threaded path always
/// flat-encodes through the connection scratch, so value payloads are
/// charged to `data.bytes_copied` (the event loop's zero-copy mode is
/// what avoids them).
fn send(writer: &SharedWriter, msg: &Response) -> Result<()> {
    send_locked(&mut writer.lock().unwrap(), msg)
}

fn send_locked(conn: &mut ConnWriter, msg: &Response) -> Result<()> {
    let out = msg.payload_len() as u64;
    if out > 0 {
        let dm = telemetry::data_metrics();
        dm.value_bytes_out.add(out);
        dm.bytes_copied.add(out);
    }
    write_frame_reusing(&mut conn.writer, msg, &mut conn.scratch)?;
    server_metrics().frames_out.incr();
    Ok(())
}

fn serve_connection(
    stream: TcpStream,
    state: KvState,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_write_timeout(Some(WRITE_STALL_CAP))?;
    let mut reader =
        std::io::BufReader::with_capacity(1 << 18, stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(ConnWriter {
        writer: BufWriter::with_capacity(1 << 18, stream),
        scratch: Vec::new(),
    }));
    let armed: ArmedWatches = Arc::new(Mutex::new(HashMap::new()));
    server_metrics().connections.add(1);
    let result = serve_requests(&mut reader, &writer, &state, &stop, &armed);
    server_metrics().connections.add(-1);
    // A closing connection disarms whatever it left armed, so dead peers
    // never leak registry entries (their Notify would go nowhere anyway).
    for (key, token) in
        std::mem::take(&mut *armed.lock().unwrap()).into_values()
    {
        state.unwatch(&key, token);
    }
    result
}

fn serve_requests(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &SharedWriter,
    state: &KvState,
    stop: &Arc<AtomicBool>,
    armed: &ArmedWatches,
) -> Result<()> {
    loop {
        // `KvServer::shutdown` closes tracked sockets, which surfaces here
        // as EOF/error and ends the connection thread.
        let req: Option<Request> = read_frame(reader)?;
        let Some(req) = req else { return Ok(()) };
        server_metrics().frames_in.incr();
        match req {
            Request::Subscribe { channels } => {
                // Connection flips into push mode: acknowledge then forward
                // published messages until the peer hangs up.
                let rx = state.subscribe(&channels);
                send(writer, &Response::Ok)?;
                loop {
                    match rx.recv_timeout(Duration::from_millis(100)) {
                        Ok(msg) => {
                            let push = Response::Message {
                                channel: msg.channel,
                                payload: msg.payload,
                            };
                            let sent = send(writer, &push);
                            if sent.is_err() {
                                return Ok(()); // subscriber gone
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            if stop.load(Ordering::Relaxed) {
                                return Ok(());
                            }
                        }
                        Err(_) => return Ok(()),
                    }
                }
            }
            Request::Watch { key, id } => {
                // Ack FIFO first; the Notify push is out-of-band (it may
                // land immediately after when the key already exists).
                send(writer, &Response::Ok)?;
                let push = writer.clone();
                let prune = armed.clone();
                let token = state.watch(
                    &key,
                    Box::new(move |v| {
                        // A fired watch prunes its own tracking entry
                        // (armed-lock strictly before writer-lock, the
                        // same order Unwatch uses). Fired from the
                        // storing writer's thread; a dead or wedged peer
                        // just loses its push, bounded by the socket
                        // write timeout.
                        let fired = Instant::now();
                        prune.lock().unwrap().remove(&id);
                        let sent = send_locked(
                            &mut push.lock().unwrap(),
                            &Response::Notify { id, value: Buf::from_arc(v) },
                        );
                        if sent.is_ok() {
                            let m = server_metrics();
                            m.notify_pushes.incr();
                            m.wake_us.record_duration(fired.elapsed());
                        }
                    }),
                );
                if let Some(token) = token {
                    // Raced an immediate fire? The callback may have run
                    // (and found nothing to prune) before this insert —
                    // but then the registry already discharged the token,
                    // so the stale entry only costs a no-op unwatch later.
                    armed.lock().unwrap().insert(id, (key, token));
                }
            }
            Request::Unwatch { key, id } => {
                let entry = armed.lock().unwrap().remove(&id);
                let removed = match entry {
                    Some((key, token)) => state.unwatch(&key, token),
                    // Unknown id: already fired (pruned at fire time) or
                    // never armed here.
                    None => {
                        let _ = key;
                        false
                    }
                };
                send(writer, &Response::Int(i64::from(removed)))?;
            }
            other => {
                let resp = respond(state, other);
                send(writer, &resp)?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Bytes;
    use crate::kv::client::{KvClient, KvSubscriber};
    use crate::net::{Ingress, ServerBuilder};

    #[test]
    fn server_basic_ops_over_tcp() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.ping().unwrap();
        client.set("k", Bytes(vec![1, 2, 3])).unwrap();
        assert_eq!(client.get("k").unwrap(), Some(Bytes(vec![1, 2, 3])));
        assert!(client.exists("k").unwrap());
        assert_eq!(
            client.mget(&["k".into(), "nope".into()]).unwrap(),
            vec![Some(Bytes(vec![1, 2, 3])), None]
        );
        assert!(client.del("k").unwrap());
        assert_eq!(client.get("k").unwrap(), None);
    }

    #[test]
    fn threaded_ingress_basic_ops_and_watch() {
        let server = ServerBuilder::new()
            .ingress(Ingress::Threaded)
            .spawn_kv()
            .unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.set("k", Bytes(vec![7])).unwrap();
        assert_eq!(client.get("k").unwrap(), Some(Bytes(vec![7])));
        let addr = server.addr;
        let waiter = std::thread::spawn(move || {
            let c = KvClient::connect(addr).unwrap();
            c.wait_get("tk", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        client.set("tk", Bytes(vec![8])).unwrap();
        assert_eq!(waiter.join().unwrap(), Some(Bytes(vec![8])));
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_spawn_shims_still_work() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.ping().unwrap();
        let state = KvState::new();
        state.set("pre", Bytes(vec![1]));
        let server2 = KvServer::spawn_with_state(state).unwrap();
        let client2 = KvClient::connect(server2.addr).unwrap();
        assert_eq!(client2.get("pre").unwrap(), Some(Bytes(vec![1])));
    }

    #[test]
    fn mput_mget_roundtrip_over_tcp() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client
            .mput(vec![
                ("a".into(), Bytes(vec![1])),
                ("b".into(), Bytes(vec![2, 2])),
                ("c".into(), Bytes(Vec::new())),
            ])
            .unwrap();
        // Partial miss: positions align with the request, absent keys None.
        assert_eq!(
            client
                .mget(&["a".into(), "missing".into(), "c".into(), "b".into()])
                .unwrap(),
            vec![
                Some(Bytes(vec![1])),
                None,
                Some(Bytes(Vec::new())),
                Some(Bytes(vec![2, 2]))
            ]
        );
        // Empty batches are legal on both ops.
        client.mput(Vec::new()).unwrap();
        assert_eq!(client.mget(&[]).unwrap(), Vec::new());
        // MPut overwrites like Set.
        client.mput(vec![("a".into(), Bytes(vec![9]))]).unwrap();
        assert_eq!(client.get("a").unwrap(), Some(Bytes(vec![9])));
        let (keys, _, _) = client.stats().unwrap();
        assert_eq!(keys, 3);
    }

    #[test]
    fn mdel_over_tcp() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client
            .mput(vec![
                ("a".into(), Bytes(vec![1])),
                ("b".into(), Bytes(vec![2])),
            ])
            .unwrap();
        assert_eq!(
            client.mdel(&["a".into(), "b".into(), "nope".into()]).unwrap(),
            2
        );
        assert_eq!(client.get("a").unwrap(), None);
        assert_eq!(client.mdel(&[]).unwrap(), 0);
    }

    #[test]
    fn mexists_over_tcp() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client
            .mput(vec![
                ("a".into(), Bytes(vec![1])),
                ("b".into(), Bytes(vec![2])),
            ])
            .unwrap();
        assert_eq!(
            client
                .mexists(&["a".into(), "nope".into(), "b".into()])
                .unwrap(),
            vec![true, false, true]
        );
        assert_eq!(client.mexists(&[]).unwrap(), Vec::<bool>::new());
    }

    #[test]
    fn mput_wakes_cross_client_waiter() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let addr = server.addr;
        let waiter = std::thread::spawn(move || {
            let c = KvClient::connect(addr).unwrap();
            c.wait_get("batch-k", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let setter = KvClient::connect(server.addr).unwrap();
        setter
            .mput(vec![("batch-k".into(), Bytes(vec![4]))])
            .unwrap();
        assert_eq!(waiter.join().unwrap(), Some(Bytes(vec![4])));
    }

    #[test]
    fn wait_get_across_clients() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let addr = server.addr;
        let waiter = std::thread::spawn(move || {
            let c = KvClient::connect(addr).unwrap();
            c.wait_get("slow", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let setter = KvClient::connect(server.addr).unwrap();
        setter.set("slow", Bytes(vec![9])).unwrap();
        assert_eq!(waiter.join().unwrap(), Some(Bytes(vec![9])));
    }

    #[test]
    fn pubsub_over_tcp() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let sub =
            KvSubscriber::connect(server.addr, &["topic".into()]).unwrap();
        // Give the subscriber registration a beat.
        std::thread::sleep(Duration::from_millis(30));
        let publisher = KvClient::connect(server.addr).unwrap();
        let n = publisher.publish("topic", Bytes(vec![42])).unwrap();
        assert_eq!(n, 1);
        let msg = sub.next(Some(Duration::from_secs(2))).unwrap().unwrap();
        assert_eq!(msg.channel, "topic");
        assert_eq!(msg.payload, Bytes(vec![42]));
    }

    #[test]
    fn queue_over_tcp() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let c = KvClient::connect(server.addr).unwrap();
        c.lpush("q", Bytes(vec![1])).unwrap();
        c.lpush("q", Bytes(vec![2])).unwrap();
        assert_eq!(c.brpop("q", Some(Duration::from_secs(1))).unwrap(),
                   Some(Bytes(vec![1])));
        assert_eq!(c.brpop("q", Some(Duration::from_millis(20))).unwrap()
                       .map(|b| b.0),
                   Some(vec![2]));
        assert_eq!(c.brpop("q", Some(Duration::from_millis(20))).unwrap(),
                   None);
    }

    #[test]
    fn stats_and_flush() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let c = KvClient::connect(server.addr).unwrap();
        c.set("a", Bytes(vec![0; 100])).unwrap();
        let (keys, bytes, ops) = c.stats().unwrap();
        assert_eq!(keys, 1);
        assert_eq!(bytes, 100);
        assert!(ops >= 1);
        c.flush_all().unwrap();
        let (keys, bytes, _) = c.stats().unwrap();
        assert_eq!((keys, bytes), (0, 0));
    }

    #[test]
    fn server_shutdown_rejects_new_connections() {
        let mut server = ServerBuilder::new().spawn_kv().unwrap();
        let addr = server.addr;
        server.shutdown();
        std::thread::sleep(Duration::from_millis(20));
        // Either connect fails or the first request errors out.
        let r = KvClient::connect(addr).and_then(|c| c.ping());
        assert!(r.is_err());
    }

    #[test]
    fn threaded_shutdown_rejects_new_connections() {
        let mut server = ServerBuilder::new()
            .ingress(Ingress::Threaded)
            .spawn_kv()
            .unwrap();
        let addr = server.addr;
        server.shutdown();
        std::thread::sleep(Duration::from_millis(20));
        let r = KvClient::connect(addr).and_then(|c| c.ping());
        assert!(r.is_err());
    }

    #[test]
    fn concurrent_clients_hammer() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let addr = server.addr;
        let hs: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let c = KvClient::connect(addr).unwrap();
                    for j in 0..50 {
                        let key = format!("k{i}-{j}");
                        c.set(&key, Bytes(vec![i as u8, j as u8])).unwrap();
                        assert_eq!(
                            c.get(&key).unwrap(),
                            Some(Bytes(vec![i as u8, j as u8]))
                        );
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let c = KvClient::connect(addr).unwrap();
        let (keys, _, _) = c.stats().unwrap();
        assert_eq!(keys, 200);
    }
}
