//! TCP front-end for the KV engine: thread-per-connection, length-prefixed
//! frames, Redis-style subscribe mode, and out-of-band watch pushes.
//!
//! A connection's writer is shared between its request loop and the watch
//! callbacks it arms: `Watch` registers in the engine's registry
//! ([`KvState::watch`]) with a callback that pushes the `Notify` frame
//! from whichever writer thread stores the key — the connection thread
//! never parks, so an armed watch costs the server nothing until it
//! fires. Watches a connection leaves armed are disarmed when it closes.

use std::collections::HashMap;
use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::codec::{Bytes, Encode};
use crate::error::Result;
use crate::kv::protocol::{read_frame, write_frame, Request, Response};
use crate::kv::state::KvState;
use crate::metrics::telemetry;

/// Cached registry handles for the server's hot-path metrics (one lookup
/// per process, not per frame).
struct ServerMetrics {
    connections: Arc<telemetry::Gauge>,
    frames_in: Arc<telemetry::Counter>,
    frames_out: Arc<telemetry::Counter>,
    notify_pushes: Arc<telemetry::Counter>,
    op_us: Arc<telemetry::Histogram>,
    wake_us: Arc<telemetry::Histogram>,
}

fn server_metrics() -> &'static ServerMetrics {
    static M: OnceLock<ServerMetrics> = OnceLock::new();
    M.get_or_init(|| ServerMetrics {
        connections: telemetry::gauge("kv.server.connections"),
        frames_in: telemetry::counter("kv.server.frames_in"),
        frames_out: telemetry::counter("kv.server.frames_out"),
        notify_pushes: telemetry::counter("kv.server.notify_pushes"),
        op_us: telemetry::histogram("kv.server.op_us"),
        wake_us: telemetry::histogram("watch.wake_to_notify_us"),
    })
}

/// A running KV server. Dropping the handle shuts it down.
pub struct KvServer {
    pub addr: SocketAddr,
    state: KvState,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Live connection sockets, force-closed on shutdown.
    conns: Arc<std::sync::Mutex<Vec<TcpStream>>>,
}

impl KvServer {
    /// Bind to 127.0.0.1 on an ephemeral port and start serving.
    pub fn spawn() -> Result<KvServer> {
        Self::spawn_with_state(KvState::new())
    }

    /// Serve an externally created state (lets tests/benches share the
    /// engine between a TCP endpoint and embedded handles).
    pub fn spawn_with_state(state: KvState) -> Result<KvServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let state2 = state.clone();
        let conns: Arc<std::sync::Mutex<Vec<TcpStream>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let conns2 = conns.clone();
        // Accept loop polls with a timeout so shutdown is prompt.
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new()
            .name(format!("kv-accept-{}", addr.port()))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if let Ok(clone) = stream.try_clone() {
                                conns2.lock().unwrap().push(clone);
                            }
                            let st = state2.clone();
                            let stop3 = stop2.clone();
                            std::thread::Builder::new()
                                .name("kv-conn".into())
                                .spawn(move || {
                                    let _ = serve_connection(stream, st, stop3);
                                })
                                .expect("spawn kv-conn");
                        }
                        Err(ref e)
                            if e.kind() == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn kv-accept");
        Ok(KvServer {
            addr,
            state,
            stop,
            accept_thread: Some(accept_thread),
            conns,
        })
    }

    /// The shared engine (for embedded access / gauges).
    pub fn state(&self) -> &KvState {
        &self.state
    }

    /// Stop accepting, force-close live connections, and wind down.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for KvServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_request(state: &KvState, req: Request) -> Response {
    match req {
        Request::Get { key } => Response::Value(state.get(&key)),
        Request::Set { key, value } => {
            if let Err(e) = KvState::check_value_size(&value) {
                return Response::Error(e.to_string());
            }
            state.set(&key, value);
            Response::Ok
        }
        Request::SetNx { key, value } => {
            Response::Int(i64::from(state.set_nx(&key, value)))
        }
        Request::Del { key } => Response::Int(i64::from(state.del(&key))),
        Request::MDel { keys } => Response::Int(state.mdel(&keys)),
        Request::MExists { keys } => Response::Bools(state.mexists(&keys)),
        Request::Exists { key } => Response::Int(i64::from(state.exists(&key))),
        Request::MGet { keys } => Response::Values(state.mget(&keys)),
        Request::MPut { items } => {
            for (_, value) in &items {
                if let Err(e) = KvState::check_value_size(value) {
                    return Response::Error(e.to_string());
                }
            }
            state.mset(items);
            Response::Ok
        }
        Request::WaitGet { key, timeout_ms } => {
            let timeout = if timeout_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(timeout_ms))
            };
            Response::Value(state.wait_get(&key, timeout))
        }
        Request::Incr { key, by } => Response::Int(state.incr(&key, by)),
        Request::Keys { prefix } => Response::KeysList(state.keys(&prefix)),
        Request::Publish { channel, payload } => {
            Response::Int(state.publish(&channel, payload))
        }
        Request::LPush { list, value } => {
            state.lpush(&list, value);
            Response::Ok
        }
        Request::BRPop { list, timeout_ms } => {
            let timeout = if timeout_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(timeout_ms))
            };
            Response::Value(state.brpop(&list, timeout))
        }
        Request::FlushAll => {
            state.flush_all();
            Response::Ok
        }
        Request::Stats => {
            let (keys, bytes, ops) = state.stats();
            Response::StatsReply { keys, bytes, ops }
        }
        Request::Ping => Response::Ok,
        Request::Telemetry => Response::Telemetry {
            data: Bytes(telemetry::snapshot().to_bytes()),
        },
        Request::Subscribe { .. }
        | Request::Watch { .. }
        | Request::Unwatch { .. }
        | Request::Traced { .. } => {
            unreachable!("push-mode/envelope requests handled in serve_requests")
        }
    }
}

/// The sharable write half of a connection: FIFO responses from the
/// request loop and out-of-band `Notify` pushes from watch callbacks
/// interleave at frame granularity under one lock.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

/// Cap on how long any single frame write may block on a peer's socket
/// buffer. Notify pushes run on the *storing* connection's thread, so
/// without a bound one watcher that stopped reading could wedge unrelated
/// writers; with it, the wedged peer's pushes start erroring (and its
/// connection dies) while writers stall at most this long.
const WRITE_STALL_CAP: Duration = Duration::from_secs(5);

/// Watches one connection armed, shared with its fire callbacks so a
/// fired watch prunes its own entry: client watch id -> (key, registry
/// token).
type ArmedWatches = Arc<Mutex<HashMap<u64, (String, u64)>>>;

/// Write one FIFO/push frame and count it.
fn send<T: Encode>(writer: &SharedWriter, msg: &T) -> Result<()> {
    write_frame(&mut *writer.lock().unwrap(), msg)?;
    server_metrics().frames_out.incr();
    Ok(())
}

fn serve_connection(
    stream: TcpStream,
    state: KvState,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_write_timeout(Some(WRITE_STALL_CAP))?;
    let mut reader = std::io::BufReader::with_capacity(1 << 18, stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(
        BufWriter::with_capacity(1 << 18, stream),
    ));
    let armed: ArmedWatches = Arc::new(Mutex::new(HashMap::new()));
    server_metrics().connections.add(1);
    let result = serve_requests(&mut reader, &writer, &state, &stop, &armed);
    server_metrics().connections.add(-1);
    // A closing connection disarms whatever it left armed, so dead peers
    // never leak registry entries (their Notify would go nowhere anyway).
    for (key, token) in std::mem::take(&mut *armed.lock().unwrap()).into_values()
    {
        state.unwatch(&key, token);
    }
    result
}

fn serve_requests(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &SharedWriter,
    state: &KvState,
    stop: &Arc<AtomicBool>,
    armed: &ArmedWatches,
) -> Result<()> {
    loop {
        // `KvServer::shutdown` closes tracked sockets, which surfaces here
        // as EOF/error and ends the connection thread.
        let req: Option<Request> = read_frame(reader)?;
        let Some(req) = req else { return Ok(()) };
        server_metrics().frames_in.incr();
        match req {
            Request::Subscribe { channels } => {
                // Connection flips into push mode: acknowledge then forward
                // published messages until the peer hangs up.
                let rx = state.subscribe(&channels);
                send(writer, &Response::Ok)?;
                loop {
                    match rx.recv_timeout(Duration::from_millis(100)) {
                        Ok(msg) => {
                            let push = Response::Message {
                                channel: msg.channel,
                                payload: msg.payload,
                            };
                            let sent = send(writer, &push);
                            if sent.is_err() {
                                return Ok(()); // subscriber gone
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            if stop.load(Ordering::Relaxed) {
                                return Ok(());
                            }
                        }
                        Err(_) => return Ok(()),
                    }
                }
            }
            Request::Watch { key, id } => {
                // Ack FIFO first; the Notify push is out-of-band (it may
                // land immediately after when the key already exists).
                send(writer, &Response::Ok)?;
                let push = writer.clone();
                let prune = armed.clone();
                let token = state.watch(
                    &key,
                    Box::new(move |v| {
                        // A fired watch prunes its own tracking entry
                        // (armed-lock strictly before writer-lock, the
                        // same order Unwatch uses). Fired from the
                        // storing writer's thread; a dead or wedged peer
                        // just loses its push, bounded by the socket
                        // write timeout.
                        let fired = Instant::now();
                        prune.lock().unwrap().remove(&id);
                        let sent = write_frame(
                            &mut *push.lock().unwrap(),
                            &Response::Notify { id, value: Bytes(v.to_vec()) },
                        );
                        if sent.is_ok() {
                            let m = server_metrics();
                            m.frames_out.incr();
                            m.notify_pushes.incr();
                            m.wake_us.record_duration(fired.elapsed());
                        }
                    }),
                );
                if let Some(token) = token {
                    // Raced an immediate fire? The callback may have run
                    // (and found nothing to prune) before this insert —
                    // but then the registry already discharged the token,
                    // so the stale entry only costs a no-op unwatch later.
                    armed.lock().unwrap().insert(id, (key, token));
                }
            }
            Request::Unwatch { key, id } => {
                let entry = armed.lock().unwrap().remove(&id);
                let removed = match entry {
                    Some((key, token)) => state.unwatch(&key, token),
                    // Unknown id: already fired (pruned at fire time) or
                    // never armed here.
                    None => {
                        let _ = key;
                        false
                    }
                };
                send(writer, &Response::Int(i64::from(removed)))?;
            }
            Request::Traced { trace_id, span_id, inner } => {
                // Unwrap the envelope: adopt the caller's trace, stamp a
                // server-side span parented on the client's, and execute
                // the inner op as if it arrived bare. Push-mode inners
                // would change FIFO semantics mid-trace, so they are
                // rejected rather than silently untraced.
                let resp = match *inner {
                    Request::Subscribe { .. }
                    | Request::Watch { .. }
                    | Request::Unwatch { .. }
                    | Request::Traced { .. } => Response::Error(
                        "traced envelope cannot carry push-mode or nested \
                         requests"
                            .into(),
                    ),
                    inner => {
                        let name = inner.name();
                        let span = telemetry::next_span_id();
                        let start = Instant::now();
                        let resp = handle_request(state, inner);
                        server_metrics().op_us.record_duration(start.elapsed());
                        telemetry::trace_event(
                            trace_id, span, span_id, "kv.server", name,
                        );
                        resp
                    }
                };
                send(writer, &resp)?;
            }
            other => {
                let start = Instant::now();
                let resp = handle_request(state, other);
                server_metrics().op_us.record_duration(start.elapsed());
                send(writer, &resp)?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Bytes;
    use crate::kv::client::{KvClient, KvSubscriber};

    #[test]
    fn server_basic_ops_over_tcp() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client.ping().unwrap();
        client.set("k", Bytes(vec![1, 2, 3])).unwrap();
        assert_eq!(client.get("k").unwrap(), Some(Bytes(vec![1, 2, 3])));
        assert!(client.exists("k").unwrap());
        assert_eq!(
            client.mget(&["k".into(), "nope".into()]).unwrap(),
            vec![Some(Bytes(vec![1, 2, 3])), None]
        );
        assert!(client.del("k").unwrap());
        assert_eq!(client.get("k").unwrap(), None);
    }

    #[test]
    fn mput_mget_roundtrip_over_tcp() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client
            .mput(vec![
                ("a".into(), Bytes(vec![1])),
                ("b".into(), Bytes(vec![2, 2])),
                ("c".into(), Bytes(Vec::new())),
            ])
            .unwrap();
        // Partial miss: positions align with the request, absent keys None.
        assert_eq!(
            client
                .mget(&["a".into(), "missing".into(), "c".into(), "b".into()])
                .unwrap(),
            vec![
                Some(Bytes(vec![1])),
                None,
                Some(Bytes(Vec::new())),
                Some(Bytes(vec![2, 2]))
            ]
        );
        // Empty batches are legal on both ops.
        client.mput(Vec::new()).unwrap();
        assert_eq!(client.mget(&[]).unwrap(), Vec::new());
        // MPut overwrites like Set.
        client.mput(vec![("a".into(), Bytes(vec![9]))]).unwrap();
        assert_eq!(client.get("a").unwrap(), Some(Bytes(vec![9])));
        let (keys, _, _) = client.stats().unwrap();
        assert_eq!(keys, 3);
    }

    #[test]
    fn mdel_over_tcp() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client
            .mput(vec![
                ("a".into(), Bytes(vec![1])),
                ("b".into(), Bytes(vec![2])),
            ])
            .unwrap();
        assert_eq!(
            client.mdel(&["a".into(), "b".into(), "nope".into()]).unwrap(),
            2
        );
        assert_eq!(client.get("a").unwrap(), None);
        assert_eq!(client.mdel(&[]).unwrap(), 0);
    }

    #[test]
    fn mexists_over_tcp() {
        let server = KvServer::spawn().unwrap();
        let client = KvClient::connect(server.addr).unwrap();
        client
            .mput(vec![
                ("a".into(), Bytes(vec![1])),
                ("b".into(), Bytes(vec![2])),
            ])
            .unwrap();
        assert_eq!(
            client
                .mexists(&["a".into(), "nope".into(), "b".into()])
                .unwrap(),
            vec![true, false, true]
        );
        assert_eq!(client.mexists(&[]).unwrap(), Vec::<bool>::new());
    }

    #[test]
    fn mput_wakes_cross_client_waiter() {
        let server = KvServer::spawn().unwrap();
        let addr = server.addr;
        let waiter = std::thread::spawn(move || {
            let c = KvClient::connect(addr).unwrap();
            c.wait_get("batch-k", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let setter = KvClient::connect(server.addr).unwrap();
        setter
            .mput(vec![("batch-k".into(), Bytes(vec![4]))])
            .unwrap();
        assert_eq!(waiter.join().unwrap(), Some(Bytes(vec![4])));
    }

    #[test]
    fn wait_get_across_clients() {
        let server = KvServer::spawn().unwrap();
        let addr = server.addr;
        let waiter = std::thread::spawn(move || {
            let c = KvClient::connect(addr).unwrap();
            c.wait_get("slow", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let setter = KvClient::connect(server.addr).unwrap();
        setter.set("slow", Bytes(vec![9])).unwrap();
        assert_eq!(waiter.join().unwrap(), Some(Bytes(vec![9])));
    }

    #[test]
    fn pubsub_over_tcp() {
        let server = KvServer::spawn().unwrap();
        let sub =
            KvSubscriber::connect(server.addr, &["topic".into()]).unwrap();
        // Give the subscriber registration a beat.
        std::thread::sleep(Duration::from_millis(30));
        let publisher = KvClient::connect(server.addr).unwrap();
        let n = publisher.publish("topic", Bytes(vec![42])).unwrap();
        assert_eq!(n, 1);
        let msg = sub.next(Some(Duration::from_secs(2))).unwrap().unwrap();
        assert_eq!(msg.channel, "topic");
        assert_eq!(msg.payload, Bytes(vec![42]));
    }

    #[test]
    fn queue_over_tcp() {
        let server = KvServer::spawn().unwrap();
        let c = KvClient::connect(server.addr).unwrap();
        c.lpush("q", Bytes(vec![1])).unwrap();
        c.lpush("q", Bytes(vec![2])).unwrap();
        assert_eq!(c.brpop("q", Some(Duration::from_secs(1))).unwrap(),
                   Some(Bytes(vec![1])));
        assert_eq!(c.brpop("q", Some(Duration::from_millis(20))).unwrap()
                       .map(|b| b.0),
                   Some(vec![2]));
        assert_eq!(c.brpop("q", Some(Duration::from_millis(20))).unwrap(),
                   None);
    }

    #[test]
    fn stats_and_flush() {
        let server = KvServer::spawn().unwrap();
        let c = KvClient::connect(server.addr).unwrap();
        c.set("a", Bytes(vec![0; 100])).unwrap();
        let (keys, bytes, ops) = c.stats().unwrap();
        assert_eq!(keys, 1);
        assert_eq!(bytes, 100);
        assert!(ops >= 1);
        c.flush_all().unwrap();
        let (keys, bytes, _) = c.stats().unwrap();
        assert_eq!((keys, bytes), (0, 0));
    }

    #[test]
    fn server_shutdown_rejects_new_connections() {
        let mut server = KvServer::spawn().unwrap();
        let addr = server.addr;
        server.shutdown();
        std::thread::sleep(Duration::from_millis(20));
        // Either connect fails or the first request errors out.
        let r = KvClient::connect(addr).and_then(|c| c.ping());
        assert!(r.is_err());
    }

    #[test]
    fn concurrent_clients_hammer() {
        let server = KvServer::spawn().unwrap();
        let addr = server.addr;
        let hs: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let c = KvClient::connect(addr).unwrap();
                    for j in 0..50 {
                        let key = format!("k{i}-{j}");
                        c.set(&key, Bytes(vec![i as u8, j as u8])).unwrap();
                        assert_eq!(
                            c.get(&key).unwrap(),
                            Some(Bytes(vec![i as u8, j as u8]))
                        );
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let c = KvClient::connect(addr).unwrap();
        let (keys, _, _) = c.stats().unwrap();
        assert_eq!(keys, 200);
    }
}
