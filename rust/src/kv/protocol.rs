//! Wire protocol for the redis-sim KV server.
//!
//! Frames are `u32` little-endian length + codec-encoded body. Commands
//! mirror the subset of Redis that ProxyStore's connectors use (GET/SET/
//! DEL/EXISTS/MGET/MPUT, pub/sub, lists with blocking pop) plus `WaitGet`
//! — a blocking GET with timeout that the ProxyFutures pattern uses so
//! proxy resolution can park server-side instead of client-side polling.
//! The batched pair (`MGet`/`MPut`) carries whole key sets in one frame —
//! the wire half of the shard fabric's `get_many`/`put_many` fast path.
//!
//! The protocol is strictly request/response FIFO per connection (the
//! server answers frames in arrival order; `Subscribe` flips a connection
//! into push mode and out of this contract). That ordering invariant is
//! what lets the pipelined [`KvClient`](crate::kv::KvClient) keep N
//! requests in flight on one socket and match responses to completion
//! handles by queue position alone — no request ids on the wire.
//!
//! The watch/notify plane is the one deliberate exception: `Watch`
//! registers a client-chosen id and is acknowledged FIFO like any other
//! request, but the eventual `Notify { id, .. }` push arrives
//! *out-of-band* — whenever some writer stores the key — and is routed by
//! its id, not by queue position. A parked watch therefore never stalls
//! the shared response stream the way the older server-side-blocking
//! `WaitGet` did (which still exists, still parks, and is still FIFO).

use std::io::{Read, Write};

use crate::codec::{Bytes, Decode, Encode, Reader, get_varint, put_varint};
use crate::error::{Error, Result};

/// Client → server commands.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Fetch a key's value.
    Get { key: String },
    /// Store a value.
    Set { key: String, value: Bytes },
    /// Store only if absent; replies `Int(1)` if stored, `Int(0)` if not.
    SetNx { key: String, value: Bytes },
    /// Delete a key; replies `Int(1)` if it existed.
    Del { key: String },
    /// Existence check; replies `Int(0/1)`.
    Exists { key: String },
    /// Batched get.
    MGet { keys: Vec<String> },
    /// Batched set: all pairs land under one lock acquisition and one wire
    /// round trip (the shard fabric's `put_many` fast path).
    MPut { items: Vec<(String, Bytes)> },
    /// Batched delete; replies `Int(n_removed)`. One frame for a whole
    /// eviction sweep (ownership lifetimes, bulk retention).
    MDel { keys: Vec<String> },
    /// Batched existence check; replies `Bools`, positionally aligned.
    /// Completes the batched KV protocol: membership probes over whole
    /// key sets (shard-fabric `exists_many`) pay one round trip.
    MExists { keys: Vec<String> },
    /// Blocking get: wait up to `timeout_ms` for the key to appear
    /// (0 = wait forever). Parks the connection's FIFO response stream for
    /// its whole duration — the watch plane (`Watch`/`Notify`) is the
    /// nonblocking replacement; this survives as a protocol-level
    /// primitive and for single-purpose connections.
    WaitGet { key: String, timeout_ms: u64 },
    /// Arm an out-of-band watch on `key` under a client-chosen `id`.
    /// Acknowledged `Ok` in FIFO order; fires a push-mode
    /// [`Response::Notify`] carrying `id` when the key is stored
    /// (immediately if it already exists). One-shot.
    Watch { key: String, id: u64 },
    /// Disarm a watch; replies `Int(1)` if it was still armed (it will
    /// never fire), `Int(0)` if it already fired or was unknown.
    Unwatch { key: String, id: u64 },
    /// Atomic increment; creates the key at 0 first.
    Incr { key: String, by: i64 },
    /// Keys with a prefix (admin/debug).
    Keys { prefix: String },
    /// Publish to a channel; replies `Int(n_receivers)`.
    Publish { channel: String, payload: Bytes },
    /// Switch this connection into subscriber push mode.
    Subscribe { channels: Vec<String> },
    /// Append to a list (queue semantics for stream shims).
    LPush { list: String, value: Bytes },
    /// Blocking pop from the tail; waits up to `timeout_ms` (0 = forever).
    BRPop { list: String, timeout_ms: u64 },
    /// Drop all data (test/bench reset).
    FlushAll,
    /// Server statistics snapshot.
    Stats,
    /// Liveness probe.
    Ping,
    /// Trace-context envelope: carries the client's trace/span ids across
    /// the wire so the server can stamp its own span onto the same trace.
    /// The server unwraps, records a server-side span parented on
    /// `span_id`, and executes `inner` exactly as if it had arrived bare.
    /// Push-mode commands (`Subscribe`/`Watch`/`Unwatch`) and nested
    /// envelopes are rejected — tracing must not change FIFO semantics.
    Traced { trace_id: u64, span_id: u64, inner: Box<Request> },
    /// Fetch the server process's full telemetry registry snapshot
    /// (encoded [`TelemetrySnapshot`](crate::metrics::TelemetrySnapshot)).
    Telemetry,
}

/// Server → client replies (plus async `Message` pushes in subscribe mode).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    /// GET/WaitGet/BRPop result; `None` = missing/timeout.
    Value(Option<Bytes>),
    /// MGET result, positionally aligned with the request keys.
    Values(Vec<Option<Bytes>>),
    /// MEXISTS result, positionally aligned with the request keys.
    Bools(Vec<bool>),
    Int(i64),
    KeysList(Vec<String>),
    /// Async pub/sub push.
    Message { channel: String, payload: Bytes },
    /// Out-of-band watch firing: pushed whenever a watched key is stored,
    /// routed client-side by the watch `id` (never FIFO-matched).
    Notify { id: u64, value: Bytes },
    /// Stats: (n_keys, resident_bytes, ops_served).
    StatsReply { keys: u64, bytes: u64, ops: u64 },
    Error(String),
    /// Encoded [`TelemetrySnapshot`](crate::metrics::TelemetrySnapshot)
    /// of the server process's registry (reply to `Request::Telemetry`).
    /// Kept opaque at this layer so the protocol does not depend on the
    /// snapshot's evolving field set.
    Telemetry { data: Bytes },
}

impl Request {
    /// Stable lower-case op label, used to name telemetry spans and
    /// histograms. `Traced` reports its inner op's label — the envelope
    /// itself is not an operation.
    pub fn name(&self) -> &'static str {
        match self {
            Request::Get { .. } => "get",
            Request::Set { .. } => "set",
            Request::SetNx { .. } => "set_nx",
            Request::Del { .. } => "del",
            Request::Exists { .. } => "exists",
            Request::MGet { .. } => "mget",
            Request::MPut { .. } => "mput",
            Request::MDel { .. } => "mdel",
            Request::MExists { .. } => "mexists",
            Request::WaitGet { .. } => "wait_get",
            Request::Watch { .. } => "watch",
            Request::Unwatch { .. } => "unwatch",
            Request::Incr { .. } => "incr",
            Request::Keys { .. } => "keys",
            Request::Publish { .. } => "publish",
            Request::Subscribe { .. } => "subscribe",
            Request::LPush { .. } => "lpush",
            Request::BRPop { .. } => "brpop",
            Request::FlushAll => "flush_all",
            Request::Stats => "stats",
            Request::Ping => "ping",
            Request::Traced { inner, .. } => inner.name(),
            Request::Telemetry => "telemetry",
        }
    }
}

macro_rules! tagged {
    ($buf:expr, $tag:expr $(, $field:expr)*) => {{
        put_varint($buf, $tag);
        $($field.encode($buf);)*
    }};
}

impl Encode for Request {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Request::Get { key } => tagged!(buf, 0, key),
            Request::Set { key, value } => tagged!(buf, 1, key, value),
            Request::SetNx { key, value } => tagged!(buf, 2, key, value),
            Request::Del { key } => tagged!(buf, 3, key),
            Request::Exists { key } => tagged!(buf, 4, key),
            Request::MGet { keys } => tagged!(buf, 5, keys),
            Request::WaitGet { key, timeout_ms } => {
                tagged!(buf, 6, key, timeout_ms)
            }
            Request::Incr { key, by } => tagged!(buf, 7, key, by),
            Request::Keys { prefix } => tagged!(buf, 8, prefix),
            Request::Publish { channel, payload } => {
                tagged!(buf, 9, channel, payload)
            }
            Request::Subscribe { channels } => tagged!(buf, 10, channels),
            Request::LPush { list, value } => tagged!(buf, 11, list, value),
            Request::BRPop { list, timeout_ms } => {
                tagged!(buf, 12, list, timeout_ms)
            }
            Request::FlushAll => tagged!(buf, 13),
            Request::Stats => tagged!(buf, 14),
            Request::Ping => tagged!(buf, 15),
            Request::MPut { items } => tagged!(buf, 16, items),
            Request::MDel { keys } => tagged!(buf, 17, keys),
            Request::MExists { keys } => tagged!(buf, 18, keys),
            Request::Watch { key, id } => tagged!(buf, 19, key, id),
            Request::Unwatch { key, id } => tagged!(buf, 20, key, id),
            Request::Traced { trace_id, span_id, inner } => {
                put_varint(buf, 21);
                trace_id.encode(buf);
                span_id.encode(buf);
                inner.as_ref().encode(buf);
            }
            Request::Telemetry => tagged!(buf, 22),
        }
    }
}

impl Decode for Request {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => Request::Get { key: Decode::decode(r)? },
            1 => Request::Set {
                key: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            2 => Request::SetNx {
                key: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            3 => Request::Del { key: Decode::decode(r)? },
            4 => Request::Exists { key: Decode::decode(r)? },
            5 => Request::MGet { keys: Decode::decode(r)? },
            6 => Request::WaitGet {
                key: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            7 => Request::Incr {
                key: Decode::decode(r)?,
                by: Decode::decode(r)?,
            },
            8 => Request::Keys { prefix: Decode::decode(r)? },
            9 => Request::Publish {
                channel: Decode::decode(r)?,
                payload: Decode::decode(r)?,
            },
            10 => Request::Subscribe { channels: Decode::decode(r)? },
            11 => Request::LPush {
                list: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            12 => Request::BRPop {
                list: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            13 => Request::FlushAll,
            14 => Request::Stats,
            15 => Request::Ping,
            16 => Request::MPut { items: Decode::decode(r)? },
            17 => Request::MDel { keys: Decode::decode(r)? },
            18 => Request::MExists { keys: Decode::decode(r)? },
            19 => Request::Watch {
                key: Decode::decode(r)?,
                id: Decode::decode(r)?,
            },
            20 => Request::Unwatch {
                key: Decode::decode(r)?,
                id: Decode::decode(r)?,
            },
            21 => Request::Traced {
                trace_id: Decode::decode(r)?,
                span_id: Decode::decode(r)?,
                inner: Box::new(Decode::decode(r)?),
            },
            22 => Request::Telemetry,
            t => return Err(Error::Protocol(format!("bad request tag {t}"))),
        })
    }
}

impl Encode for Response {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Response::Ok => tagged!(buf, 0),
            Response::Value(v) => tagged!(buf, 1, v),
            Response::Values(v) => tagged!(buf, 2, v),
            Response::Int(v) => tagged!(buf, 3, v),
            Response::KeysList(v) => tagged!(buf, 4, v),
            Response::Message { channel, payload } => {
                tagged!(buf, 5, channel, payload)
            }
            Response::StatsReply { keys, bytes, ops } => {
                tagged!(buf, 6, keys, bytes, ops)
            }
            Response::Error(msg) => tagged!(buf, 7, msg),
            Response::Bools(v) => tagged!(buf, 8, v),
            Response::Notify { id, value } => tagged!(buf, 9, id, value),
            Response::Telemetry { data } => tagged!(buf, 10, data),
        }
    }
}

impl Decode for Response {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => Response::Ok,
            1 => Response::Value(Decode::decode(r)?),
            2 => Response::Values(Decode::decode(r)?),
            3 => Response::Int(Decode::decode(r)?),
            4 => Response::KeysList(Decode::decode(r)?),
            5 => Response::Message {
                channel: Decode::decode(r)?,
                payload: Decode::decode(r)?,
            },
            6 => Response::StatsReply {
                keys: Decode::decode(r)?,
                bytes: Decode::decode(r)?,
                ops: Decode::decode(r)?,
            },
            7 => Response::Error(Decode::decode(r)?),
            8 => Response::Bools(Decode::decode(r)?),
            9 => Response::Notify {
                id: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            10 => Response::Telemetry { data: Decode::decode(r)? },
            t => return Err(Error::Protocol(format!("bad response tag {t}"))),
        })
    }
}

/// Write one length-prefixed frame and flush the writer.
pub fn write_frame<W: Write, T: Encode>(w: &mut W, msg: &T) -> Result<()> {
    write_frame_unflushed(w, msg)?;
    w.flush()?;
    Ok(())
}

/// Write one length-prefixed frame without flushing — the write-coalescing
/// client path buffers many frames and flushes once per policy tick.
pub fn write_frame_unflushed<W: Write, T: Encode>(
    w: &mut W,
    msg: &T,
) -> Result<()> {
    let body = msg.to_bytes();
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    Ok(())
}

/// Read one length-prefixed frame; `None` on clean EOF at a frame boundary.
pub fn read_frame<R: Read, T: Decode>(r: &mut R) -> Result<Option<T>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > 1 << 30 {
        return Err(Error::Protocol(format!("frame too large: {len}")));
    }
    // read_to_end on a bounded Take appends without zero-initializing the
    // buffer first (std fills via its uninit-spare-capacity path), which
    // matters at multi-MB frames.
    let mut body = Vec::with_capacity(len);
    let n = r.by_ref().take(len as u64).read_to_end(&mut body)?;
    if n < len {
        return Err(Error::Io(std::sync::Arc::new(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("frame truncated: {n}/{len}"),
        ))));
    }
    Ok(Some(T::from_bytes(&body)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &req).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        let back: Request = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(req, back);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Request::Get { key: "k".into() });
        roundtrip_req(Request::Set {
            key: "k".into(),
            value: Bytes(vec![1, 2, 3]),
        });
        roundtrip_req(Request::MGet { keys: vec!["a".into(), "b".into()] });
        roundtrip_req(Request::MPut {
            items: vec![
                ("a".into(), Bytes(vec![1, 2])),
                ("b".into(), Bytes(Vec::new())),
            ],
        });
        roundtrip_req(Request::MPut { items: Vec::new() });
        roundtrip_req(Request::MDel { keys: vec!["a".into(), "b".into()] });
        roundtrip_req(Request::MDel { keys: Vec::new() });
        roundtrip_req(Request::MExists { keys: vec!["a".into(), "b".into()] });
        roundtrip_req(Request::MExists { keys: Vec::new() });
        roundtrip_req(Request::WaitGet { key: "k".into(), timeout_ms: 500 });
        roundtrip_req(Request::Watch { key: "k".into(), id: u64::MAX });
        roundtrip_req(Request::Unwatch { key: "k".into(), id: 0 });
        roundtrip_req(Request::Publish {
            channel: "c".into(),
            payload: Bytes(vec![9; 100]),
        });
        roundtrip_req(Request::Subscribe { channels: vec!["c".into()] });
        roundtrip_req(Request::BRPop { list: "l".into(), timeout_ms: 0 });
        roundtrip_req(Request::FlushAll);
        roundtrip_req(Request::Ping);
        roundtrip_req(Request::Incr { key: "n".into(), by: -3 });
        roundtrip_req(Request::Telemetry);
        roundtrip_req(Request::Traced {
            trace_id: u64::MAX,
            span_id: 7,
            inner: Box::new(Request::Get { key: "k".into() }),
        });
        roundtrip_req(Request::Traced {
            trace_id: 1,
            span_id: 2,
            inner: Box::new(Request::MPut {
                items: vec![("a".into(), Bytes(vec![1, 2]))],
            }),
        });
    }

    #[test]
    fn request_names_follow_inner_op() {
        assert_eq!(Request::Get { key: "k".into() }.name(), "get");
        assert_eq!(Request::Telemetry.name(), "telemetry");
        let traced = Request::Traced {
            trace_id: 1,
            span_id: 2,
            inner: Box::new(Request::Set {
                key: "k".into(),
                value: Bytes(vec![1]),
            }),
        };
        assert_eq!(traced.name(), "set");
    }

    #[test]
    fn responses_roundtrip() {
        for resp in [
            Response::Ok,
            Response::Value(None),
            Response::Value(Some(Bytes(vec![0; 10]))),
            Response::Values(vec![None, Some(Bytes(vec![1]))]),
            Response::Bools(vec![true, false, true]),
            Response::Bools(Vec::new()),
            Response::Int(-7),
            Response::KeysList(vec!["x".into()]),
            Response::Message {
                channel: "c".into(),
                payload: Bytes(vec![2]),
            },
            Response::Notify { id: 42, value: Bytes(vec![1, 2, 3]) },
            Response::Notify { id: 0, value: Bytes(Vec::new()) },
            Response::StatsReply { keys: 1, bytes: 2, ops: 3 },
            Response::Error("boom".into()),
            Response::Telemetry { data: Bytes(vec![1, 2, 3]) },
            Response::Telemetry { data: Bytes(Vec::new()) },
        ] {
            let mut buf = Vec::new();
            write_frame(&mut buf, &resp).unwrap();
            let mut cur = std::io::Cursor::new(buf);
            let back: Response = read_frame(&mut cur).unwrap().unwrap();
            assert_eq!(resp, back);
        }
    }

    #[test]
    fn clean_eof_returns_none() {
        let buf: Vec<u8> = Vec::new();
        let mut cur = std::io::Cursor::new(buf);
        let r: Option<Request> = read_frame(&mut cur).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = std::io::Cursor::new(buf);
        let r: Result<Option<Request>> = read_frame(&mut cur);
        assert!(r.is_err());
    }

    #[test]
    fn bad_tag_rejected() {
        let mut body = Vec::new();
        put_varint(&mut body, 99);
        assert!(Request::from_bytes(&body).is_err());
        assert!(Response::from_bytes(&body).is_err());
    }
}
