//! Wire protocol for the redis-sim KV server.
//!
//! Frames are `u32` little-endian length + codec-encoded body. Commands
//! mirror the subset of Redis that ProxyStore's connectors use (GET/SET/
//! DEL/EXISTS/MGET/MPUT, pub/sub, lists with blocking pop) plus `WaitGet`
//! — a blocking GET with timeout that the ProxyFutures pattern uses so
//! proxy resolution can park server-side instead of client-side polling.
//! The batched pair (`MGet`/`MPut`) carries whole key sets in one frame —
//! the wire half of the shard fabric's `get_many`/`put_many` fast path.
//!
//! The protocol is strictly request/response FIFO per connection (the
//! server answers frames in arrival order; `Subscribe` flips a connection
//! into push mode and out of this contract). That ordering invariant is
//! what lets the pipelined [`KvClient`](crate::kv::KvClient) keep N
//! requests in flight on one socket and match responses to completion
//! handles by queue position alone — no request ids on the wire.
//!
//! The watch/notify plane is the one deliberate exception: `Watch`
//! registers a client-chosen id and is acknowledged FIFO like any other
//! request, but the eventual `Notify { id, .. }` push arrives
//! *out-of-band* — whenever some writer stores the key — and is routed by
//! its id, not by queue position. A parked watch therefore never stalls
//! the shared response stream the way the older server-side-blocking
//! `WaitGet` did (which still exists, still parks, and is still FIFO).

use std::io::{Read, Write};
use std::sync::Arc;

use crate::codec::{
    Buf, Bytes, Decode, Encode, Reader, get_varint, put_varint,
};
use crate::error::{Error, Result};
use crate::net::WireFrame;

/// Client → server commands.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Fetch a key's value.
    Get { key: String },
    /// Store a value.
    Set { key: String, value: Bytes },
    /// Store only if absent; replies `Int(1)` if stored, `Int(0)` if not.
    SetNx { key: String, value: Bytes },
    /// Delete a key; replies `Int(1)` if it existed.
    Del { key: String },
    /// Existence check; replies `Int(0/1)`.
    Exists { key: String },
    /// Batched get.
    MGet { keys: Vec<String> },
    /// Batched set: all pairs land under one lock acquisition and one wire
    /// round trip (the shard fabric's `put_many` fast path).
    MPut { items: Vec<(String, Bytes)> },
    /// Batched delete; replies `Int(n_removed)`. One frame for a whole
    /// eviction sweep (ownership lifetimes, bulk retention).
    MDel { keys: Vec<String> },
    /// Batched existence check; replies `Bools`, positionally aligned.
    /// Completes the batched KV protocol: membership probes over whole
    /// key sets (shard-fabric `exists_many`) pay one round trip.
    MExists { keys: Vec<String> },
    /// Blocking get: wait up to `timeout_ms` for the key to appear
    /// (0 = wait forever). Parks the connection's FIFO response stream for
    /// its whole duration — the watch plane (`Watch`/`Notify`) is the
    /// nonblocking replacement; this survives as a protocol-level
    /// primitive and for single-purpose connections.
    WaitGet { key: String, timeout_ms: u64 },
    /// Arm an out-of-band watch on `key` under a client-chosen `id`.
    /// Acknowledged `Ok` in FIFO order; fires a push-mode
    /// [`Response::Notify`] carrying `id` when the key is stored
    /// (immediately if it already exists). One-shot.
    Watch { key: String, id: u64 },
    /// Disarm a watch; replies `Int(1)` if it was still armed (it will
    /// never fire), `Int(0)` if it already fired or was unknown.
    Unwatch { key: String, id: u64 },
    /// Atomic increment; creates the key at 0 first.
    Incr { key: String, by: i64 },
    /// Keys with a prefix (admin/debug).
    Keys { prefix: String },
    /// Publish to a channel; replies `Int(n_receivers)`.
    Publish { channel: String, payload: Bytes },
    /// Switch this connection into subscriber push mode.
    Subscribe { channels: Vec<String> },
    /// Append to a list (queue semantics for stream shims).
    LPush { list: String, value: Bytes },
    /// Blocking pop from the tail; waits up to `timeout_ms` (0 = forever).
    BRPop { list: String, timeout_ms: u64 },
    /// Drop all data (test/bench reset).
    FlushAll,
    /// Server statistics snapshot.
    Stats,
    /// Liveness probe.
    Ping,
    /// Trace-context envelope: carries the client's trace/span ids across
    /// the wire so the server can stamp its own span onto the same trace.
    /// The server unwraps, records a server-side span parented on
    /// `span_id`, and executes `inner` exactly as if it had arrived bare.
    /// Push-mode commands (`Subscribe`/`Watch`/`Unwatch`) and nested
    /// envelopes are rejected — tracing must not change FIFO semantics.
    Traced { trace_id: u64, span_id: u64, inner: Box<Request> },
    /// Fetch the server process's full telemetry registry snapshot
    /// (encoded [`TelemetrySnapshot`](crate::metrics::TelemetrySnapshot)).
    Telemetry,
}

/// Server → client replies (plus async `Message` pushes in subscribe mode).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    /// GET/WaitGet/BRPop result; `None` = missing/timeout. The payload
    /// is a [`Buf`] window — on the server a refcount bump of the engine
    /// map's cached allocation, on the client a window into the received
    /// frame — so values cross this type without being copied.
    Value(Option<Buf>),
    /// MGET result, positionally aligned with the request keys.
    Values(Vec<Option<Buf>>),
    /// MEXISTS result, positionally aligned with the request keys.
    Bools(Vec<bool>),
    Int(i64),
    KeysList(Vec<String>),
    /// Async pub/sub push.
    Message { channel: String, payload: Bytes },
    /// Out-of-band watch firing: pushed whenever a watched key is stored,
    /// routed client-side by the watch `id` (never FIFO-matched).
    Notify { id: u64, value: Buf },
    /// Stats: (n_keys, resident_bytes, ops_served).
    StatsReply { keys: u64, bytes: u64, ops: u64 },
    Error(String),
    /// Encoded [`TelemetrySnapshot`](crate::metrics::TelemetrySnapshot)
    /// of the server process's registry (reply to `Request::Telemetry`).
    /// Kept opaque at this layer so the protocol does not depend on the
    /// snapshot's evolving field set.
    Telemetry { data: Bytes },
}

impl Request {
    /// Stable lower-case op label, used to name telemetry spans and
    /// histograms. `Traced` reports its inner op's label — the envelope
    /// itself is not an operation.
    pub fn name(&self) -> &'static str {
        match self {
            Request::Get { .. } => "get",
            Request::Set { .. } => "set",
            Request::SetNx { .. } => "set_nx",
            Request::Del { .. } => "del",
            Request::Exists { .. } => "exists",
            Request::MGet { .. } => "mget",
            Request::MPut { .. } => "mput",
            Request::MDel { .. } => "mdel",
            Request::MExists { .. } => "mexists",
            Request::WaitGet { .. } => "wait_get",
            Request::Watch { .. } => "watch",
            Request::Unwatch { .. } => "unwatch",
            Request::Incr { .. } => "incr",
            Request::Keys { .. } => "keys",
            Request::Publish { .. } => "publish",
            Request::Subscribe { .. } => "subscribe",
            Request::LPush { .. } => "lpush",
            Request::BRPop { .. } => "brpop",
            Request::FlushAll => "flush_all",
            Request::Stats => "stats",
            Request::Ping => "ping",
            Request::Traced { inner, .. } => inner.name(),
            Request::Telemetry => "telemetry",
        }
    }
}

macro_rules! tagged {
    ($buf:expr, $tag:expr $(, $field:expr)*) => {{
        put_varint($buf, $tag);
        $($field.encode($buf);)*
    }};
}

impl Encode for Request {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Request::Get { key } => tagged!(buf, 0, key),
            Request::Set { key, value } => tagged!(buf, 1, key, value),
            Request::SetNx { key, value } => tagged!(buf, 2, key, value),
            Request::Del { key } => tagged!(buf, 3, key),
            Request::Exists { key } => tagged!(buf, 4, key),
            Request::MGet { keys } => tagged!(buf, 5, keys),
            Request::WaitGet { key, timeout_ms } => {
                tagged!(buf, 6, key, timeout_ms)
            }
            Request::Incr { key, by } => tagged!(buf, 7, key, by),
            Request::Keys { prefix } => tagged!(buf, 8, prefix),
            Request::Publish { channel, payload } => {
                tagged!(buf, 9, channel, payload)
            }
            Request::Subscribe { channels } => tagged!(buf, 10, channels),
            Request::LPush { list, value } => tagged!(buf, 11, list, value),
            Request::BRPop { list, timeout_ms } => {
                tagged!(buf, 12, list, timeout_ms)
            }
            Request::FlushAll => tagged!(buf, 13),
            Request::Stats => tagged!(buf, 14),
            Request::Ping => tagged!(buf, 15),
            Request::MPut { items } => tagged!(buf, 16, items),
            Request::MDel { keys } => tagged!(buf, 17, keys),
            Request::MExists { keys } => tagged!(buf, 18, keys),
            Request::Watch { key, id } => tagged!(buf, 19, key, id),
            Request::Unwatch { key, id } => tagged!(buf, 20, key, id),
            Request::Traced { trace_id, span_id, inner } => {
                put_varint(buf, 21);
                trace_id.encode(buf);
                span_id.encode(buf);
                inner.as_ref().encode(buf);
            }
            Request::Telemetry => tagged!(buf, 22),
        }
    }
}

impl Decode for Request {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => Request::Get { key: Decode::decode(r)? },
            1 => Request::Set {
                key: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            2 => Request::SetNx {
                key: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            3 => Request::Del { key: Decode::decode(r)? },
            4 => Request::Exists { key: Decode::decode(r)? },
            5 => Request::MGet { keys: Decode::decode(r)? },
            6 => Request::WaitGet {
                key: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            7 => Request::Incr {
                key: Decode::decode(r)?,
                by: Decode::decode(r)?,
            },
            8 => Request::Keys { prefix: Decode::decode(r)? },
            9 => Request::Publish {
                channel: Decode::decode(r)?,
                payload: Decode::decode(r)?,
            },
            10 => Request::Subscribe { channels: Decode::decode(r)? },
            11 => Request::LPush {
                list: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            12 => Request::BRPop {
                list: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            13 => Request::FlushAll,
            14 => Request::Stats,
            15 => Request::Ping,
            16 => Request::MPut { items: Decode::decode(r)? },
            17 => Request::MDel { keys: Decode::decode(r)? },
            18 => Request::MExists { keys: Decode::decode(r)? },
            19 => Request::Watch {
                key: Decode::decode(r)?,
                id: Decode::decode(r)?,
            },
            20 => Request::Unwatch {
                key: Decode::decode(r)?,
                id: Decode::decode(r)?,
            },
            21 => Request::Traced {
                trace_id: Decode::decode(r)?,
                span_id: Decode::decode(r)?,
                inner: Box::new(Decode::decode(r)?),
            },
            22 => Request::Telemetry,
            t => return Err(Error::Protocol(format!("bad request tag {t}"))),
        })
    }
}

impl Encode for Response {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Response::Ok => tagged!(buf, 0),
            Response::Value(v) => tagged!(buf, 1, v),
            Response::Values(v) => tagged!(buf, 2, v),
            Response::Int(v) => tagged!(buf, 3, v),
            Response::KeysList(v) => tagged!(buf, 4, v),
            Response::Message { channel, payload } => {
                tagged!(buf, 5, channel, payload)
            }
            Response::StatsReply { keys, bytes, ops } => {
                tagged!(buf, 6, keys, bytes, ops)
            }
            Response::Error(msg) => tagged!(buf, 7, msg),
            Response::Bools(v) => tagged!(buf, 8, v),
            Response::Notify { id, value } => tagged!(buf, 9, id, value),
            Response::Telemetry { data } => tagged!(buf, 10, data),
        }
    }
}

impl Response {
    /// Encode into a gather [`WireFrame`]: header bytes (tags, lengths,
    /// scalar fields) are owned, every value payload is attached as a
    /// `Shared` window of its cached allocation — a refcount bump, never
    /// a copy. Wire bytes are identical to [`Encode::to_bytes`]; only
    /// the ownership of the payload ranges differs. Variants without
    /// bulk payloads fall back to a flat single-segment encode.
    pub fn into_frame(self) -> WireFrame {
        let mut frame = WireFrame::new();
        let mut head = Vec::new();
        match self {
            Response::Value(v) => {
                put_varint(&mut head, 1);
                push_opt_payload(&mut frame, &mut head, v);
            }
            Response::Values(vs) => {
                put_varint(&mut head, 2);
                put_varint(&mut head, vs.len() as u64);
                for v in vs {
                    push_opt_payload(&mut frame, &mut head, v);
                }
            }
            Response::Notify { id, value } => {
                put_varint(&mut head, 9);
                id.encode(&mut head);
                push_payload(&mut frame, &mut head, value);
            }
            other => other.encode(&mut head),
        }
        frame.push_owned(head);
        frame
    }

    /// Total value-payload bytes this response carries — the bytes the
    /// zero-copy plane ships as shared segments instead of copying.
    pub fn payload_len(&self) -> usize {
        match self {
            Response::Value(v) => v.as_ref().map_or(0, |b| b.len()),
            Response::Values(vs) => vs.iter().flatten().map(|b| b.len()).sum(),
            Response::Notify { value, .. } => value.len(),
            _ => 0,
        }
    }
}

/// Append one value payload: its length varint joins the pending header
/// bytes, the bytes themselves ride as a `Shared` segment. (The outbox
/// inlines tiny shared segments on its side — one threshold, one
/// `data.bytes_copied` counting site.)
fn push_payload(frame: &mut WireFrame, head: &mut Vec<u8>, value: Buf) {
    put_varint(head, value.len() as u64);
    if !value.is_empty() {
        frame.push_owned(std::mem::take(head));
        frame.push_shared(value);
    }
}

fn push_opt_payload(
    frame: &mut WireFrame,
    head: &mut Vec<u8>,
    value: Option<Buf>,
) {
    match value {
        None => head.push(0),
        Some(b) => {
            head.push(1);
            push_payload(frame, head, b);
        }
    }
}

/// Decode a response from an owned frame body, windowing value payloads
/// (`Value`/`Values`/`Notify`) straight over `data` instead of copying
/// them out — the client-side half of the zero-copy data plane. Other
/// variants take the ordinary borrowed decode.
pub fn decode_response_owned(data: Vec<u8>) -> Result<Response> {
    {
        let mut r = Reader::new(&data);
        match get_varint(&mut r)? {
            1 | 2 | 9 => {}
            _ => return Response::from_bytes(&data),
        }
    }
    let arc = Arc::new(data);
    let mut r = Reader::new(arc.as_slice());
    let resp = match get_varint(&mut r)? {
        1 => Response::Value(take_opt_window(&mut r, &arc)?),
        2 => {
            let n = get_varint(&mut r)? as usize;
            let mut vs = Vec::with_capacity(n.min(r.remaining()));
            for _ in 0..n {
                vs.push(take_opt_window(&mut r, &arc)?);
            }
            Response::Values(vs)
        }
        9 => Response::Notify {
            id: Decode::decode(&mut r)?,
            value: take_window(&mut r, &arc)?,
        },
        _ => unreachable!("tag screened above"),
    };
    if !r.is_empty() {
        return Err(Error::Codec(format!(
            "{} trailing bytes after decode",
            r.remaining()
        )));
    }
    Ok(resp)
}

/// Parse one length-prefixed payload as a window over `arc` (validated
/// by advancing the reader, so a hostile length fails before any window
/// is minted).
fn take_window(r: &mut Reader<'_>, arc: &Arc<Vec<u8>>) -> Result<Buf> {
    let n = get_varint(r)?;
    if n > r.remaining() as u64 {
        return Err(Error::Codec(format!("length {n} exceeds input")));
    }
    let n = n as usize;
    let off = r.position();
    r.take(n)?;
    Ok(Buf::window(Arc::clone(arc), off, n))
}

fn take_opt_window(
    r: &mut Reader<'_>,
    arc: &Arc<Vec<u8>>,
) -> Result<Option<Buf>> {
    match r.take(1)?[0] {
        0 => Ok(None),
        1 => Ok(Some(take_window(r, arc)?)),
        b => Err(Error::Codec(format!("invalid option tag {b}"))),
    }
}

impl Decode for Response {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => Response::Ok,
            1 => Response::Value(Decode::decode(r)?),
            2 => Response::Values(Decode::decode(r)?),
            3 => Response::Int(Decode::decode(r)?),
            4 => Response::KeysList(Decode::decode(r)?),
            5 => Response::Message {
                channel: Decode::decode(r)?,
                payload: Decode::decode(r)?,
            },
            6 => Response::StatsReply {
                keys: Decode::decode(r)?,
                bytes: Decode::decode(r)?,
                ops: Decode::decode(r)?,
            },
            7 => Response::Error(Decode::decode(r)?),
            8 => Response::Bools(Decode::decode(r)?),
            9 => Response::Notify {
                id: Decode::decode(r)?,
                value: Decode::decode(r)?,
            },
            10 => Response::Telemetry { data: Decode::decode(r)? },
            t => return Err(Error::Protocol(format!("bad response tag {t}"))),
        })
    }
}

/// Write one length-prefixed frame and flush the writer.
pub fn write_frame<W: Write, T: Encode>(w: &mut W, msg: &T) -> Result<()> {
    write_frame_unflushed(w, msg)?;
    w.flush()?;
    Ok(())
}

/// Write one length-prefixed frame without flushing — the write-coalescing
/// client path buffers many frames and flushes once per policy tick.
pub fn write_frame_unflushed<W: Write, T: Encode>(
    w: &mut W,
    msg: &T,
) -> Result<()> {
    let body = msg.to_bytes();
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    Ok(())
}

/// Write one length-prefixed frame, encoding into `scratch` (cleared,
/// capacity kept) instead of a fresh per-frame allocation — the threaded
/// ingress keeps one scratch per connection writer so steady-state
/// replies allocate nothing.
pub fn write_frame_reusing<W: Write, T: Encode>(
    w: &mut W,
    msg: &T,
    scratch: &mut Vec<u8>,
) -> Result<()> {
    scratch.clear();
    msg.encode(scratch);
    w.write_all(&(scratch.len() as u32).to_le_bytes())?;
    w.write_all(scratch)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame; `None` on clean EOF at a frame boundary.
pub fn read_frame<R: Read, T: Decode>(r: &mut R) -> Result<Option<T>> {
    match read_frame_raw(r)? {
        Some(body) => Ok(Some(T::from_bytes(&body)?)),
        None => Ok(None),
    }
}

/// Read one length-prefixed frame body without decoding it; `None` on
/// clean EOF at a frame boundary. The pipelined client reads raw bodies
/// so [`decode_response_owned`] can window value payloads over the
/// frame's own allocation instead of copying them out.
pub fn read_frame_raw<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > 1 << 30 {
        return Err(Error::Protocol(format!("frame too large: {len}")));
    }
    // read_to_end on a bounded Take appends without zero-initializing the
    // buffer first (std fills via its uninit-spare-capacity path), which
    // matters at multi-MB frames.
    let mut body = Vec::with_capacity(len);
    let n = r.by_ref().take(len as u64).read_to_end(&mut body)?;
    if n < len {
        return Err(Error::Io(std::sync::Arc::new(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("frame truncated: {n}/{len}"),
        ))));
    }
    Ok(Some(body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &req).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        let back: Request = read_frame(&mut cur).unwrap().unwrap();
        assert_eq!(req, back);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Request::Get { key: "k".into() });
        roundtrip_req(Request::Set {
            key: "k".into(),
            value: Bytes(vec![1, 2, 3]),
        });
        roundtrip_req(Request::MGet { keys: vec!["a".into(), "b".into()] });
        roundtrip_req(Request::MPut {
            items: vec![
                ("a".into(), Bytes(vec![1, 2])),
                ("b".into(), Bytes(Vec::new())),
            ],
        });
        roundtrip_req(Request::MPut { items: Vec::new() });
        roundtrip_req(Request::MDel { keys: vec!["a".into(), "b".into()] });
        roundtrip_req(Request::MDel { keys: Vec::new() });
        roundtrip_req(Request::MExists { keys: vec!["a".into(), "b".into()] });
        roundtrip_req(Request::MExists { keys: Vec::new() });
        roundtrip_req(Request::WaitGet { key: "k".into(), timeout_ms: 500 });
        roundtrip_req(Request::Watch { key: "k".into(), id: u64::MAX });
        roundtrip_req(Request::Unwatch { key: "k".into(), id: 0 });
        roundtrip_req(Request::Publish {
            channel: "c".into(),
            payload: Bytes(vec![9; 100]),
        });
        roundtrip_req(Request::Subscribe { channels: vec!["c".into()] });
        roundtrip_req(Request::BRPop { list: "l".into(), timeout_ms: 0 });
        roundtrip_req(Request::FlushAll);
        roundtrip_req(Request::Ping);
        roundtrip_req(Request::Incr { key: "n".into(), by: -3 });
        roundtrip_req(Request::Telemetry);
        roundtrip_req(Request::Traced {
            trace_id: u64::MAX,
            span_id: 7,
            inner: Box::new(Request::Get { key: "k".into() }),
        });
        roundtrip_req(Request::Traced {
            trace_id: 1,
            span_id: 2,
            inner: Box::new(Request::MPut {
                items: vec![("a".into(), Bytes(vec![1, 2]))],
            }),
        });
    }

    #[test]
    fn request_names_follow_inner_op() {
        assert_eq!(Request::Get { key: "k".into() }.name(), "get");
        assert_eq!(Request::Telemetry.name(), "telemetry");
        let traced = Request::Traced {
            trace_id: 1,
            span_id: 2,
            inner: Box::new(Request::Set {
                key: "k".into(),
                value: Bytes(vec![1]),
            }),
        };
        assert_eq!(traced.name(), "set");
    }

    fn sample_responses() -> Vec<Response> {
        vec![
            Response::Ok,
            Response::Value(None),
            Response::Value(Some(Buf::from_vec(vec![0; 10]))),
            Response::Value(Some(Buf::from_vec(vec![7; 4096]))),
            Response::Values(vec![None, Some(Buf::from_vec(vec![1]))]),
            Response::Values(vec![
                Some(Buf::from_vec(vec![9; 2000])),
                None,
                Some(Buf::from_vec(Vec::new())),
            ]),
            Response::Bools(vec![true, false, true]),
            Response::Bools(Vec::new()),
            Response::Int(-7),
            Response::KeysList(vec!["x".into()]),
            Response::Message {
                channel: "c".into(),
                payload: Bytes(vec![2]),
            },
            Response::Notify { id: 42, value: Buf::from_vec(vec![1, 2, 3]) },
            Response::Notify { id: 0, value: Buf::from_vec(Vec::new()) },
            Response::StatsReply { keys: 1, bytes: 2, ops: 3 },
            Response::Error("boom".into()),
            Response::Telemetry { data: Bytes(vec![1, 2, 3]) },
            Response::Telemetry { data: Bytes(Vec::new()) },
        ]
    }

    #[test]
    fn responses_roundtrip() {
        for resp in sample_responses() {
            let mut buf = Vec::new();
            write_frame(&mut buf, &resp).unwrap();
            let mut cur = std::io::Cursor::new(buf);
            let back: Response = read_frame(&mut cur).unwrap().unwrap();
            assert_eq!(resp, back);
        }
    }

    #[test]
    fn into_frame_matches_flat_encoding() {
        // The gather frame must put the exact same bytes on the wire as
        // the flat encoder, for every response shape.
        for resp in sample_responses() {
            let flat = resp.to_bytes();
            let frame = resp.into_frame();
            assert_eq!(frame.len(), flat.len());
            assert_eq!(frame.concat(), flat);
        }
    }

    #[test]
    fn decode_response_owned_windows_payloads_in_place() {
        let payload: Vec<u8> = (0..1 << 20).map(|i| (i % 251) as u8).collect();
        let resp = Response::Value(Some(Buf::from_vec(payload.clone())));
        let body = resp.to_bytes();
        let body_ptr = body.as_ptr();
        let body_len = body.len();
        let back = decode_response_owned(body).unwrap();
        let Response::Value(Some(v)) = &back else {
            panic!("wrong variant: {back:?}")
        };
        assert_eq!(v.as_slice(), &payload[..]);
        // Zero-copy: the payload window points inside the original frame
        // allocation (tag + option byte + length varint, then payload).
        let off = unsafe { v.as_slice().as_ptr().offset_from(body_ptr) };
        assert!(
            off > 0 && (off as usize) + v.len() <= body_len,
            "payload window escaped the frame allocation (off={off})"
        );
    }

    #[test]
    fn decode_response_owned_other_variants_and_hostile_input() {
        // Non-payload variants fall back to the flat decoder.
        let resp = Response::Int(-7);
        assert_eq!(decode_response_owned(resp.to_bytes()).unwrap(), resp);
        // A frame whose declared payload length overruns the body fails
        // before any window is minted.
        let mut bad = Vec::new();
        put_varint(&mut bad, 1); // Value tag
        bad.push(1); // Some
        put_varint(&mut bad, 1000); // declared len >> actual
        bad.extend_from_slice(&[1, 2, 3]);
        assert!(decode_response_owned(bad).is_err());
        // Trailing bytes after a complete response are rejected.
        let mut trailing = Response::Value(None).to_bytes();
        trailing.push(0);
        assert!(decode_response_owned(trailing).is_err());
    }

    #[test]
    fn write_frame_reusing_matches_plain_write() {
        let resp = Response::Value(Some(Buf::from_vec(vec![5; 300])));
        let mut plain = Vec::new();
        write_frame(&mut plain, &resp).unwrap();
        let mut reused = Vec::new();
        let mut scratch = vec![0xAAu8; 8]; // stale bytes must not leak
        write_frame_reusing(&mut reused, &resp, &mut scratch).unwrap();
        assert_eq!(plain, reused);
    }

    #[test]
    fn clean_eof_returns_none() {
        let buf: Vec<u8> = Vec::new();
        let mut cur = std::io::Cursor::new(buf);
        let r: Option<Request> = read_frame(&mut cur).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = std::io::Cursor::new(buf);
        let r: Result<Option<Request>> = read_frame(&mut cur);
        assert!(r.is_err());
    }

    #[test]
    fn bad_tag_rejected() {
        let mut body = Vec::new();
        put_varint(&mut body, 99);
        assert!(Request::from_bytes(&body).is_err());
        assert!(Response::from_bytes(&body).is_err());
    }
}
