//! Shared KV state: the storage engine behind both the TCP server and the
//! embedded (in-process) handle.
//!
//! Waiting is event-driven: a **watch registry** maps keys to one-shot
//! callbacks, and every write path (`set`/`set_nx`/`mset`) fires exactly
//! the watchers of the keys it touched — a put wakes its waiters and
//! nobody else, so a million parked watches cost zero CPU. `wait_get` is
//! itself built on the registry (register, park, fire), and the TCP
//! server's push-mode `Notify` frames ride the same callbacks. The
//! `Mutex<Inner>` + `Condvar` pair survives only for `BRPop` (list pops
//! re-check their predicate on `lpush`). Pub/sub fan-out happens under
//! the same lock for a consistent receiver count but the actual channel
//! sends never block (unbounded `mpsc`), so a slow subscriber cannot
//! stall writers — matching Redis' fire-and-forget pub/sub semantics.
//!
//! The engine is optionally **durable** ([`KvState::open_durable`]): every
//! key/value mutation (`set`/`set_nx`/`mset`/`del`/`mdel`/`flush_all`)
//! appends a record to a segmented WAL *under the engine lock* (so log
//! order equals apply order) and group-commits it *after* releasing the
//! lock, before the caller acks. Recovery loads the newest snapshot and
//! replays the WAL tail; replay records are idempotent upserts/deletes, so
//! a snapshot raced by concurrent writers still converges. Durability
//! covers the key/value map only — lists, counters, pub/sub channels and
//! armed watches are transient by design (they encode in-flight
//! coordination, not data of record).

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::codec::{get_varint, put_varint, Buf, Bytes, Reader};
use crate::error::{Error, Result};
use crate::metrics::{telemetry, StoreBytes};
use crate::persist::{
    load_latest_snapshot, write_snapshot, DurabilityOptions, RecoveryStats,
    Wal,
};

/// Cached watch-plane registry handles (process-wide across engines).
struct WatchMetrics {
    armed: Arc<telemetry::Gauge>,
    fires: Arc<telemetry::Counter>,
}

fn watch_metrics() -> &'static WatchMetrics {
    static M: std::sync::OnceLock<WatchMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| WatchMetrics {
        armed: telemetry::gauge("watch.armed"),
        fires: telemetry::counter("watch.fires"),
    })
}

/// A pub/sub push delivered to a subscriber connection.
#[derive(Debug, Clone)]
pub struct PubSubMsg {
    pub channel: String,
    pub payload: Bytes,
}

/// One-shot watcher callback: invoked with the stored value (sharing the
/// engine's allocation) the moment the watched key is written — or
/// immediately at registration if it already exists. Callbacks run on the
/// writer's thread with no engine lock held, so they may complete handles
/// and chain, but must stay cheap and non-blocking.
pub type WatchCallback = Box<dyn FnOnce(Arc<Vec<u8>>) + Send>;

#[derive(Default)]
struct Inner {
    /// The engine map stores [`Buf`]s — write paths insert full windows
    /// over the received value, so every read (`get_buf`, WAL append,
    /// snapshot encode, watch fire) shares the same allocation and
    /// conversions back to `Arc<Vec<u8>>` stay free.
    data: HashMap<String, Buf>,
    lists: HashMap<String, VecDeque<Bytes>>,
    counters: HashMap<String, i64>,
    subscribers: HashMap<String, Vec<mpsc::Sender<PubSubMsg>>>,
    /// Armed watches per key; tokens let a waiter disarm on timeout.
    watches: HashMap<String, Vec<(u64, WatchCallback)>>,
}

impl Inner {
    /// Detach the watchers a write to `key` must fire (called under the
    /// engine lock; the callbacks run after it is released).
    fn take_watches(&mut self, key: &str) -> Vec<(u64, WatchCallback)> {
        let fired = self.watches.remove(key).unwrap_or_default();
        if !fired.is_empty() {
            let m = watch_metrics();
            m.armed.add(-(fired.len() as i64));
            m.fires.add(fired.len() as u64);
        }
        fired
    }
}

// ---------------------------------------------------------------------------
// Durability: WAL record codec + recovery
// ---------------------------------------------------------------------------

/// WAL record tags for KV mutations.
const REC_SET: u8 = 1;
const REC_DEL: u8 = 2;
const REC_CLEAR: u8 = 3;

fn encode_set(key: &str, value: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(key.len() + value.len() + 16);
    buf.push(REC_SET);
    put_varint(&mut buf, key.len() as u64);
    buf.extend_from_slice(key.as_bytes());
    put_varint(&mut buf, value.len() as u64);
    buf.extend_from_slice(value);
    buf
}

fn encode_del(key: &str) -> Vec<u8> {
    let mut buf = Vec::with_capacity(key.len() + 8);
    buf.push(REC_DEL);
    put_varint(&mut buf, key.len() as u64);
    buf.extend_from_slice(key.as_bytes());
    buf
}

/// Apply one CRC-validated replay record to the recovering map.
/// Records are idempotent upserts/deletes, so replaying a tail that
/// overlaps the snapshot horizon converges to the same state.
fn apply_record(data: &mut HashMap<String, Buf>, rec: &[u8]) -> Result<()> {
    let mut r = Reader::new(rec);
    match r.take(1)?[0] {
        REC_SET => {
            let klen = get_varint(&mut r)? as usize;
            let key = std::str::from_utf8(r.take(klen)?)
                .map_err(|_| Error::Codec("wal key not utf8".into()))?
                .to_string();
            let vlen = get_varint(&mut r)? as usize;
            let val = r.take(vlen)?.to_vec();
            data.insert(key, Buf::from_vec(val));
        }
        REC_DEL => {
            let klen = get_varint(&mut r)? as usize;
            let key = std::str::from_utf8(r.take(klen)?)
                .map_err(|_| Error::Codec("wal key not utf8".into()))?;
            data.remove(key);
        }
        REC_CLEAR => data.clear(),
        tag => {
            return Err(Error::Codec(format!("unknown wal record tag {tag}")))
        }
    }
    Ok(())
}

fn encode_snapshot(entries: &[(String, Buf)]) -> Vec<u8> {
    let total: usize = entries.iter().map(|(k, v)| k.len() + v.len() + 16).sum();
    let mut buf = Vec::with_capacity(total + 8);
    put_varint(&mut buf, entries.len() as u64);
    for (k, v) in entries {
        put_varint(&mut buf, k.len() as u64);
        buf.extend_from_slice(k.as_bytes());
        put_varint(&mut buf, v.len() as u64);
        buf.extend_from_slice(v);
    }
    buf
}

fn decode_snapshot(
    payload: &[u8],
    data: &mut HashMap<String, Buf>,
) -> Result<()> {
    let mut r = Reader::new(payload);
    let n = get_varint(&mut r)?;
    for _ in 0..n {
        let klen = get_varint(&mut r)? as usize;
        let key = std::str::from_utf8(r.take(klen)?)
            .map_err(|_| Error::Codec("snapshot key not utf8".into()))?
            .to_string();
        let vlen = get_varint(&mut r)? as usize;
        data.insert(key, Buf::from_vec(r.take(vlen)?.to_vec()));
    }
    Ok(())
}

/// Durability sidecar of one engine: the mutation WAL plus snapshot
/// bookkeeping. Shared by all clones of the owning [`KvState`].
struct KvPersist {
    wal: Wal,
    snap_dir: PathBuf,
    snapshot_every: u64,
    /// Mutations logged since the last snapshot.
    since_snapshot: AtomicU64,
    /// Single-writer latch for snapshot rolls.
    snapshotting: AtomicBool,
    recovery: RecoveryStats,
}

/// The storage engine. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct KvState {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    /// Bytes resident across values + list entries (Fig 7/10 gauge).
    pub gauge: Arc<StoreBytes>,
    ops: Arc<AtomicU64>,
    next_watch: Arc<AtomicU64>,
    /// `Some` when the engine writes through to a data dir.
    persist: Option<Arc<KvPersist>>,
}

impl Default for KvState {
    fn default() -> Self {
        Self::new()
    }
}

impl KvState {
    pub fn new() -> Self {
        KvState {
            inner: Arc::new((Mutex::new(Inner::default()), Condvar::new())),
            gauge: StoreBytes::new(),
            ops: Arc::new(AtomicU64::new(0)),
            next_watch: Arc::new(AtomicU64::new(0)),
            persist: None,
        }
    }

    /// Open a durable engine rooted at `opts.data_dir/kv`: recover the
    /// key/value map from the newest snapshot plus WAL tail replay, then
    /// write-through every subsequent mutation.
    ///
    /// Lists, counters, pub/sub and watches start empty — only the
    /// key/value map is durable (see the module docs).
    pub fn open_durable(opts: &DurabilityOptions) -> Result<KvState> {
        let kv_dir = opts.data_dir.join("kv");
        let wal_dir = kv_dir.join("wal");
        let snap_dir = kv_dir.join("snap");
        std::fs::create_dir_all(&wal_dir)?;
        std::fs::create_dir_all(&snap_dir)?;

        let mut data: HashMap<String, Buf> = HashMap::new();
        let mut from_seq = 0u64;
        let mut snapshot_seq = None;
        if let Some((seq, payload)) = load_latest_snapshot(&snap_dir)? {
            decode_snapshot(&payload, &mut data)?;
            from_seq = seq + 1;
            snapshot_seq = Some(seq);
        }
        let mut replay_err = None;
        let stats = Wal::replay(&wal_dir, from_seq, |_seq, rec| {
            if replay_err.is_none() {
                if let Err(e) = apply_record(&mut data, rec) {
                    replay_err = Some(e);
                }
            }
        })?;
        if let Some(e) = replay_err {
            return Err(e);
        }
        let wal =
            Wal::open(&wal_dir, stats.next_seq, opts.segment_bytes, opts.fsync)?;

        let gauge = StoreBytes::new();
        gauge.add(data.values().map(|v| v.len()).sum());
        Ok(KvState {
            inner: Arc::new((
                Mutex::new(Inner { data, ..Inner::default() }),
                Condvar::new(),
            )),
            gauge,
            ops: Arc::new(AtomicU64::new(0)),
            next_watch: Arc::new(AtomicU64::new(0)),
            persist: Some(Arc::new(KvPersist {
                wal,
                snap_dir,
                snapshot_every: opts.snapshot_every_ops,
                since_snapshot: AtomicU64::new(0),
                snapshotting: AtomicBool::new(false),
                recovery: RecoveryStats {
                    snapshot_seq,
                    replayed_records: stats.replayed,
                    truncated_records: stats.truncated,
                },
            })),
        })
    }

    /// What recovery found at open, or `None` for a RAM-only engine.
    pub fn recovery_stats(&self) -> Option<RecoveryStats> {
        self.persist.as_ref().map(|p| p.recovery)
    }

    /// True when mutations write through to a data dir.
    pub fn is_durable(&self) -> bool {
        self.persist.is_some()
    }

    /// Append one WAL record. Must be called under the engine lock so
    /// log order equals apply order. Fail-stop: an engine that cannot
    /// log a mutation must not ack it, so I/O errors panic.
    fn log(&self, record: Vec<u8>) -> Option<u64> {
        self.persist.as_ref().map(|p| {
            p.since_snapshot.fetch_add(1, Ordering::Relaxed);
            p.wal.append(&record).unwrap_or_else(|e| {
                panic!("kv wal append failed (fail-stop): {e}")
            })
        })
    }

    /// Group-commit the mutation logged as `seq` (call after releasing
    /// the engine lock, before acking), then roll a snapshot if the
    /// configured mutation budget since the last one is spent.
    fn commit_logged(&self, seq: Option<u64>) {
        let (Some(p), Some(seq)) = (self.persist.as_ref(), seq) else {
            return;
        };
        if let Err(e) = p.wal.commit(seq) {
            panic!("kv wal commit failed (fail-stop): {e}");
        }
        if p.snapshot_every > 0
            && p.since_snapshot.load(Ordering::Relaxed) >= p.snapshot_every
        {
            self.snapshot_now();
        }
    }

    /// Write a point-in-time snapshot and reclaim WAL segments below its
    /// horizon. No-op on RAM-only engines; concurrent callers coalesce
    /// (one writes, the rest return immediately).
    pub fn snapshot_now(&self) {
        let Some(p) = self.persist.as_ref() else { return };
        if p.snapshotting.swap(true, Ordering::Acquire) {
            return;
        }
        let result = (|| -> Result<()> {
            // Clone the map (Arc values — cheap) and read the WAL
            // frontier under the engine lock: every seq < frontier is
            // both logged and applied, so the image covers exactly the
            // records below it.
            let (m, _) = &*self.inner;
            let (entries, next_seq) = {
                let inner = m.lock().unwrap();
                let entries: Vec<(String, Buf)> = inner
                    .data
                    .iter()
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect();
                (entries, p.wal.next_seq())
            };
            p.since_snapshot.store(0, Ordering::Relaxed);
            if next_seq == 0 {
                return Ok(()); // nothing ever logged
            }
            let horizon = next_seq - 1;
            write_snapshot(&p.snap_dir, horizon, &encode_snapshot(&entries))?;
            p.wal.truncate_below(horizon)?;
            Ok(())
        })();
        p.snapshotting.store(false, Ordering::Release);
        if let Err(e) = result {
            panic!("kv snapshot failed (fail-stop): {e}");
        }
    }

    /// Force buffered WAL records to disk (clean shutdown aid; acked
    /// durability normally follows the configured fsync policy).
    pub fn persist_sync(&self) {
        if let Some(p) = self.persist.as_ref() {
            if let Err(e) = p.wal.sync() {
                panic!("kv wal sync failed (fail-stop): {e}");
            }
        }
    }

    fn bump(&self) {
        self.ops.fetch_add(1, Ordering::Relaxed);
    }

    pub fn ops_served(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn set(&self, key: &str, value: Bytes) {
        self.bump();
        let (m, _) = &*self.inner;
        let (watchers, stored, logged) = {
            let mut inner = m.lock().unwrap();
            self.gauge.add(value.0.len());
            let stored = Buf::from_vec(value.0);
            if let Some(old) =
                inner.data.insert(key.to_string(), stored.clone())
            {
                self.gauge.sub(old.len());
            }
            // The WAL record encodes from the same allocation the map
            // now shares — no staging copy of the value.
            let logged = self.log(encode_set(key, &stored));
            (inner.take_watches(key), stored, logged)
        };
        // Commit (group fsync per policy) before acking or waking anyone.
        self.commit_logged(logged);
        // Fire outside the engine lock: exactly this key's waiters wake,
        // and their callbacks may chain freely.
        for (_, cb) in watchers {
            cb(stored.to_blob());
        }
    }

    /// Returns true if stored (key was absent).
    pub fn set_nx(&self, key: &str, value: Bytes) -> bool {
        self.bump();
        let (m, _) = &*self.inner;
        let (watchers, stored, logged) = {
            let mut inner = m.lock().unwrap();
            if inner.data.contains_key(key) {
                return false;
            }
            self.gauge.add(value.0.len());
            let stored = Buf::from_vec(value.0);
            inner.data.insert(key.to_string(), stored.clone());
            // A winning set_nx logs as a plain Set: replay stays
            // idempotent and losing attempts never touch the WAL.
            let logged = self.log(encode_set(key, &stored));
            (inner.take_watches(key), stored, logged)
        };
        self.commit_logged(logged);
        for (_, cb) in watchers {
            cb(stored.to_blob());
        }
        true
    }

    pub fn get(&self, key: &str) -> Option<Bytes> {
        self.get_shared(key).map(|b| Bytes(b.to_vec()))
    }

    /// Zero-copy read: the returned `Arc` shares the stored allocation
    /// (free — write paths store full windows). This is the
    /// embedded-connector hot path (proxy resolution).
    pub fn get_shared(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        self.get_buf(key).map(|b| b.to_blob())
    }

    /// Zero-copy read as a [`Buf`] window: a refcount bump of the engine
    /// map's cached allocation — the TCP server's GET response path.
    pub fn get_buf(&self, key: &str) -> Option<Buf> {
        self.bump();
        let (m, _) = &*self.inner;
        m.lock().unwrap().data.get(key).cloned()
    }

    pub fn mget(&self, keys: &[String]) -> Vec<Option<Bytes>> {
        self.mget_shared(keys)
            .into_iter()
            .map(|o| o.map(|b| Bytes(b.to_vec())))
            .collect()
    }

    /// Batched zero-copy read: all keys resolved under one lock
    /// acquisition, sharing the stored allocations (embedded fast path of
    /// the shard fabric's `get_many`).
    pub fn mget_shared(&self, keys: &[String]) -> Vec<Option<Arc<Vec<u8>>>> {
        self.mget_buf(keys)
            .into_iter()
            .map(|o| o.map(|b| b.to_blob()))
            .collect()
    }

    /// Batched zero-copy read as [`Buf`] windows (the MGET response
    /// path): one lock acquisition, one refcount bump per hit.
    pub fn mget_buf(&self, keys: &[String]) -> Vec<Option<Buf>> {
        self.bump();
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        keys.iter().map(|k| inner.data.get(k).cloned()).collect()
    }

    /// Batched set: all pairs inserted under one lock acquisition; each
    /// key's armed watchers fire once the batch lands.
    pub fn mset(&self, items: Vec<(String, Bytes)>) {
        self.bump();
        let (m, _) = &*self.inner;
        let mut fired: Vec<(WatchCallback, Arc<Vec<u8>>)> = Vec::new();
        let mut logged = None;
        {
            let mut inner = m.lock().unwrap();
            for (key, value) in items {
                self.gauge.add(value.0.len());
                let stored = Buf::from_vec(value.0);
                for (_, cb) in inner.take_watches(&key) {
                    fired.push((cb, stored.to_blob()));
                }
                // One record per pair; the batch group-commits once below.
                logged = self.log(encode_set(&key, &stored)).or(logged);
                if let Some(old) = inner.data.insert(key, stored) {
                    self.gauge.sub(old.len());
                }
            }
        }
        self.commit_logged(logged);
        for (cb, stored) in fired {
            cb(stored);
        }
    }

    /// Arm a one-shot watch on `key`: `cb` fires with the value on the
    /// next write — or immediately (and without registering, returning
    /// `None`) if the key already exists. The returned token disarms via
    /// [`KvState::unwatch`]. This registry is the engine half of the
    /// watch/notify plane: `wait_get` parks on it, the TCP server's
    /// `Watch` command registers through it, and the memory connector's
    /// native [`watch`](crate::store::Connector::watch) completes straight
    /// from it.
    pub fn watch(&self, key: &str, cb: WatchCallback) -> Option<u64> {
        self.bump();
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        if let Some(v) = inner.data.get(key) {
            let v = v.to_blob();
            drop(inner);
            cb(v);
            return None;
        }
        let token = self.next_watch.fetch_add(1, Ordering::Relaxed);
        inner
            .watches
            .entry(key.to_string())
            .or_default()
            .push((token, cb));
        watch_metrics().armed.add(1);
        Some(token)
    }

    /// Disarm a watch. `false` means it already fired (or was never
    /// registered) — the callback ran or is about to.
    pub fn unwatch(&self, key: &str, token: u64) -> bool {
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        let Some(list) = inner.watches.get_mut(key) else {
            return false;
        };
        let before = list.len();
        list.retain(|(t, _)| *t != token);
        let removed = list.len() < before;
        if list.is_empty() {
            inner.watches.remove(key);
        }
        if removed {
            watch_metrics().armed.add(-1);
        }
        removed
    }

    /// Armed watches across all keys (diagnostics / leak tests).
    pub fn watch_count(&self) -> usize {
        let (m, _) = &*self.inner;
        m.lock().unwrap().watches.values().map(Vec::len).sum()
    }

    /// Blocking get: wait for the key up to `timeout` (`None` = forever).
    pub fn wait_get(&self, key: &str, timeout: Option<Duration>) -> Option<Bytes> {
        self.wait_get_shared(key, timeout).map(|b| Bytes(b.to_vec()))
    }

    /// Blocking zero-copy read (see [`KvState::get_shared`]), parked on
    /// the watch registry: the waiter wakes from the single targeted
    /// callback its key's writer fires — no shared condvar, no herd.
    pub fn wait_get_shared(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Option<Arc<Vec<u8>>> {
        type Slot = Arc<(Mutex<Option<Arc<Vec<u8>>>>, Condvar)>;
        let slot: Slot = Arc::new((Mutex::new(None), Condvar::new()));
        let fill = slot.clone();
        let token = match self.watch(
            key,
            Box::new(move |v| {
                *fill.0.lock().unwrap() = Some(v);
                fill.1.notify_all();
            }),
        ) {
            // Fired inline: the key already existed.
            None => return slot.0.lock().unwrap().take(),
            Some(token) => token,
        };
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut guard = slot.0.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return Some(v);
            }
            match deadline {
                None => guard = slot.1.wait(guard).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        drop(guard);
                        if self.unwatch(key, token) {
                            return None; // disarmed before firing
                        }
                        // Fired concurrently with the timeout: the
                        // callback is landing; take its value.
                        guard = slot.0.lock().unwrap();
                        loop {
                            if let Some(v) = guard.take() {
                                return Some(v);
                            }
                            guard = slot.1.wait(guard).unwrap();
                        }
                    }
                    let (g, _) = slot.1.wait_timeout(guard, d - now).unwrap();
                    guard = g;
                }
            }
        }
    }

    /// Batched existence check under one lock acquisition, positionally
    /// aligned with `keys` (the wire half of `Connector::exists_many`).
    pub fn mexists(&self, keys: &[String]) -> Vec<bool> {
        self.bump();
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        keys.iter().map(|k| inner.data.contains_key(k)).collect()
    }

    /// Batched delete under one lock acquisition; returns how many of the
    /// keys existed (the wire half of `Connector::delete_many`).
    pub fn mdel(&self, keys: &[String]) -> i64 {
        self.bump();
        let (m, _) = &*self.inner;
        let (removed, logged) = {
            let mut inner = m.lock().unwrap();
            let mut removed = 0;
            let mut freed = 0;
            let mut logged = None;
            for key in keys {
                if let Some(old) = inner.data.remove(key) {
                    freed += old.len();
                    removed += 1;
                    logged = self.log(encode_del(key)).or(logged);
                }
            }
            self.gauge.sub(freed);
            (removed, logged)
        };
        self.commit_logged(logged);
        removed
    }

    /// Returns true if the key existed.
    pub fn del(&self, key: &str) -> bool {
        self.bump();
        let (m, _) = &*self.inner;
        let logged = {
            let mut inner = m.lock().unwrap();
            match inner.data.remove(key) {
                Some(old) => {
                    self.gauge.sub(old.len());
                    self.log(encode_del(key))
                }
                None => return false,
            }
        };
        self.commit_logged(logged);
        true
    }

    pub fn exists(&self, key: &str) -> bool {
        self.bump();
        let (m, _) = &*self.inner;
        m.lock().unwrap().data.contains_key(key)
    }

    pub fn incr(&self, key: &str, by: i64) -> i64 {
        self.bump();
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        let v = inner.counters.entry(key.to_string()).or_insert(0);
        *v += by;
        *v
    }

    pub fn keys(&self, prefix: &str) -> Vec<String> {
        self.bump();
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        let mut out: Vec<String> = inner
            .data
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        out.sort();
        out
    }

    pub fn lpush(&self, list: &str, value: Bytes) {
        self.bump();
        let (m, cv) = &*self.inner;
        let mut inner = m.lock().unwrap();
        self.gauge.add(value.0.len());
        inner
            .lists
            .entry(list.to_string())
            .or_default()
            .push_front(value);
        cv.notify_all();
    }

    /// Blocking pop from the tail (FIFO with lpush).
    pub fn brpop(&self, list: &str, timeout: Option<Duration>) -> Option<Bytes> {
        self.bump();
        let (m, cv) = &*self.inner;
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut inner = m.lock().unwrap();
        loop {
            if let Some(q) = inner.lists.get_mut(list) {
                if let Some(v) = q.pop_back() {
                    self.gauge.sub(v.0.len());
                    return Some(v);
                }
            }
            match deadline {
                None => inner = cv.wait(inner).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return None;
                    }
                    let (guard, _) = cv.wait_timeout(inner, d - now).unwrap();
                    inner = guard;
                    if Instant::now() >= d {
                        let empty = inner
                            .lists
                            .get(list)
                            .map(|q| q.is_empty())
                            .unwrap_or(true);
                        if empty {
                            return None;
                        }
                    }
                }
            }
        }
    }

    /// Register a subscriber; returns the receiving end.
    pub fn subscribe(&self, channels: &[String]) -> mpsc::Receiver<PubSubMsg> {
        self.bump();
        let (tx, rx) = mpsc::channel();
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        for c in channels {
            inner
                .subscribers
                .entry(c.clone())
                .or_default()
                .push(tx.clone());
        }
        rx
    }

    /// Publish; returns the number of live receivers.
    pub fn publish(&self, channel: &str, payload: Bytes) -> i64 {
        self.bump();
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        let mut delivered = 0;
        if let Some(subs) = inner.subscribers.get_mut(channel) {
            subs.retain(|tx| {
                let ok = tx
                    .send(PubSubMsg {
                        channel: channel.to_string(),
                        payload: payload.clone(),
                    })
                    .is_ok();
                if ok {
                    delivered += 1;
                }
                ok
            });
        }
        delivered
    }

    pub fn flush_all(&self) {
        self.bump();
        let (m, cv) = &*self.inner;
        let logged = {
            let mut inner = m.lock().unwrap();
            let freed: usize =
                inner.data.values().map(|v| v.len()).sum::<usize>()
                    + inner
                        .lists
                        .values()
                        .flat_map(|q| q.iter().map(|v| v.0.len()))
                        .sum::<usize>();
            self.gauge.sub(freed);
            inner.data.clear();
            inner.lists.clear();
            inner.counters.clear();
            cv.notify_all();
            self.log(vec![REC_CLEAR])
        };
        self.commit_logged(logged);
    }

    pub fn stats(&self) -> (u64, u64, u64) {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        (
            inner.data.len() as u64,
            self.gauge.get().max(0) as u64,
            self.ops_served(),
        )
    }

    /// Validate key size limits (paper notes Redis' 512 MB value cap).
    pub fn check_value_size(value: &Bytes) -> Result<()> {
        const MAX: usize = 512 * 1024 * 1024;
        if value.0.len() > MAX {
            return Err(Error::Protocol(format!(
                "value {} bytes exceeds 512MB cap",
                value.0.len()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_del_roundtrip() {
        let kv = KvState::new();
        assert!(kv.get("k").is_none());
        kv.set("k", Bytes(vec![1, 2, 3]));
        assert_eq!(kv.get("k"), Some(Bytes(vec![1, 2, 3])));
        assert!(kv.exists("k"));
        assert_eq!(kv.gauge.get(), 3);
        assert!(kv.del("k"));
        assert!(!kv.del("k"));
        assert_eq!(kv.gauge.get(), 0);
    }

    #[test]
    fn overwrite_adjusts_gauge() {
        let kv = KvState::new();
        kv.set("k", Bytes(vec![0; 100]));
        kv.set("k", Bytes(vec![0; 40]));
        assert_eq!(kv.gauge.get(), 40);
        assert_eq!(kv.gauge.peak(), 140); // transiently both resident
    }

    #[test]
    fn set_nx_only_first_wins() {
        let kv = KvState::new();
        assert!(kv.set_nx("k", Bytes(vec![1])));
        assert!(!kv.set_nx("k", Bytes(vec![2])));
        assert_eq!(kv.get("k"), Some(Bytes(vec![1])));
    }

    #[test]
    fn wait_get_times_out() {
        let kv = KvState::new();
        let t0 = Instant::now();
        let v = kv.wait_get("missing", Some(Duration::from_millis(30)));
        assert!(v.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn wait_get_wakes_on_set() {
        let kv = KvState::new();
        let kv2 = kv.clone();
        let h = std::thread::spawn(move || {
            kv2.wait_get("later", Some(Duration::from_secs(5)))
        });
        std::thread::sleep(Duration::from_millis(20));
        kv.set("later", Bytes(vec![7]));
        assert_eq!(h.join().unwrap(), Some(Bytes(vec![7])));
    }

    #[test]
    fn list_fifo_and_blocking_pop() {
        let kv = KvState::new();
        kv.lpush("q", Bytes(vec![1]));
        kv.lpush("q", Bytes(vec![2]));
        assert_eq!(kv.brpop("q", None), Some(Bytes(vec![1])));
        assert_eq!(kv.brpop("q", None), Some(Bytes(vec![2])));
        assert_eq!(kv.brpop("q", Some(Duration::from_millis(10))), None);

        let kv2 = kv.clone();
        let h = std::thread::spawn(move || kv2.brpop("q", None));
        std::thread::sleep(Duration::from_millis(20));
        kv.lpush("q", Bytes(vec![3]));
        assert_eq!(h.join().unwrap(), Some(Bytes(vec![3])));
        assert_eq!(kv.gauge.get(), 0);
    }

    #[test]
    fn pubsub_fanout_and_counts() {
        let kv = KvState::new();
        let rx1 = kv.subscribe(&["c".to_string()]);
        let rx2 = kv.subscribe(&["c".to_string()]);
        assert_eq!(kv.publish("c", Bytes(vec![5])), 2);
        assert_eq!(rx1.recv().unwrap().payload, Bytes(vec![5]));
        assert_eq!(rx2.recv().unwrap().payload, Bytes(vec![5]));
        assert_eq!(kv.publish("nobody", Bytes(vec![1])), 0);
        drop(rx1);
        assert_eq!(kv.publish("c", Bytes(vec![6])), 1);
    }

    #[test]
    fn incr_and_keys() {
        let kv = KvState::new();
        assert_eq!(kv.incr("n", 2), 2);
        assert_eq!(kv.incr("n", -5), -3);
        kv.set("a:1", Bytes(vec![]));
        kv.set("a:2", Bytes(vec![]));
        kv.set("b:1", Bytes(vec![]));
        assert_eq!(kv.keys("a:"), vec!["a:1".to_string(), "a:2".to_string()]);
    }

    #[test]
    fn flush_all_resets_gauge() {
        let kv = KvState::new();
        kv.set("a", Bytes(vec![0; 10]));
        kv.lpush("l", Bytes(vec![0; 5]));
        kv.flush_all();
        assert_eq!(kv.gauge.get(), 0);
        assert!(kv.get("a").is_none());
        let (keys, bytes, _) = kv.stats();
        assert_eq!((keys, bytes), (0, 0));
    }

    #[test]
    fn value_size_cap() {
        assert!(KvState::check_value_size(&Bytes(vec![0; 10])).is_ok());
        // Don't actually allocate 512MB; fabricate a length via from_raw parts
        // is unsafe -- just trust the threshold logic with a boundary test.
    }

    #[test]
    fn mget_alignment() {
        let kv = KvState::new();
        kv.set("x", Bytes(vec![1]));
        let got = kv.mget(&["x".into(), "y".into(), "x".into()]);
        assert_eq!(got, vec![Some(Bytes(vec![1])), None, Some(Bytes(vec![1]))]);
    }

    #[test]
    fn mset_batch_and_gauge() {
        let kv = KvState::new();
        kv.set("a", Bytes(vec![0; 10]));
        kv.mset(vec![
            ("a".into(), Bytes(vec![1; 4])), // overwrite shrinks gauge
            ("b".into(), Bytes(vec![2; 6])),
        ]);
        assert_eq!(kv.gauge.get(), 10);
        assert_eq!(kv.get("a"), Some(Bytes(vec![1; 4])));
        assert_eq!(kv.get("b"), Some(Bytes(vec![2; 6])));
        kv.mset(Vec::new()); // empty batch is a no-op
        assert_eq!(kv.gauge.get(), 10);
    }

    #[test]
    fn mdel_removes_batch_and_adjusts_gauge() {
        let kv = KvState::new();
        kv.set("a", Bytes(vec![0; 10]));
        kv.set("b", Bytes(vec![0; 20]));
        kv.set("c", Bytes(vec![0; 30]));
        let n = kv.mdel(&["a".into(), "missing".into(), "c".into()]);
        assert_eq!(n, 2);
        assert_eq!(kv.gauge.get(), 20);
        assert!(kv.get("a").is_none());
        assert!(kv.get("b").is_some());
        assert_eq!(kv.mdel(&[]), 0);
    }

    #[test]
    fn mexists_alignment() {
        let kv = KvState::new();
        kv.set("a", Bytes(vec![1]));
        kv.set("c", Bytes(vec![3]));
        assert_eq!(
            kv.mexists(&["a".into(), "b".into(), "c".into(), "a".into()]),
            vec![true, false, true, true]
        );
        assert_eq!(kv.mexists(&[]), Vec::<bool>::new());
    }

    #[test]
    fn watch_fires_on_set_and_disarms() {
        let kv = KvState::new();
        let fired = Arc::new(Mutex::new(Vec::new()));
        let f2 = fired.clone();
        let token = kv
            .watch("w", Box::new(move |v| f2.lock().unwrap().push(v.to_vec())))
            .expect("key absent: must register");
        assert_eq!(kv.watch_count(), 1);
        kv.set("other", Bytes(vec![9])); // unrelated write: no wake
        assert!(fired.lock().unwrap().is_empty());
        kv.set("w", Bytes(vec![1, 2]));
        assert_eq!(*fired.lock().unwrap(), vec![vec![1, 2]]);
        assert_eq!(kv.watch_count(), 0, "fired watch must disarm");
        // One-shot: a second write does not re-fire.
        kv.set("w", Bytes(vec![3]));
        assert_eq!(fired.lock().unwrap().len(), 1);
        assert!(!kv.unwatch("w", token), "already fired");
    }

    #[test]
    fn watch_existing_key_fires_inline() {
        let kv = KvState::new();
        kv.set("here", Bytes(vec![7]));
        let fired = Arc::new(Mutex::new(None));
        let f2 = fired.clone();
        let token =
            kv.watch("here", Box::new(move |v| *f2.lock().unwrap() = Some(v)));
        assert!(token.is_none(), "existing key fires without registering");
        assert_eq!(
            fired.lock().unwrap().as_ref().map(|v| v.to_vec()),
            Some(vec![7])
        );
        assert_eq!(kv.watch_count(), 0);
    }

    #[test]
    fn unwatch_disarms_and_mset_fires_batch_watchers() {
        let kv = KvState::new();
        let count = Arc::new(Mutex::new(0));
        let c2 = count.clone();
        let token = kv
            .watch("a", Box::new(move |_| *c2.lock().unwrap() += 1))
            .unwrap();
        assert!(kv.unwatch("a", token));
        assert!(!kv.unwatch("a", token), "second disarm is a no-op");
        kv.set("a", Bytes(vec![1]));
        assert_eq!(*count.lock().unwrap(), 0, "disarmed watch must not fire");

        // mset fires every touched key's watchers, none of the others.
        let hits = Arc::new(Mutex::new(Vec::new()));
        for key in ["b", "c", "d"] {
            let h = hits.clone();
            kv.watch(key, Box::new(move |v| h.lock().unwrap().push(v.to_vec())))
                .unwrap();
        }
        kv.mset(vec![
            ("b".into(), Bytes(vec![1])),
            ("c".into(), Bytes(vec![2])),
        ]);
        let mut got = hits.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, vec![vec![1], vec![2]]);
        assert_eq!(kv.watch_count(), 1, "d stays armed");
    }

    #[test]
    fn set_nx_fires_watchers_only_when_stored() {
        let kv = KvState::new();
        let count = Arc::new(Mutex::new(0));
        let c2 = count.clone();
        kv.watch("nx", Box::new(move |_| *c2.lock().unwrap() += 1))
            .unwrap();
        assert!(kv.set_nx("nx", Bytes(vec![1])));
        assert_eq!(*count.lock().unwrap(), 1);
        let c3 = count.clone();
        // Key exists now: a losing set_nx fires nothing (watch fires
        // inline at registration instead).
        assert!(kv
            .watch("nx", Box::new(move |_| *c3.lock().unwrap() += 10))
            .is_none());
        assert!(!kv.set_nx("nx", Bytes(vec![2])));
        assert_eq!(*count.lock().unwrap(), 11);
    }

    #[test]
    fn mset_wakes_blocked_waiters() {
        let kv = KvState::new();
        let kv2 = kv.clone();
        let h = std::thread::spawn(move || {
            kv2.wait_get("batched", Some(Duration::from_secs(5)))
        });
        std::thread::sleep(Duration::from_millis(20));
        kv.mset(vec![("batched".into(), Bytes(vec![3]))]);
        assert_eq!(h.join().unwrap(), Some(Bytes(vec![3])));
    }

    fn durable_opts(tag: &str) -> DurabilityOptions {
        let dir = std::env::temp_dir().join(format!(
            "pallas-kvstate-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        DurabilityOptions::new(dir)
    }

    #[test]
    fn durable_mutations_survive_reopen() {
        let opts =
            durable_opts("reopen").fsync(crate::persist::FsyncPolicy::Off);
        let kv = KvState::open_durable(&opts).unwrap();
        assert!(kv.is_durable());
        assert_eq!(kv.recovery_stats().unwrap().replayed_records, 0);
        kv.set("a", Bytes(vec![1; 8]));
        kv.set("b", Bytes(vec![2; 8]));
        kv.mset(vec![
            ("c".into(), Bytes(vec![3; 4])),
            ("a".into(), Bytes(vec![9; 2])), // overwrite
        ]);
        assert!(kv.set_nx("d", Bytes(vec![4])));
        assert!(!kv.set_nx("d", Bytes(vec![5]))); // loser: not logged
        assert!(kv.del("b"));
        assert_eq!(kv.mdel(&["c".into(), "missing".into()]), 1);
        kv.persist_sync();
        drop(kv);

        let kv = KvState::open_durable(&opts).unwrap();
        let stats = kv.recovery_stats().unwrap();
        // set a, set b, 2x mset, set_nx d, del b, mdel c = 7 records.
        assert_eq!(stats.replayed_records, 7);
        assert_eq!(stats.truncated_records, 0);
        assert_eq!(kv.get("a"), Some(Bytes(vec![9; 2])));
        assert!(kv.get("b").is_none());
        assert!(kv.get("c").is_none());
        assert_eq!(kv.get("d"), Some(Bytes(vec![4])));
        // Gauge reflects recovered residency: a (2) + d (1).
        assert_eq!(kv.gauge.get(), 3);
        let _ = std::fs::remove_dir_all(&opts.data_dir);
    }

    #[test]
    fn durable_snapshot_pins_and_reclaims_wal() {
        let opts = durable_opts("snap")
            .fsync(crate::persist::FsyncPolicy::Off)
            .segment_bytes(4096)
            .snapshot_every_ops(32);
        let kv = KvState::open_durable(&opts).unwrap();
        for i in 0..100u32 {
            kv.set(&format!("k{i}"), Bytes(vec![i as u8; 256]));
        }
        kv.persist_sync();
        drop(kv);

        // A snapshot rolled (≥32 mutations) and reclaimed covered
        // segments: recovery seeds from it and replays only the tail.
        let kv = KvState::open_durable(&opts).unwrap();
        let stats = kv.recovery_stats().unwrap();
        assert!(stats.snapshot_seq.is_some());
        assert!(
            stats.replayed_records < 100,
            "tail replay only, got {}",
            stats.replayed_records
        );
        for i in 0..100u32 {
            assert_eq!(
                kv.get(&format!("k{i}")),
                Some(Bytes(vec![i as u8; 256]))
            );
        }
        // New writes continue cleanly after recovery.
        kv.set("post", Bytes(vec![7]));
        kv.persist_sync();
        drop(kv);
        let kv = KvState::open_durable(&opts).unwrap();
        assert_eq!(kv.get("post"), Some(Bytes(vec![7])));
        let _ = std::fs::remove_dir_all(&opts.data_dir);
    }

    #[test]
    fn durable_flush_all_clears_recovered_state() {
        let opts =
            durable_opts("flush").fsync(crate::persist::FsyncPolicy::Off);
        let kv = KvState::open_durable(&opts).unwrap();
        kv.set("gone", Bytes(vec![1; 16]));
        kv.flush_all();
        kv.set("kept", Bytes(vec![2; 16]));
        kv.persist_sync();
        drop(kv);
        let kv = KvState::open_durable(&opts).unwrap();
        assert!(kv.get("gone").is_none());
        assert_eq!(kv.get("kept"), Some(Bytes(vec![2; 16])));
        let _ = std::fs::remove_dir_all(&opts.data_dir);
    }
}
