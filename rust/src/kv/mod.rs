//! redis-sim: the mediated-channel substrate.
//!
//! The paper's deployments use Redis/KeyDB servers as the mediated
//! communication channel between proxy producers and consumers. The offline
//! environment has no Redis, so this module implements the required subset
//! from scratch: a TCP KV server ([`KvServer`]) with Redis-flavoured
//! semantics (GET/SET/DEL/EXISTS/MGET/MPUT/MDEL, pub/sub channels, lists
//! with blocking pop) plus two extensions. `WaitGet` is a server-side
//! blocking GET (it parks the connection; kept as a protocol primitive).
//! The **watch plane** supersedes it for real waiting: `Watch` arms a
//! one-shot waiter in the engine's registry and the eventual value
//! arrives as an out-of-band `Notify` push routed by watch id, so parked
//! waiters share the pipelined connection with live traffic — this is
//! what ProxyFutures resolution and every `wait_get` ride now.
//! The batched trio `MGET`/`MPUT`/`MDEL` moves whole key sets per frame:
//! the shard fabric ([`crate::shard`]) rides the first two for
//! `get_many`/`put_many`, and ownership's bulk-eviction paths (lifetime
//! close, `Store::evict_many`) ride `MDEL` via `Connector::delete_many`.
//!
//! The storage engine ([`KvState`]) is usable embedded (zero-copy,
//! in-process) or over TCP ([`KvClient`]/[`KvSubscriber`]); connectors can
//! pick either, which lets benches separate protocol overhead from engine
//! overhead. The TCP client is *pipelined* ([`KvClient`]): N in-flight
//! requests share one socket, with a reader thread matching FIFO
//! responses to [`Pending`](crate::ops::Pending) completion handles —
//! the wire half of the nonblocking submission API in [`crate::ops`].

mod client;
mod protocol;
mod server;
mod state;

pub use client::{ClientOptions, FlushPolicy, KvClient, KvSubscriber};
pub use protocol::{
    decode_response_owned, read_frame, read_frame_raw, write_frame,
    write_frame_reusing, write_frame_unflushed, Request, Response,
};
pub use server::KvServer;
pub use state::{KvState, PubSubMsg};
