//! The high-level `Store` interface (paper Sec III, Fig 2).
//!
//! A [`Store`] wraps a [`Connector`] and provides typed object operations:
//! `put`/`get`/`evict`, proxy creation ([`Store::proxy`]), distributed
//! futures ([`Store::future`]), owned proxies ([`crate::ownership`]), and
//! lifetime attachment. Keys are generated, unique, and never reused.
//!
//! Batched operations ([`Store::put_many`], [`Store::get_many`],
//! [`Store::proxy_many`]) move whole key sets per call; connectors with a
//! wire protocol serve them in one round trip (`MGET`/`MPUT`), and the
//! sharded fabric ([`crate::shard`]) fans them out across backends in
//! parallel. [`StoreMetrics`] counts batched traffic per key and per byte,
//! exactly like the single-key operations.
//!
//! Asynchronous operations ([`Store::put_async`], [`Store::get_async`],
//! [`Store::proxy_async`]) submit instead of blocking: the op is in
//! flight when the call returns — on the wire for pipelined channels
//! ([`crate::ops`]), on a shared reactor worker otherwise — and the
//! caller settles via the returned [`PendingWrite`]/[`PendingGet`]
//! handle, overlapping resolution with compute.
//!
//! The connector zoo spans the paper's deployments and the scaling work on
//! top: in-process memory, shared filesystem, TCP KV ([`TcpKvConnector`]),
//! throttled/netsim views, size-policy multi-routing, and the
//! consistent-hash shard fabric ([`crate::shard::ShardedConnector`]) with
//! replication and read-fallback.

mod connectors;

pub use connectors::{
    Blob, Connector, ConnectorDesc, FileConnector, MemoryConnector,
    MultiConnector, TcpKvConnector, ThrottledConnector,
};

use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::codec::{Decode, Encode};
use crate::error::Result;
use crate::futures::ProxyFuture;
use crate::metrics::{MirroredCounter, StoreBytes};
use crate::ops::{self, Op, OpResult, Pending};
use crate::proxy::{Factory, Proxy};

/// Typed object store over a mediated channel. Cheap to clone.
#[derive(Clone)]
pub struct Store {
    inner: Arc<StoreInner>,
}

struct StoreInner {
    name: String,
    connector: Arc<dyn Connector>,
    next_key: AtomicU64,
    /// Operation counters (puts, gets, evictions) for diagnostics. Each
    /// is exact per-store and mirrored into the process-wide telemetry
    /// registry (`store.puts` etc.) so one snapshot covers every store.
    puts: MirroredCounter,
    gets: MirroredCounter,
    evicts: MirroredCounter,
    put_bytes: MirroredCounter,
    get_bytes: MirroredCounter,
}

/// Snapshot of a store's operation counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreMetrics {
    pub puts: u64,
    pub gets: u64,
    pub evicts: u64,
    pub put_bytes: u64,
    pub get_bytes: u64,
}

impl Store {
    /// Create a store over an explicit connector.
    pub fn new(name: &str, connector: Arc<dyn Connector>) -> Store {
        Store {
            inner: Arc::new(StoreInner {
                name: name.to_string(),
                connector,
                next_key: AtomicU64::new(0),
                puts: MirroredCounter::new("store.puts"),
                gets: MirroredCounter::new("store.gets"),
                evicts: MirroredCounter::new("store.evicts"),
                put_bytes: MirroredCounter::new("store.put_bytes"),
                get_bytes: MirroredCounter::new("store.get_bytes"),
            }),
        }
    }

    /// Convenience: store over a fresh in-process channel.
    pub fn memory(name: &str) -> Store {
        Store::new(name, MemoryConnector::new())
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    pub fn connector(&self) -> &Arc<dyn Connector> {
        &self.inner.connector
    }

    /// Store-resident bytes gauge, if the connector reports one.
    pub fn gauge(&self) -> Option<Arc<StoreBytes>> {
        self.inner.connector.gauge()
    }

    /// Generate a fresh unique key.
    pub fn new_key(&self) -> String {
        let n = self.inner.next_key.fetch_add(1, Ordering::Relaxed);
        // Salt with a per-process nonce so independent Store instances
        // sharing one channel never collide.
        static SALT: AtomicU64 = AtomicU64::new(0);
        static SALT_INIT: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
        let salt = *SALT_INIT.get_or_init(|| {
            SALT.fetch_add(1, Ordering::Relaxed);
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(1)
        });
        format!("{}-{:x}-{}", self.inner.name, salt ^ (n << 20), n)
    }

    /// Serialize and store an object; returns its key.
    pub fn put<T: Encode>(&self, obj: &T) -> Result<String> {
        let key = self.new_key();
        self.put_at(&key, obj)?;
        Ok(key)
    }

    /// Serialize and store at an explicit key.
    pub fn put_at<T: Encode>(&self, key: &str, obj: &T) -> Result<()> {
        let data = obj.to_bytes();
        self.inner.puts.incr();
        self.inner.put_bytes.add(data.len() as u64);
        self.inner.connector.put(key, data)
    }

    /// Fetch and decode an object.
    pub fn get<T: Decode>(&self, key: &str) -> Result<Option<T>> {
        self.inner.gets.incr();
        match self.inner.connector.get(key)? {
            Some(bytes) => {
                self.inner.get_bytes.add(bytes.len() as u64);
                Ok(Some(T::from_bytes(&bytes)?))
            }
            None => Ok(None),
        }
    }

    /// Fetch the raw serialized bytes as a zero-copy view: a
    /// [`Buf`](crate::codec::Buf) window over the channel's own
    /// allocation (the memory engine's stored value, a TCP response
    /// frame). Use when the caller wants the bytes themselves — e.g. to
    /// forward them — rather than a decoded object; counts toward the
    /// same get metrics as [`Store::get`].
    pub fn get_view(&self, key: &str) -> Result<Option<crate::codec::Buf>> {
        self.inner.gets.incr();
        match self.inner.connector.get_view(key)? {
            Some(view) => {
                self.inner.get_bytes.add(view.len() as u64);
                Ok(Some(view))
            }
            None => Ok(None),
        }
    }

    /// Blocking fetch for a key that may not exist yet: arms a watch on
    /// the connector's event plane and parks on the handle — one push
    /// wakes the wait (`Ok(None)` = timed out).
    pub fn wait_get<T: Decode>(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<T>> {
        self.inner.gets.incr();
        let handle = self.inner.connector.watch(key);
        let got = match timeout {
            None => Some(handle.wait()?),
            Some(t) => handle.wait_timeout(t)?,
        };
        match got {
            Some(bytes) => {
                self.inner.get_bytes.add(bytes.len() as u64);
                Ok(Some(T::from_bytes(&bytes)?))
            }
            None => Ok(None),
        }
    }

    /// Arm a watch without blocking: the returned handle completes when
    /// the key exists (immediately if it already does). The async twin of
    /// [`Store::wait_get`], riding the out-of-band watch plane through
    /// the submission API ([`Op::Watch`]) — a parked handle costs no
    /// dedicated connection, no thread, and no poll tick on channels with
    /// a native watch.
    pub fn watch_async<T: Decode>(&self, key: &str) -> PendingGet<T> {
        self.inner.gets.incr();
        let handle = ops::submit(
            &self.inner.connector,
            Op::Watch { key: key.to_string() },
        );
        PendingGet { store: self.clone(), handle, _marker: PhantomData }
    }

    /// Batched serialize-and-store; returns the generated keys, aligned
    /// with `objs`. One connector `put_many` (a single wire round trip on
    /// batching channels; a parallel fan-out on the shard fabric).
    pub fn put_many<T: Encode>(&self, objs: &[T]) -> Result<Vec<String>> {
        let mut items = Vec::with_capacity(objs.len());
        let mut keys = Vec::with_capacity(objs.len());
        let mut total = 0u64;
        for obj in objs {
            let key = self.new_key();
            let data = obj.to_bytes();
            total += data.len() as u64;
            items.push((key.clone(), data));
            keys.push(key);
        }
        // Counters account per key / per byte, same as the single-key ops.
        self.inner.puts.add(objs.len() as u64);
        self.inner.put_bytes.add(total);
        self.inner.connector.put_many(items)?;
        Ok(keys)
    }

    /// Batched fetch-and-decode, positionally aligned with `keys`
    /// (`None` = missing). Amortizes round trips the same way
    /// [`Store::put_many`] does.
    pub fn get_many<T: Decode>(&self, keys: &[String]) -> Result<Vec<Option<T>>> {
        self.inner.gets.add(keys.len() as u64);
        let blobs = self.inner.connector.get_many(keys)?;
        let mut out = Vec::with_capacity(blobs.len());
        for blob in blobs {
            match blob {
                Some(bytes) => {
                    self.inner.get_bytes.add(bytes.len() as u64);
                    out.push(Some(T::from_bytes(&bytes)?));
                }
                None => out.push(None),
            }
        }
        Ok(out)
    }

    /// Mint lazy proxies for a whole batch with one batched put (the
    /// producer-side analogue of [`crate::proxy::prefetch`]).
    pub fn proxy_many<T: Encode>(&self, objs: &[T]) -> Result<Vec<Proxy<T>>> {
        let keys = self.put_many(objs)?;
        Ok(keys
            .iter()
            .map(|k| Proxy::from_factory(self.factory_for(k, false, 0)))
            .collect())
    }

    pub fn exists(&self, key: &str) -> Result<bool> {
        self.inner.connector.exists(key)
    }

    pub fn evict(&self, key: &str) -> Result<()> {
        self.inner.evicts.incr();
        // Keep same-process semantics intuitive: an evicted key is gone.
        crate::proxy::cache::global()
            .invalidate(&self.inner.connector.desc().to_bytes(), key);
        self.inner.connector.evict(key)
    }

    /// Batched eviction: one connector `delete_many` (native MDEL on wire
    /// channels, parallel per-shard sweep on the fabric) instead of a
    /// round trip per key. Proxy caches are invalidated like `evict`.
    pub fn evict_many(&self, keys: &[String]) -> Result<()> {
        self.inner.evicts.add(keys.len() as u64);
        let desc = self.inner.connector.desc().to_bytes();
        for key in keys {
            crate::proxy::cache::global().invalidate(&desc, key);
        }
        self.inner.connector.delete_many(keys)
    }

    /// Submit a serialize-and-store without blocking on the channel: the
    /// key is generated and the write is in flight when this returns.
    /// Channels with a native pipeline (TCP KV) put the op on the wire;
    /// blocking channels are driven by a shared reactor worker — either
    /// way the caller overlaps the write with its own compute and settles
    /// via [`PendingWrite::wait`].
    pub fn put_async<T: Encode>(&self, obj: &T) -> PendingWrite {
        let key = self.new_key();
        let data = obj.to_bytes();
        self.inner.puts.incr();
        self.inner.put_bytes.add(data.len() as u64);
        let handle =
            ops::submit(&self.inner.connector, Op::Put { key: key.clone(), data });
        PendingWrite { key, handle, settled: Mutex::new(None) }
    }

    /// Submit a fetch without blocking on the channel; decode happens at
    /// [`PendingGet::wait`]. The async twin of [`Store::get`], for
    /// overlapping resolution with compute (issue the get early, take the
    /// value where it's needed).
    pub fn get_async<T: Decode>(&self, key: &str) -> PendingGet<T> {
        self.inner.gets.incr();
        let handle =
            ops::submit(&self.inner.connector, Op::Get { key: key.to_string() });
        PendingGet { store: self.clone(), handle, _marker: PhantomData }
    }

    /// Mint a proxy while its target's write is still in flight. The
    /// proxy carries ProxyFutures wait semantics (like [`Store::future`]):
    /// resolution parks until the target exists, so resolving before the
    /// write lands is safe on *every* channel — pipelined or pooled — it
    /// just waits out the in-flight put. The trade-off is the same one
    /// futures make: if the write *fails*, the target never appears and a
    /// resolver waits forever — wait on the returned [`PendingWrite`]
    /// first wherever the write can fail (it surfaces the error).
    pub fn proxy_async<T: Encode>(&self, obj: &T) -> (Proxy<T>, PendingWrite) {
        let write = self.put_async(obj);
        let proxy = Proxy::from_factory(self.factory_for(&write.key, true, 0));
        (proxy, write)
    }

    /// Factory metadata for a key in this store.
    pub fn factory_for(&self, key: &str, wait: bool, timeout_ms: u64) -> Factory {
        Factory {
            desc: self.inner.connector.desc(),
            key: key.to_string(),
            wait,
            timeout_ms,
            store_name: self.inner.name.clone(),
        }
    }

    /// Create a lazy transparent proxy of `obj` (paper: `Store.proxy(t)`):
    /// serialize, put, wrap the factory.
    pub fn proxy<T: Encode>(&self, obj: &T) -> Result<Proxy<T>> {
        let key = self.put(obj)?;
        Ok(Proxy::from_factory(self.factory_for(&key, false, 0)))
    }

    /// Proxy an already-stored key.
    pub fn proxy_from_key<T>(&self, key: &str) -> Proxy<T> {
        Proxy::from_factory(self.factory_for(key, false, 0))
    }

    /// Create a distributed future bound to this store (paper Sec IV-A:
    /// `Store.future()`).
    pub fn future<T>(&self) -> ProxyFuture<T> {
        let key = format!("future-{}", self.new_key());
        ProxyFuture::new(self.factory_for(&key, true, 0))
    }

    /// Counter snapshot.
    pub fn metrics(&self) -> StoreMetrics {
        StoreMetrics {
            puts: self.inner.puts.get(),
            gets: self.inner.gets.get(),
            evicts: self.inner.evicts.get(),
            put_bytes: self.inner.put_bytes.get(),
            get_bytes: self.inner.get_bytes.get(),
        }
    }
}

impl std::fmt::Debug for Store {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Store")
            .field("name", &self.inner.name)
            .field("connector", &self.inner.connector.desc())
            .finish()
    }
}

/// Completion handle for an asynchronously submitted store write
/// ([`Store::put_async`], [`Store::proxy_async`]). Drop-safe: abandoning
/// the handle abandons only the acknowledgement, never the write.
/// [`PendingWrite::wait`] is idempotent — the settled outcome is cached,
/// so a defensive second wait sees the same result, not a take error.
pub struct PendingWrite {
    key: String,
    handle: Pending<OpResult>,
    /// Cached outcome, so repeated waits all report the real result.
    settled: Mutex<Option<Result<()>>>,
}

impl PendingWrite {
    /// The key the object was (or is being) stored under.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Whether the write has settled.
    pub fn is_complete(&self) -> bool {
        self.handle.is_complete()
    }

    /// Block until the write lands (or surfaces its error). Idempotent:
    /// every call reports the same settled outcome.
    pub fn wait(&self) -> Result<()> {
        let mut settled = self.settled.lock().unwrap();
        if let Some(res) = &*settled {
            return res.clone();
        }
        let res = self.handle.wait().and_then(OpResult::into_unit);
        *settled = Some(res.clone());
        res
    }

    /// Bounded wait: `Ok(false)` if still in flight when the timeout
    /// elapses (the handle stays usable; wait again later). A settled
    /// outcome — success or error — is cached like [`PendingWrite::wait`].
    /// Stays bounded even while another thread is parked in an indefinite
    /// [`PendingWrite::wait`]: the settle lock is only ever *tried*, never
    /// blocked on past the deadline.
    pub fn wait_timeout(&self, timeout: Duration) -> Result<bool> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Ok(mut settled) = self.settled.try_lock() {
                if let Some(res) = &*settled {
                    return res.clone().map(|()| true);
                }
                let now = Instant::now();
                let left = deadline.saturating_duration_since(now);
                return match self.handle.wait_timeout(left) {
                    Ok(Some(op)) => {
                        let res = op.into_unit();
                        *settled = Some(res.clone());
                        res.map(|()| true)
                    }
                    Ok(None) => Ok(false),
                    Err(e) => {
                        *settled = Some(Err(e.clone()));
                        Err(e)
                    }
                };
            }
            // Another thread holds the settle lock (likely parked in an
            // unbounded wait). Poll until it records or we time out.
            if Instant::now() >= deadline {
                return Ok(false);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// Typed completion handle for [`Store::get_async`]: decode happens at
/// take time, so the fetch crosses the wire while the caller computes.
/// [`PendingGet::wait`] consumes the handle — the decoded value moves out
/// exactly once, and a second wait is a compile error rather than a
/// runtime surprise.
pub struct PendingGet<T> {
    store: Store,
    handle: Pending<OpResult>,
    _marker: PhantomData<fn() -> T>,
}

impl<T: Decode> PendingGet<T> {
    /// Whether the fetch has settled.
    pub fn is_complete(&self) -> bool {
        self.handle.is_complete()
    }

    /// Block until the fetch completes; decode and return the value
    /// (`None` = missing, like [`Store::get`]). Consumes the handle.
    pub fn wait(self) -> Result<Option<T>> {
        match self.handle.wait()?.into_value()? {
            Some(bytes) => {
                self.store.inner.get_bytes.add(bytes.len() as u64);
                Ok(Some(T::from_bytes(&bytes)?))
            }
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = Store::memory("t");
        let key = s.put(&"value".to_string()).unwrap();
        assert_eq!(s.get::<String>(&key).unwrap(), Some("value".into()));
        assert!(s.exists(&key).unwrap());
        s.evict(&key).unwrap();
        assert_eq!(s.get::<String>(&key).unwrap(), None);
        let m = s.metrics();
        assert_eq!(m.puts, 1);
        assert_eq!(m.gets, 2);
        assert_eq!(m.evicts, 1);
        assert!(m.put_bytes > 0);
    }

    #[test]
    fn batched_ops_roundtrip_and_count_metrics() {
        let s = Store::memory("t-batch");
        let objs: Vec<String> =
            (0..10).map(|i| format!("value-{i}")).collect();
        let keys = s.put_many(&objs).unwrap();
        assert_eq!(keys.len(), 10);
        let got: Vec<Option<String>> = s.get_many(&keys).unwrap();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.as_deref(), Some(format!("value-{i}").as_str()));
        }
        // Partial miss alignment.
        let mixed = vec![keys[0].clone(), "absent".to_string(), keys[9].clone()];
        let got: Vec<Option<String>> = s.get_many(&mixed).unwrap();
        assert!(got[0].is_some() && got[1].is_none() && got[2].is_some());
        // Empty batches.
        assert!(s.put_many::<String>(&[]).unwrap().is_empty());
        assert!(s.get_many::<String>(&[]).unwrap().is_empty());

        // Metrics must not undercount fabric traffic: batched ops add per
        // key and per byte, exactly like the single-key path.
        let m = s.metrics();
        assert_eq!(m.puts, 10);
        assert_eq!(m.gets, 13);
        let per_obj = objs[0].to_bytes().len() as u64;
        assert_eq!(m.put_bytes, 10 * per_obj);
        assert_eq!(m.get_bytes, 12 * per_obj);
    }

    #[test]
    fn proxy_many_mints_resolvable_proxies() {
        let s = Store::memory("t-proxy-many");
        let objs: Vec<u64> = (0..5).map(|i| i * 11).collect();
        let proxies = s.proxy_many(&objs).unwrap();
        assert_eq!(proxies.len(), 5);
        for (i, p) in proxies.iter().enumerate() {
            assert!(!p.is_resolved());
            assert_eq!(*p.resolve().unwrap(), i as u64 * 11);
        }
    }

    #[test]
    fn async_put_get_roundtrip_and_metrics() {
        let s = Store::memory("t-async");
        let write = s.put_async(&"async-value".to_string());
        write.wait().unwrap();
        assert!(write.is_complete());
        // Idempotent: a defensive second wait sees the cached outcome.
        write.wait().unwrap();
        assert!(write.wait_timeout(Duration::from_millis(5)).unwrap());
        let get = s.get_async::<String>(write.key());
        assert_eq!(get.wait().unwrap(), Some("async-value".into()));
        // Missing keys stay None, like the blocking path.
        assert_eq!(s.get_async::<String>("absent").wait().unwrap(), None);
        // Async traffic counts in the same per-key/per-byte metrics.
        let m = s.metrics();
        assert_eq!(m.puts, 1);
        assert_eq!(m.gets, 2);
        assert!(m.put_bytes > 0);
        assert_eq!(m.get_bytes, m.put_bytes);
    }

    #[test]
    fn wait_timeout_on_settled_write() {
        let s = Store::memory("t-async-timeout");
        let write = s.put_async(&7u64);
        // Memory completes at submit; a bounded wait must see that.
        assert!(write.wait_timeout(Duration::from_millis(50)).unwrap());
    }

    #[test]
    fn proxy_async_resolves_even_before_write_settles() {
        let s = Store::memory("t-proxy-async");
        let (proxy, write) = s.proxy_async(&vec![1u8, 2, 3]);
        assert_eq!(proxy.key(), write.key());
        // Wait-mode proxy: resolution parks until the in-flight write
        // lands, so resolving immediately is safe on any channel.
        assert_eq!(*proxy.resolve().unwrap(), vec![1u8, 2, 3]);
        write.wait().unwrap();
    }

    #[test]
    fn watch_async_completes_on_later_put() {
        let s = Store::memory("t-watch");
        let key = s.new_key();
        let pending = s.watch_async::<String>(&key);
        assert!(!pending.is_complete());
        s.put_at(&key, &"arrived".to_string()).unwrap();
        assert_eq!(pending.wait().unwrap(), Some("arrived".into()));
        // Already-stored keys complete immediately.
        let key2 = s.put(&7u64).unwrap();
        assert_eq!(s.watch_async::<u64>(&key2).wait().unwrap(), Some(7));
    }

    #[test]
    fn keys_are_unique() {
        let s = Store::memory("t");
        let mut keys = std::collections::HashSet::new();
        for _ in 0..1000 {
            assert!(keys.insert(s.new_key()));
        }
    }

    #[test]
    fn two_stores_share_one_channel() {
        let conn = MemoryConnector::new();
        let a = Store::new("a", conn.clone());
        let b = Store::new("b", conn);
        let key = a.put(&9u32).unwrap();
        assert_eq!(b.get::<u32>(&key).unwrap(), Some(9));
    }

    #[test]
    fn typed_decode_error_surfaces() {
        let s = Store::memory("t");
        let key = s.put(&"text".to_string()).unwrap();
        // Decoding a string as u64 must fail loudly, not garbage.
        assert!(s.get::<u64>(&key).is_err());
    }
}
