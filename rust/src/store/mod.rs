//! The high-level `Store` interface (paper Sec III, Fig 2).
//!
//! A [`Store`] wraps a [`Connector`] and provides typed object operations:
//! `put`/`get`/`evict`, proxy creation ([`Store::proxy`]), distributed
//! futures ([`Store::future`]), owned proxies ([`crate::ownership`]), and
//! lifetime attachment. Keys are generated, unique, and never reused.
//!
//! Batched operations ([`Store::put_many`], [`Store::get_many`],
//! [`Store::proxy_many`]) move whole key sets per call; connectors with a
//! wire protocol serve them in one round trip (`MGET`/`MPUT`), and the
//! sharded fabric ([`crate::shard`]) fans them out across backends in
//! parallel. [`StoreMetrics`] counts batched traffic per key and per byte,
//! exactly like the single-key operations.
//!
//! The connector zoo spans the paper's deployments and the scaling work on
//! top: in-process memory, shared filesystem, TCP KV ([`TcpKvConnector`]),
//! throttled/netsim views, size-policy multi-routing, and the
//! consistent-hash shard fabric ([`crate::shard::ShardedConnector`]) with
//! replication and read-fallback.

mod connectors;

pub use connectors::{
    Blob, Connector, ConnectorDesc, FileConnector, MemoryConnector,
    MultiConnector, TcpKvConnector, ThrottledConnector,
};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::codec::{Decode, Encode};
use crate::error::Result;
use crate::futures::ProxyFuture;
use crate::metrics::StoreBytes;
use crate::proxy::{Factory, Proxy};

/// Typed object store over a mediated channel. Cheap to clone.
#[derive(Clone)]
pub struct Store {
    inner: Arc<StoreInner>,
}

struct StoreInner {
    name: String,
    connector: Arc<dyn Connector>,
    next_key: AtomicU64,
    /// Operation counters (puts, gets, evictions) for diagnostics.
    puts: AtomicU64,
    gets: AtomicU64,
    evicts: AtomicU64,
    put_bytes: AtomicU64,
    get_bytes: AtomicU64,
}

/// Snapshot of a store's operation counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreMetrics {
    pub puts: u64,
    pub gets: u64,
    pub evicts: u64,
    pub put_bytes: u64,
    pub get_bytes: u64,
}

impl Store {
    /// Create a store over an explicit connector.
    pub fn new(name: &str, connector: Arc<dyn Connector>) -> Store {
        Store {
            inner: Arc::new(StoreInner {
                name: name.to_string(),
                connector,
                next_key: AtomicU64::new(0),
                puts: AtomicU64::new(0),
                gets: AtomicU64::new(0),
                evicts: AtomicU64::new(0),
                put_bytes: AtomicU64::new(0),
                get_bytes: AtomicU64::new(0),
            }),
        }
    }

    /// Convenience: store over a fresh in-process channel.
    pub fn memory(name: &str) -> Store {
        Store::new(name, MemoryConnector::new())
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    pub fn connector(&self) -> &Arc<dyn Connector> {
        &self.inner.connector
    }

    /// Store-resident bytes gauge, if the connector reports one.
    pub fn gauge(&self) -> Option<Arc<StoreBytes>> {
        self.inner.connector.gauge()
    }

    /// Generate a fresh unique key.
    pub fn new_key(&self) -> String {
        let n = self.inner.next_key.fetch_add(1, Ordering::Relaxed);
        // Salt with a per-process nonce so independent Store instances
        // sharing one channel never collide.
        static SALT: AtomicU64 = AtomicU64::new(0);
        static SALT_INIT: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
        let salt = *SALT_INIT.get_or_init(|| {
            SALT.fetch_add(1, Ordering::Relaxed);
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(1)
        });
        format!("{}-{:x}-{}", self.inner.name, salt ^ (n << 20), n)
    }

    /// Serialize and store an object; returns its key.
    pub fn put<T: Encode>(&self, obj: &T) -> Result<String> {
        let key = self.new_key();
        self.put_at(&key, obj)?;
        Ok(key)
    }

    /// Serialize and store at an explicit key.
    pub fn put_at<T: Encode>(&self, key: &str, obj: &T) -> Result<()> {
        let data = obj.to_bytes();
        self.inner.puts.fetch_add(1, Ordering::Relaxed);
        self.inner
            .put_bytes
            .fetch_add(data.len() as u64, Ordering::Relaxed);
        self.inner.connector.put(key, data)
    }

    /// Fetch and decode an object.
    pub fn get<T: Decode>(&self, key: &str) -> Result<Option<T>> {
        self.inner.gets.fetch_add(1, Ordering::Relaxed);
        match self.inner.connector.get(key)? {
            Some(bytes) => {
                self.inner
                    .get_bytes
                    .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                Ok(Some(T::from_bytes(&bytes)?))
            }
            None => Ok(None),
        }
    }

    /// Blocking fetch (used by futures and tests).
    pub fn wait_get<T: Decode>(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<T>> {
        self.inner.gets.fetch_add(1, Ordering::Relaxed);
        match self.inner.connector.wait_get(key, timeout)? {
            Some(bytes) => {
                self.inner
                    .get_bytes
                    .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                Ok(Some(T::from_bytes(&bytes)?))
            }
            None => Ok(None),
        }
    }

    /// Batched serialize-and-store; returns the generated keys, aligned
    /// with `objs`. One connector `put_many` (a single wire round trip on
    /// batching channels; a parallel fan-out on the shard fabric).
    pub fn put_many<T: Encode>(&self, objs: &[T]) -> Result<Vec<String>> {
        let mut items = Vec::with_capacity(objs.len());
        let mut keys = Vec::with_capacity(objs.len());
        let mut total = 0u64;
        for obj in objs {
            let key = self.new_key();
            let data = obj.to_bytes();
            total += data.len() as u64;
            items.push((key.clone(), data));
            keys.push(key);
        }
        // Counters account per key / per byte, same as the single-key ops.
        self.inner.puts.fetch_add(objs.len() as u64, Ordering::Relaxed);
        self.inner.put_bytes.fetch_add(total, Ordering::Relaxed);
        self.inner.connector.put_many(items)?;
        Ok(keys)
    }

    /// Batched fetch-and-decode, positionally aligned with `keys`
    /// (`None` = missing). Amortizes round trips the same way
    /// [`Store::put_many`] does.
    pub fn get_many<T: Decode>(&self, keys: &[String]) -> Result<Vec<Option<T>>> {
        self.inner.gets.fetch_add(keys.len() as u64, Ordering::Relaxed);
        let blobs = self.inner.connector.get_many(keys)?;
        let mut out = Vec::with_capacity(blobs.len());
        for blob in blobs {
            match blob {
                Some(bytes) => {
                    self.inner
                        .get_bytes
                        .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    out.push(Some(T::from_bytes(&bytes)?));
                }
                None => out.push(None),
            }
        }
        Ok(out)
    }

    /// Mint lazy proxies for a whole batch with one batched put (the
    /// producer-side analogue of [`crate::proxy::prefetch`]).
    pub fn proxy_many<T: Encode>(&self, objs: &[T]) -> Result<Vec<Proxy<T>>> {
        let keys = self.put_many(objs)?;
        Ok(keys
            .iter()
            .map(|k| Proxy::from_factory(self.factory_for(k, false, 0)))
            .collect())
    }

    pub fn exists(&self, key: &str) -> Result<bool> {
        self.inner.connector.exists(key)
    }

    pub fn evict(&self, key: &str) -> Result<()> {
        self.inner.evicts.fetch_add(1, Ordering::Relaxed);
        // Keep same-process semantics intuitive: an evicted key is gone.
        crate::proxy::cache::global()
            .invalidate(&self.inner.connector.desc().to_bytes(), key);
        self.inner.connector.evict(key)
    }

    /// Batched eviction: one connector `delete_many` (native MDEL on wire
    /// channels, parallel per-shard sweep on the fabric) instead of a
    /// round trip per key. Proxy caches are invalidated like `evict`.
    pub fn evict_many(&self, keys: &[String]) -> Result<()> {
        self.inner
            .evicts
            .fetch_add(keys.len() as u64, Ordering::Relaxed);
        let desc = self.inner.connector.desc().to_bytes();
        for key in keys {
            crate::proxy::cache::global().invalidate(&desc, key);
        }
        self.inner.connector.delete_many(keys)
    }

    /// Factory metadata for a key in this store.
    pub fn factory_for(&self, key: &str, wait: bool, timeout_ms: u64) -> Factory {
        Factory {
            desc: self.inner.connector.desc(),
            key: key.to_string(),
            wait,
            timeout_ms,
            store_name: self.inner.name.clone(),
        }
    }

    /// Create a lazy transparent proxy of `obj` (paper: `Store.proxy(t)`):
    /// serialize, put, wrap the factory.
    pub fn proxy<T: Encode>(&self, obj: &T) -> Result<Proxy<T>> {
        let key = self.put(obj)?;
        Ok(Proxy::from_factory(self.factory_for(&key, false, 0)))
    }

    /// Proxy an already-stored key.
    pub fn proxy_from_key<T>(&self, key: &str) -> Proxy<T> {
        Proxy::from_factory(self.factory_for(key, false, 0))
    }

    /// Create a distributed future bound to this store (paper Sec IV-A:
    /// `Store.future()`).
    pub fn future<T>(&self) -> ProxyFuture<T> {
        let key = format!("future-{}", self.new_key());
        ProxyFuture::new(self.factory_for(&key, true, 0))
    }

    /// Counter snapshot.
    pub fn metrics(&self) -> StoreMetrics {
        StoreMetrics {
            puts: self.inner.puts.load(Ordering::Relaxed),
            gets: self.inner.gets.load(Ordering::Relaxed),
            evicts: self.inner.evicts.load(Ordering::Relaxed),
            put_bytes: self.inner.put_bytes.load(Ordering::Relaxed),
            get_bytes: self.inner.get_bytes.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Debug for Store {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Store")
            .field("name", &self.inner.name)
            .field("connector", &self.inner.connector.desc())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = Store::memory("t");
        let key = s.put(&"value".to_string()).unwrap();
        assert_eq!(s.get::<String>(&key).unwrap(), Some("value".into()));
        assert!(s.exists(&key).unwrap());
        s.evict(&key).unwrap();
        assert_eq!(s.get::<String>(&key).unwrap(), None);
        let m = s.metrics();
        assert_eq!(m.puts, 1);
        assert_eq!(m.gets, 2);
        assert_eq!(m.evicts, 1);
        assert!(m.put_bytes > 0);
    }

    #[test]
    fn batched_ops_roundtrip_and_count_metrics() {
        let s = Store::memory("t-batch");
        let objs: Vec<String> =
            (0..10).map(|i| format!("value-{i}")).collect();
        let keys = s.put_many(&objs).unwrap();
        assert_eq!(keys.len(), 10);
        let got: Vec<Option<String>> = s.get_many(&keys).unwrap();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.as_deref(), Some(format!("value-{i}").as_str()));
        }
        // Partial miss alignment.
        let mixed = vec![keys[0].clone(), "absent".to_string(), keys[9].clone()];
        let got: Vec<Option<String>> = s.get_many(&mixed).unwrap();
        assert!(got[0].is_some() && got[1].is_none() && got[2].is_some());
        // Empty batches.
        assert!(s.put_many::<String>(&[]).unwrap().is_empty());
        assert!(s.get_many::<String>(&[]).unwrap().is_empty());

        // Metrics must not undercount fabric traffic: batched ops add per
        // key and per byte, exactly like the single-key path.
        let m = s.metrics();
        assert_eq!(m.puts, 10);
        assert_eq!(m.gets, 13);
        let per_obj = objs[0].to_bytes().len() as u64;
        assert_eq!(m.put_bytes, 10 * per_obj);
        assert_eq!(m.get_bytes, 12 * per_obj);
    }

    #[test]
    fn proxy_many_mints_resolvable_proxies() {
        let s = Store::memory("t-proxy-many");
        let objs: Vec<u64> = (0..5).map(|i| i * 11).collect();
        let proxies = s.proxy_many(&objs).unwrap();
        assert_eq!(proxies.len(), 5);
        for (i, p) in proxies.iter().enumerate() {
            assert!(!p.is_resolved());
            assert_eq!(*p.resolve().unwrap(), i as u64 * 11);
        }
    }

    #[test]
    fn keys_are_unique() {
        let s = Store::memory("t");
        let mut keys = std::collections::HashSet::new();
        for _ in 0..1000 {
            assert!(keys.insert(s.new_key()));
        }
    }

    #[test]
    fn two_stores_share_one_channel() {
        let conn = MemoryConnector::new();
        let a = Store::new("a", conn.clone());
        let b = Store::new("b", conn);
        let key = a.put(&9u32).unwrap();
        assert_eq!(b.get::<u32>(&key).unwrap(), Some(9));
    }

    #[test]
    fn typed_decode_error_surfaces() {
        let s = Store::memory("t");
        let key = s.put(&"text".to_string()).unwrap();
        // Decoding a string as u64 must fail loudly, not garbage.
        assert!(s.get::<u64>(&key).is_err());
    }
}
