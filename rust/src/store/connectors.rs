//! Connector implementations: the mediated communication channels.
//!
//! A [`Connector`] is the low-level interface to a mediated channel (the
//! paper's Redis/file-system/Globus analogues). Connectors move raw bytes;
//! typed semantics live in [`crate::proxy`]. Every connector is fully
//! described by a [`ConnectorDesc`], which is what proxy factories carry so
//! that a proxy is self-contained: resolution can reconstruct the channel
//! from the descriptor alone (no ambient state required).

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::codec::{Buf, Bytes, Decode, Encode, Reader, get_varint, put_varint};
use crate::error::{Error, Result};
use crate::kv::{ClientOptions, KvClient, KvState};
use crate::metrics::{StoreBytes, TelemetrySnapshot};
use crate::netsim::Link;
use crate::ops::{Op, OpResult, Pending};

/// Shared immutable blob returned by connector reads. Connectors that can
/// share their internal allocation (memory) return it refcounted; others
/// wrap the freshly read buffer. Either way, resolution decodes straight
/// out of the blob with no intermediate copy.
pub type Blob = Arc<Vec<u8>>;

/// Low-level interface to a mediated channel.
pub trait Connector: Send + Sync {
    /// Self-describing configuration for factories.
    fn desc(&self) -> ConnectorDesc;

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()>;

    fn get(&self, key: &str) -> Result<Option<Blob>>;

    /// Zero-copy read: a [`Buf`] window over whatever allocation the
    /// channel already holds — the memory engine's stored buffer, the
    /// TCP client's response frame. The default flattens
    /// [`Connector::get`]'s blob into a full-window `Buf` (one refcount
    /// bump, no byte copy), so every connector has a view path.
    fn get_view(&self, key: &str) -> Result<Option<Buf>> {
        Ok(self.get(key)?.map(Buf::from_arc))
    }

    /// Store only if absent; returns whether *this* call stored it — the
    /// single-assignment primitive ProxyFutures' `set_result` rides. The
    /// default is an exists+put bridge, which is inherently racy (two
    /// concurrent callers can both observe absence and both "win"): it
    /// exists so dumb channels keep working. Channels with a native
    /// conditional write override it — the memory engine and TCP KV use
    /// the atomic `SetNx`, the shard fabrics route to the key's primary
    /// so one backend is the linearization point.
    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        if self.exists(key)? {
            return Ok(false);
        }
        self.put(key, data)?;
        Ok(true)
    }

    /// Arm an out-of-band watch: the returned handle completes with the
    /// value as soon as the key exists (immediately if it already does).
    /// This is the event plane every blocking rendezvous rides —
    /// [`Connector::wait_get`], ProxyFutures resolution, `when_all`/
    /// `when_any` fan-ins — so a parked waiter costs no connection and no
    /// poll tick on channels with a native implementation (memory
    /// registry callbacks, TCP `Notify` pushes, sharded/elastic replica
    /// arms).
    ///
    /// The default is a *poll bridge* on a dedicated thread (never a
    /// reactor worker: the pool's contract is short-lived jobs), so every
    /// connector is a valid watch endpoint. The poller reconnects through
    /// [`Connector::desc`] and stops as soon as its handle is dropped
    /// unobserved, so abandoned watches don't poll forever.
    fn watch(&self, key: &str) -> Pending<Blob> {
        let desc = self.desc();
        let key = key.to_string();
        let (completer, handle) = crate::ops::pending();
        // A failed spawn drops the completer, which fails the handle —
        // no waiter is ever stranded.
        let _ = std::thread::Builder::new().name("watch-poll".into()).spawn(
            move || {
                let conn = match desc.connect() {
                    Ok(c) => c,
                    Err(e) => return completer.complete(Err(e)),
                };
                let mut backoff = Duration::from_micros(50);
                loop {
                    match conn.get(&key) {
                        Ok(Some(v)) => return completer.complete(Ok(v)),
                        Ok(None) => {}
                        Err(e) => return completer.complete(Err(e)),
                    }
                    if completer.abandoned() {
                        return; // nobody can observe a completion anymore
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(10));
                }
            },
        );
        handle
    }

    /// Blocking get with timeout (`None` = forever): arm a watch, park on
    /// the handle. Every connector's blocking rendezvous therefore rides
    /// its best available watch plane — server push where there is one,
    /// the poll bridge where there isn't. A synchronous probe first keeps
    /// already-present keys immediate even against a tiny timeout.
    fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Blob>> {
        if let Some(v) = self.get(key)? {
            return Ok(Some(v));
        }
        let handle = self.watch(key);
        match timeout {
            None => handle.wait().map(Some),
            Some(t) => handle.wait_timeout(t),
        }
    }

    fn evict(&self, key: &str) -> Result<()>;

    fn exists(&self, key: &str) -> Result<bool>;

    /// Batched put. The default loops over [`Connector::put`]; channels
    /// with a wire protocol (TCP KV) or a lock to amortize (memory)
    /// override it so the whole batch pays one round trip.
    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        for (key, data) in items {
            self.put(&key, data)?;
        }
        Ok(())
    }

    /// Batched get, positionally aligned with `keys` (`None` = miss). The
    /// default loops over [`Connector::get`]; see [`Connector::put_many`].
    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        keys.iter().map(|k| self.get(k)).collect()
    }

    /// Batched eviction (idempotent, like [`Connector::evict`]). The
    /// default loops; channels with a native `MDEL` (memory, TCP KV)
    /// override it so a whole eviction sweep — ownership lifetimes
    /// releasing every attached object at once — pays one round trip.
    /// Best-effort: every key gets its own evict attempt even when an
    /// earlier one fails (the last error is reported), matching the
    /// per-key eviction loops this replaces.
    fn delete_many(&self, keys: &[String]) -> Result<()> {
        let mut last_err = None;
        for key in keys {
            if let Err(e) = self.evict(key) {
                last_err = Some(e);
            }
        }
        match last_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Batched existence check, positionally aligned with `keys`. The
    /// default loops over [`Connector::exists`]; channels with a native
    /// `MEXISTS` (memory, TCP KV) answer the whole probe in one round
    /// trip, and the shard fabric fans it out per shard in parallel.
    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        keys.iter().map(|k| self.exists(k)).collect()
    }

    /// Enumerate every resident key (admin / rebalancing). The elastic
    /// shard fabric uses this to compute the remapped key delta when the
    /// shard set changes. Channels that cannot enumerate keep the default
    /// error.
    fn list_keys(&self) -> Result<Vec<String>> {
        Err(Error::Config(
            "connector cannot enumerate keys".into(),
        ))
    }

    /// Nonblocking op submission: hand the channel a typed [`Op`] and get
    /// a completion handle back. The default is a *blocking bridge* — the
    /// op executes on the calling thread through the blocking methods
    /// above and the returned handle is already complete — which makes
    /// every existing connector a valid submission endpoint. Channels
    /// with a native pipeline override it: the TCP KV connector puts the
    /// request on its shared socket and a reader thread completes the
    /// handle, so N in-flight ops share one round-trip stream.
    /// Schedulers consult [`Connector::submits_nonblocking`] to tell the
    /// two contracts apart. `Watch` ops are the exception to the bridge:
    /// they may park indefinitely, so every channel routes them through
    /// its watch plane instead of executing them inline.
    fn submit(&self, op: Op) -> Pending<OpResult> {
        if let Op::Watch { key } = op {
            return crate::ops::watch_result(self.watch(&key));
        }
        Pending::ready(crate::ops::execute(self, op))
    }

    /// Whether [`Connector::submit`] returns before the op completes
    /// (native pipeline) rather than bridging through the blocking
    /// methods. Drives scheduling in
    /// [`fan_out_ops`](crate::ops::reactor::fan_out_ops): nonblocking
    /// submitters keep their in-flight ops on the wire; blocking bridges
    /// are driven by a shared reactor worker.
    fn submits_nonblocking(&self) -> bool {
        false
    }

    /// Number of objects currently resident (the Fig 10 "active proxies"
    /// measurement).
    fn len(&self) -> Result<usize>;

    /// Store-resident byte gauge, when the channel can report one.
    fn gauge(&self) -> Option<Arc<StoreBytes>> {
        None
    }

    /// Fetch the remote endpoint's telemetry snapshot, when the channel
    /// fronts a server that can report one (the `Telemetry` wire op). The
    /// default is `None`: in-process channels share *this* process's
    /// registry, so there is nothing remote to scrape. Cluster
    /// aggregation ([`crate::metrics::cluster`]) fans this across every
    /// fabric member.
    fn scrape_telemetry(&self) -> Result<Option<TelemetrySnapshot>> {
        Ok(None)
    }
}

/// Serializable connector configuration.
#[derive(Debug, Clone, PartialEq)]
pub enum ConnectorDesc {
    /// In-process shared memory, identified by a registry id.
    Memory { id: String },
    /// Shared-filesystem directory.
    File { dir: String },
    /// redis-sim server endpoint (default client options).
    TcpKv { addr: String },
    /// redis-sim server endpoint with explicit wire tuning
    /// ([`ClientOptions`]): pipeline window, flush policy, timeouts. A
    /// proxy minted against a tuned connector round-trips the tuning.
    TcpKvWith { addr: String, options: ClientOptions },
    /// A throttled view over another channel (latency us, bandwidth B/s).
    Throttled {
        inner: Box<ConnectorDesc>,
        latency_us: u64,
        bandwidth: f64,
    },
    /// Size-policy routing: objects up to `threshold` bytes go to `small`,
    /// larger ones to `large` (the paper's multi-connector deployments:
    /// e.g. Redis for small hot objects, a file system for bulk).
    Multi {
        small: Box<ConnectorDesc>,
        large: Box<ConnectorDesc>,
        threshold: u64,
    },
    /// Consistent-hash shard fabric: keys route to `shards` via a virtual-
    /// node hash ring, each key replicated on `replicas` distinct shards
    /// (see [`crate::shard`]).
    Sharded {
        shards: Vec<ConnectorDesc>,
        replicas: u64,
        vnodes: u64,
    },
    /// Elastic shard fabric (see [`crate::shard::rebalance`]): a shard
    /// fabric whose membership can change at runtime. The descriptor is a
    /// generation-stamped snapshot — `shard_ids[i]` is the stable ring id
    /// of `shards[i]` at generation `generation`. Connecting prefers the
    /// live control plane registered under `name` in this process, so a
    /// proxy minted before a rebalance resolves against the *current*
    /// membership rather than its stale snapshot.
    Elastic {
        name: String,
        generation: u64,
        shard_ids: Vec<u64>,
        shards: Vec<ConnectorDesc>,
        replicas: u64,
        vnodes: u64,
    },
}

impl Encode for ConnectorDesc {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            ConnectorDesc::Memory { id } => {
                put_varint(buf, 0);
                id.encode(buf);
            }
            ConnectorDesc::File { dir } => {
                put_varint(buf, 1);
                dir.encode(buf);
            }
            ConnectorDesc::TcpKv { addr } => {
                put_varint(buf, 2);
                addr.encode(buf);
            }
            ConnectorDesc::Throttled { inner, latency_us, bandwidth } => {
                put_varint(buf, 3);
                inner.encode(buf);
                latency_us.encode(buf);
                bandwidth.encode(buf);
            }
            ConnectorDesc::Multi { small, large, threshold } => {
                put_varint(buf, 4);
                small.encode(buf);
                large.encode(buf);
                threshold.encode(buf);
            }
            ConnectorDesc::Sharded { shards, replicas, vnodes } => {
                put_varint(buf, 5);
                shards.encode(buf);
                replicas.encode(buf);
                vnodes.encode(buf);
            }
            ConnectorDesc::Elastic {
                name,
                generation,
                shard_ids,
                shards,
                replicas,
                vnodes,
            } => {
                put_varint(buf, 6);
                name.encode(buf);
                generation.encode(buf);
                shard_ids.encode(buf);
                shards.encode(buf);
                replicas.encode(buf);
                vnodes.encode(buf);
            }
            ConnectorDesc::TcpKvWith { addr, options } => {
                put_varint(buf, 7);
                addr.encode(buf);
                options.encode(buf);
            }
        }
    }
}

impl Decode for ConnectorDesc {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => ConnectorDesc::Memory { id: Decode::decode(r)? },
            1 => ConnectorDesc::File { dir: Decode::decode(r)? },
            2 => ConnectorDesc::TcpKv { addr: Decode::decode(r)? },
            3 => ConnectorDesc::Throttled {
                inner: Box::new(Decode::decode(r)?),
                latency_us: Decode::decode(r)?,
                bandwidth: Decode::decode(r)?,
            },
            4 => ConnectorDesc::Multi {
                small: Box::new(Decode::decode(r)?),
                large: Box::new(Decode::decode(r)?),
                threshold: Decode::decode(r)?,
            },
            5 => ConnectorDesc::Sharded {
                shards: Decode::decode(r)?,
                replicas: Decode::decode(r)?,
                vnodes: Decode::decode(r)?,
            },
            6 => ConnectorDesc::Elastic {
                name: Decode::decode(r)?,
                generation: Decode::decode(r)?,
                shard_ids: Decode::decode(r)?,
                shards: Decode::decode(r)?,
                replicas: Decode::decode(r)?,
                vnodes: Decode::decode(r)?,
            },
            7 => ConnectorDesc::TcpKvWith {
                addr: Decode::decode(r)?,
                options: Decode::decode(r)?,
            },
            t => return Err(Error::Codec(format!("bad connector tag {t}"))),
        })
    }
}

impl ConnectorDesc {
    /// Reconstruct a connector from its description (the self-contained
    /// resolution path used when a proxy crosses process boundaries).
    pub fn connect(&self) -> Result<Arc<dyn Connector>> {
        match self {
            ConnectorDesc::Memory { id } => MemoryConnector::named(id),
            ConnectorDesc::File { dir } => {
                Ok(Arc::new(FileConnector::new(PathBuf::from(dir))?))
            }
            ConnectorDesc::TcpKv { addr } => {
                let addr: SocketAddr = addr.parse().map_err(|e| {
                    Error::Config(format!("bad kv addr {addr}: {e}"))
                })?;
                Ok(Arc::new(TcpKvConnector::connect(addr)?))
            }
            ConnectorDesc::TcpKvWith { addr, options } => {
                let addr: SocketAddr = addr.parse().map_err(|e| {
                    Error::Config(format!("bad kv addr {addr}: {e}"))
                })?;
                Ok(Arc::new(TcpKvConnector::connect_with(addr, *options)?))
            }
            ConnectorDesc::Throttled { inner, latency_us, bandwidth } => {
                Ok(Arc::new(ThrottledConnector::new(
                    inner.connect()?,
                    Link::new(Duration::from_micros(*latency_us), *bandwidth)
                        .uncontended(),
                    *latency_us,
                    *bandwidth,
                )))
            }
            ConnectorDesc::Multi { small, large, threshold } => {
                Ok(Arc::new(MultiConnector::new(
                    small.connect()?,
                    large.connect()?,
                    *threshold as usize,
                )))
            }
            ConnectorDesc::Sharded { shards, replicas, vnodes } => {
                let backends = shards
                    .iter()
                    .map(|d| d.connect())
                    .collect::<Result<Vec<_>>>()?;
                Ok(Arc::new(crate::shard::ShardedConnector::new(
                    backends,
                    *replicas as usize,
                    *vnodes as usize,
                )?))
            }
            ConnectorDesc::Elastic { .. } => {
                crate::shard::rebalance::connect_elastic(self)
            }
        }
    }
}

// --------------------------------------------------------------------------
// Memory connector: in-process engine with a global id registry so
// descriptors round-trip within one address space (our "cluster").
// --------------------------------------------------------------------------

/// In-process connector backed by the redis-sim storage engine.
pub struct MemoryConnector {
    id: String,
    state: KvState,
}

fn memory_registry(
) -> &'static std::sync::Mutex<std::collections::HashMap<String, KvState>> {
    static REG: std::sync::OnceLock<
        std::sync::Mutex<std::collections::HashMap<String, KvState>>,
    > = std::sync::OnceLock::new();
    REG.get_or_init(Default::default)
}

impl MemoryConnector {
    /// Create or attach to the in-process channel with this id.
    pub fn named(id: &str) -> Result<Arc<dyn Connector>> {
        let mut reg = memory_registry().lock().unwrap();
        let state = reg.entry(id.to_string()).or_insert_with(KvState::new);
        Ok(Arc::new(MemoryConnector {
            id: id.to_string(),
            state: state.clone(),
        }))
    }

    /// Fresh anonymous channel (unique id).
    pub fn new() -> Arc<dyn Connector> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let id = format!(
            "mem-{}-{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        );
        Self::named(&id).expect("memory connector")
    }

    /// The underlying engine (tests / gauges).
    pub fn state(&self) -> &KvState {
        &self.state
    }
}

impl Connector for MemoryConnector {
    fn desc(&self) -> ConnectorDesc {
        ConnectorDesc::Memory { id: self.id.clone() }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.state.set(key, Bytes(data));
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        Ok(self.state.get_shared(key))
    }

    fn get_view(&self, key: &str) -> Result<Option<Buf>> {
        // The engine stores full-window `Buf`s, so this is the stored
        // allocation itself — a refcount bump, never a copy.
        Ok(self.state.get_buf(key))
    }

    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        // Native conditional write: atomic under the engine lock.
        Ok(self.state.set_nx(key, Bytes(data)))
    }

    fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Blob>> {
        Ok(self.state.wait_get_shared(key, timeout))
    }

    /// Native watch: a registry callback completes the handle straight
    /// from the writer's thread — zero threads, zero polling, and the
    /// blob shares the engine's allocation.
    fn watch(&self, key: &str) -> Pending<Blob> {
        let (completer, handle) = crate::ops::pending();
        self.state
            .watch(key, Box::new(move |v| completer.complete(Ok(v))));
        handle
    }

    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        self.state
            .mset(items.into_iter().map(|(k, v)| (k, Bytes(v))).collect());
        Ok(())
    }

    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        Ok(self.state.mget_shared(keys))
    }

    fn delete_many(&self, keys: &[String]) -> Result<()> {
        self.state.mdel(keys);
        Ok(())
    }

    fn evict(&self, key: &str) -> Result<()> {
        self.state.del(key);
        Ok(())
    }

    fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.state.exists(key))
    }

    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        Ok(self.state.mexists(keys))
    }

    // The default `submit` blocking bridge *is* the native path here:
    // every op executes inline against the in-process engine (through the
    // overridden blocking methods above) and the handle is complete at
    // return — within one address space there is no round trip to
    // overlap. `submits_nonblocking` stays false on purpose, so the shard
    // fabric still fans memory-backed sub-batches out across pool workers
    // instead of serializing them on the submitter.

    fn list_keys(&self) -> Result<Vec<String>> {
        Ok(self.state.keys(""))
    }

    fn len(&self) -> Result<usize> {
        Ok(self.state.stats().0 as usize)
    }

    fn gauge(&self) -> Option<Arc<StoreBytes>> {
        Some(self.state.gauge.clone())
    }
}

// --------------------------------------------------------------------------
// File connector: shared-filesystem mediated channel (the paper's
// Lustre/NFS deployments). Writes are tempfile+rename for atomicity.
// --------------------------------------------------------------------------

/// Filesystem-backed connector.
pub struct FileConnector {
    dir: PathBuf,
    gauge: Arc<StoreBytes>,
}

impl FileConnector {
    pub fn new(dir: PathBuf) -> Result<FileConnector> {
        std::fs::create_dir_all(&dir)?;
        Ok(FileConnector {
            dir,
            gauge: StoreBytes::new(),
        })
    }

    fn path(&self, key: &str) -> PathBuf {
        // Keys are generated by Store (uuid-ish), never user paths; keep a
        // defensive filter anyway.
        let safe: String = key
            .chars()
            .map(|c| if c.is_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
            .collect();
        self.dir.join(safe)
    }
}

impl Connector for FileConnector {
    fn desc(&self) -> ConnectorDesc {
        ConnectorDesc::File { dir: self.dir.to_string_lossy().into_owned() }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        let path = self.path(key);
        let tmp = path.with_extension("tmp");
        self.gauge.add(data.len());
        std::fs::write(&tmp, &data)?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        match std::fs::read(self.path(key)) {
            Ok(v) => Ok(Some(Arc::new(v))),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    fn evict(&self, key: &str) -> Result<()> {
        let path = self.path(key);
        if let Ok(meta) = std::fs::metadata(&path) {
            self.gauge.sub(meta.len() as usize);
        }
        match std::fs::remove_file(path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }

    fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.path(key).exists())
    }

    fn list_keys(&self) -> Result<Vec<String>> {
        // Filenames ARE the (sanitized) keys; store-generated keys contain
        // only filename-safe characters, so they round-trip unchanged.
        Ok(std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().map(|x| x != "tmp").unwrap_or(true)
            })
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect())
    }

    fn len(&self) -> Result<usize> {
        Ok(std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().map(|x| x != "tmp").unwrap_or(true)
            })
            .count())
    }

    fn gauge(&self) -> Option<Arc<StoreBytes>> {
        Some(self.gauge.clone())
    }
}

// --------------------------------------------------------------------------
// TCP KV connector: the Redis-deployment analogue.
// --------------------------------------------------------------------------

/// Connector speaking to a redis-sim [`crate::kv::KvServer`].
pub struct TcpKvConnector {
    addr: SocketAddr,
    options: ClientOptions,
    client: KvClient,
}

impl TcpKvConnector {
    /// Connect with default wire options.
    pub fn connect(addr: SocketAddr) -> Result<TcpKvConnector> {
        TcpKvConnector::connect_with(addr, ClientOptions::default())
    }

    /// Connect with explicit wire tuning ([`ClientOptions`]); the options
    /// travel inside this connector's descriptor, so proxies resolved
    /// elsewhere reconnect with the same tuning.
    pub fn connect_with(
        addr: SocketAddr,
        options: ClientOptions,
    ) -> Result<TcpKvConnector> {
        Ok(TcpKvConnector {
            addr,
            options,
            client: KvClient::connect_with(addr, options)?,
        })
    }
}

impl Connector for TcpKvConnector {
    fn desc(&self) -> ConnectorDesc {
        // Default options keep the compact legacy descriptor (and its wire
        // encoding) so tuned and untuned connectors interoperate.
        if self.options == ClientOptions::default() {
            ConnectorDesc::TcpKv { addr: self.addr.to_string() }
        } else {
            ConnectorDesc::TcpKvWith {
                addr: self.addr.to_string(),
                options: self.options,
            }
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.client.set(key, Bytes(data))
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        Ok(self.client.get_view(key)?.map(|b| b.into_blob()))
    }

    fn get_view(&self, key: &str) -> Result<Option<Buf>> {
        // The view IS the response frame's allocation: the value crosses
        // the socket into one buffer and is never copied again.
        self.client.get_view(key)
    }

    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        // Native conditional write: the server's SetNx is the atomic
        // linearization point.
        self.client.set_nx(key, Bytes(data))
    }

    fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Blob>> {
        // Rides the watch plane on the *shared* pipelined connection: the
        // wait parks client-side on an out-of-band Notify, so it neither
        // needs a dedicated connection nor stalls in-flight traffic.
        Ok(self.client.wait_get(key, timeout)?.map(|b| Arc::new(b.0)))
    }

    /// Native watch: one `Watch` frame on the shared pipelined
    /// connection; the client's reader thread completes the handle from
    /// the out-of-band `Notify` push.
    fn watch(&self, key: &str) -> Pending<Blob> {
        self.client.watch(key)
    }

    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        // Native MPUT: the whole batch crosses the wire in one frame.
        self.client
            .mput(items.into_iter().map(|(k, v)| (k, Bytes(v))).collect())
    }

    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        // Native MGET: one round trip regardless of batch size.
        Ok(self
            .client
            .mget_view(keys)?
            .into_iter()
            .map(|o| o.map(|b| b.into_blob()))
            .collect())
    }

    fn delete_many(&self, keys: &[String]) -> Result<()> {
        // Native MDEL: the whole eviction sweep crosses the wire once.
        self.client.mdel(keys)?;
        Ok(())
    }

    fn evict(&self, key: &str) -> Result<()> {
        self.client.del(key)?;
        Ok(())
    }

    fn exists(&self, key: &str) -> Result<bool> {
        self.client.exists(key)
    }

    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        // Native MEXISTS: the whole membership probe crosses the wire once.
        self.client.mexists(keys)
    }

    /// Native submission: the op goes onto the pipelined connection and
    /// the handle completes from the client's reader thread. N in-flight
    /// ops share one round-trip stream — the wire half of the paper's
    /// overlapped-resolution pattern.
    fn submit(&self, op: Op) -> Pending<OpResult> {
        self.client.submit_op(op)
    }

    fn submits_nonblocking(&self) -> bool {
        true
    }

    fn list_keys(&self) -> Result<Vec<String>> {
        self.client.keys("")
    }

    fn len(&self) -> Result<usize> {
        Ok(self.client.stats()?.0 as usize)
    }

    fn scrape_telemetry(&self) -> Result<Option<TelemetrySnapshot>> {
        Ok(Some(self.client.telemetry()?))
    }
}

// --------------------------------------------------------------------------
// Throttled connector: netsim-shaped view over another channel.
// --------------------------------------------------------------------------

/// Wraps a connector with simulated latency/bandwidth per operation.
///
/// State lives behind an inner `Arc` (sharing the link's contention
/// clock) so the submission path can hand it to a dedicated completer
/// thread: simulated wire time is *slept out*, and sleeps must never
/// park the shared reactor pool's workers — see
/// [`Connector::submits_nonblocking`].
pub struct ThrottledConnector {
    shared: Arc<ThrottledShared>,
}

struct ThrottledShared {
    inner: Arc<dyn Connector>,
    link: Link,
    latency_us: u64,
    bandwidth: f64,
}

impl ThrottledConnector {
    pub fn new(
        inner: Arc<dyn Connector>,
        link: Link,
        latency_us: u64,
        bandwidth: f64,
    ) -> ThrottledConnector {
        ThrottledConnector {
            shared: Arc::new(ThrottledShared {
                inner,
                link,
                latency_us,
                bandwidth,
            }),
        }
    }

    /// Convenience: wrap with an uncontended link profile.
    pub fn wrap(
        inner: Arc<dyn Connector>,
        latency: Duration,
        bandwidth: f64,
    ) -> Arc<dyn Connector> {
        Arc::new(ThrottledConnector::new(
            inner,
            Link::new(latency, bandwidth).uncontended(),
            latency.as_micros() as u64,
            bandwidth,
        ))
    }
}

impl Connector for ThrottledConnector {
    fn desc(&self) -> ConnectorDesc {
        ConnectorDesc::Throttled {
            inner: Box::new(self.shared.inner.desc()),
            latency_us: self.shared.latency_us,
            bandwidth: self.shared.bandwidth,
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.shared.link.transfer(data.len());
        self.shared.inner.put(key, data)
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        let v = self.shared.inner.get(key)?;
        self.shared.link.transfer(v.as_ref().map(|v| v.len()).unwrap_or(0));
        Ok(v)
    }

    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        self.shared.link.transfer(data.len());
        self.shared.inner.put_nx(key, data)
    }

    fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Blob>> {
        let v = self.shared.inner.wait_get(key, timeout)?;
        self.shared.link.transfer(v.as_ref().map(|v| v.len()).unwrap_or(0));
        Ok(v)
    }

    /// Watch through the inner channel, paying the simulated wire time
    /// when the value arrives. The link sleep happens on a dedicated
    /// bridge thread — watch callbacks run on writers' threads and must
    /// never be slept on — which also parks on the inner handle in
    /// slices, so an abandoned watch reaps the bridge instead of leaking
    /// it forever.
    fn watch(&self, key: &str) -> Pending<Blob> {
        let inner = self.shared.inner.watch(key);
        let shared = self.shared.clone();
        let (completer, handle) = crate::ops::pending();
        let _ = std::thread::Builder::new()
            .name("throttled-watch".into())
            .spawn(move || loop {
                match inner.wait_timeout(Duration::from_millis(100)) {
                    Ok(Some(v)) => {
                        shared.link.transfer(v.len());
                        return completer.complete(Ok(v));
                    }
                    Ok(None) => {
                        if completer.abandoned() {
                            return;
                        }
                    }
                    Err(e) => return completer.complete(Err(e)),
                }
            });
        handle
    }

    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        // Pipelined semantics: one latency for the whole batch, wire time
        // for the aggregate bytes (vs per-key latency in the default loop).
        let total: usize = items.iter().map(|(_, v)| v.len()).sum();
        self.shared.link.transfer(total);
        self.shared.inner.put_many(items)
    }

    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        let out = self.shared.inner.get_many(keys)?;
        let total: usize =
            out.iter().map(|b| b.as_ref().map(|v| v.len()).unwrap_or(0)).sum();
        self.shared.link.transfer(total);
        Ok(out)
    }

    fn delete_many(&self, keys: &[String]) -> Result<()> {
        // One latency for the whole sweep (deletes carry no payload).
        self.shared.link.transfer(0);
        self.shared.inner.delete_many(keys)
    }

    fn evict(&self, key: &str) -> Result<()> {
        self.shared.inner.evict(key)
    }

    fn exists(&self, key: &str) -> Result<bool> {
        self.shared.inner.exists(key)
    }

    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        // One latency for the whole probe (existence carries no payload).
        self.shared.link.transfer(0);
        self.shared.inner.exists_many(keys)
    }

    fn list_keys(&self) -> Result<Vec<String>> {
        self.shared.link.transfer(0);
        self.shared.inner.list_keys()
    }

    fn len(&self) -> Result<usize> {
        self.shared.inner.len()
    }

    /// Simulated wire time is slept out in flight on a dedicated
    /// completer thread (sharing the link's contention clock), never on
    /// a shared reactor worker — the pool's contract is short-lived jobs
    /// only, and a netsim-shaped WAN sleep is anything but. This also
    /// preserves the unbounded per-op parallelism the scoped-thread
    /// fan-outs used to give throttled backends in the benches. Watches
    /// route through the watch plane (they may park indefinitely).
    fn submit(&self, op: Op) -> Pending<OpResult> {
        if let Op::Watch { key } = op {
            return crate::ops::watch_result(self.watch(&key));
        }
        let (completer, handle) = crate::ops::pending();
        let clone = ThrottledConnector { shared: self.shared.clone() };
        std::thread::Builder::new()
            .name("throttled-op".into())
            .spawn(move || {
                completer.complete(crate::ops::execute(&clone, op));
            })
            .expect("spawn throttled op thread");
        handle
    }

    fn submits_nonblocking(&self) -> bool {
        true
    }

    fn gauge(&self) -> Option<Arc<StoreBytes>> {
        self.shared.inner.gauge()
    }

    fn scrape_telemetry(&self) -> Result<Option<TelemetrySnapshot>> {
        self.shared.inner.scrape_telemetry()
    }
}

// --------------------------------------------------------------------------
// Multi connector: route by object size (paper's per-deployment policies).
// --------------------------------------------------------------------------

/// Routes small objects to one channel and bulk objects to another.
///
/// `get`/`exists`/`evict` don't know an object's size, so reads consult
/// the large channel first (bulk objects are the common case for proxies)
/// and fall back to the small one.
pub struct MultiConnector {
    small: Arc<dyn Connector>,
    large: Arc<dyn Connector>,
    threshold: usize,
    /// Serializes conditional writes: two racing `put_nx` callers may
    /// route to *different* size classes, where neither backend alone can
    /// arbitrate — without this, both could observe absence and both win.
    nx_lock: std::sync::Mutex<()>,
}

impl MultiConnector {
    pub fn new(
        small: Arc<dyn Connector>,
        large: Arc<dyn Connector>,
        threshold: usize,
    ) -> MultiConnector {
        MultiConnector {
            small,
            large,
            threshold,
            nx_lock: std::sync::Mutex::new(()),
        }
    }
}

impl Connector for MultiConnector {
    fn desc(&self) -> ConnectorDesc {
        ConnectorDesc::Multi {
            small: Box::new(self.small.desc()),
            large: Box::new(self.large.desc()),
            threshold: self.threshold as u64,
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        if data.len() <= self.threshold {
            self.small.put(key, data)
        } else {
            self.large.put(key, data)
        }
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        if let Some(v) = self.large.get(key)? {
            return Ok(Some(v));
        }
        self.small.get(key)
    }

    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        // Racing producers can route to *different* size classes, where
        // no single backend is the linearization point — serialize the
        // probe+write through this instance instead. (Connector-level
        // caveat: independent MultiConnector instances over the same
        // backends arbitrate only within themselves; the shard fabrics,
        // whose primary IS a shared backend, don't have this limit.)
        let _guard = self.nx_lock.lock().unwrap();
        let (target, other) = if data.len() <= self.threshold {
            (&self.small, &self.large)
        } else {
            (&self.large, &self.small)
        };
        if other.exists(key)? {
            return Ok(false);
        }
        target.put_nx(key, data)
    }

    /// Watch both size classes: the object lands on whichever side its
    /// (unknown-in-advance) size routes to, and the first arm to fire
    /// wins.
    fn watch(&self, key: &str) -> Pending<Blob> {
        let (group, handle) = crate::ops::race();
        group.add_all(vec![self.large.watch(key), self.small.watch(key)]);
        handle
    }

    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        let (small, large): (Vec<_>, Vec<_>) = items
            .into_iter()
            .partition(|(_, data)| data.len() <= self.threshold);
        if !small.is_empty() {
            self.small.put_many(small)?;
        }
        if !large.is_empty() {
            self.large.put_many(large)?;
        }
        Ok(())
    }

    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        // Batch the large channel, then batch only the misses to small —
        // same read order as `get`, still two round trips worst case.
        let mut out = self.large.get_many(keys)?;
        let miss_idx: Vec<usize> = out
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.is_none().then_some(i))
            .collect();
        if !miss_idx.is_empty() {
            let miss_keys: Vec<String> =
                miss_idx.iter().map(|&i| keys[i].clone()).collect();
            let filled = self.small.get_many(&miss_keys)?;
            for (&i, blob) in miss_idx.iter().zip(filled) {
                out[i] = blob;
            }
        }
        Ok(out)
    }

    fn delete_many(&self, keys: &[String]) -> Result<()> {
        // Size is unknown at delete time: sweep both channels, best-effort
        // — a dead large channel must not leave small objects resident.
        let large = self.large.delete_many(keys);
        let small = self.small.delete_many(keys);
        large?;
        small
    }

    fn evict(&self, key: &str) -> Result<()> {
        self.large.evict(key)?;
        self.small.evict(key)
    }

    fn exists(&self, key: &str) -> Result<bool> {
        Ok(self.large.exists(key)? || self.small.exists(key)?)
    }

    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        // Same read order as `exists`: batch the large channel, then probe
        // only the still-absent keys against small.
        let mut out = self.large.exists_many(keys)?;
        let miss_idx: Vec<usize> = out
            .iter()
            .enumerate()
            .filter_map(|(i, &hit)| (!hit).then_some(i))
            .collect();
        if !miss_idx.is_empty() {
            let miss_keys: Vec<String> =
                miss_idx.iter().map(|&i| keys[i].clone()).collect();
            let filled = self.small.exists_many(&miss_keys)?;
            for (&i, hit) in miss_idx.iter().zip(filled) {
                out[i] = hit;
            }
        }
        Ok(out)
    }

    fn list_keys(&self) -> Result<Vec<String>> {
        // The size partition is disjoint, so concatenation has no dupes.
        let mut keys = self.large.list_keys()?;
        keys.extend(self.small.list_keys()?);
        Ok(keys)
    }

    fn len(&self) -> Result<usize> {
        Ok(self.large.len()? + self.small.len()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::ServerBuilder;

    fn exercise(c: &dyn Connector) {
        assert!(!c.exists("k").unwrap());
        assert!(c.get("k").unwrap().is_none());
        c.put("k", vec![1, 2, 3]).unwrap();
        assert!(c.exists("k").unwrap());
        assert_eq!(c.get("k").unwrap().map(|b| b.to_vec()), Some(vec![1, 2, 3]));
        c.put("k", vec![9]).unwrap(); // overwrite
        assert_eq!(c.get("k").unwrap().map(|b| b.to_vec()), Some(vec![9]));
        c.evict("k").unwrap();
        assert!(!c.exists("k").unwrap());
        c.evict("k").unwrap(); // idempotent

        // Conditional write: only the first writer wins, loser changes
        // nothing.
        assert!(c.put_nx("nx", vec![1]).unwrap());
        assert!(!c.put_nx("nx", vec![2]).unwrap());
        assert_eq!(c.get("nx").unwrap().map(|b| b.to_vec()), Some(vec![1]));
        c.evict("nx").unwrap();
        assert!(c.put_nx("nx", vec![3]).unwrap()); // evicted key is absent
        c.evict("nx").unwrap();

        // Watch on an existing key completes immediately with the value.
        c.put("w1", vec![5]).unwrap();
        assert_eq!(c.watch("w1").wait().unwrap().to_vec(), vec![5]);
        c.evict("w1").unwrap();

        // Batched ops: empty batches, round trip, positional alignment.
        c.put_many(Vec::new()).unwrap();
        assert_eq!(c.get_many(&[]).unwrap(), Vec::new());
        c.put_many(vec![
            ("b1".into(), vec![1]),
            ("b2".into(), vec![2, 2]),
        ])
        .unwrap();
        let got = c
            .get_many(&["b1".into(), "nope".into(), "b2".into()])
            .unwrap();
        assert_eq!(
            got.iter().map(|b| b.as_ref().map(|v| v.to_vec())).collect::<Vec<_>>(),
            vec![Some(vec![1]), None, Some(vec![2, 2])]
        );
        // Batched existence probe: positional alignment, empty batch.
        assert_eq!(
            c.exists_many(&["b1".into(), "nope".into(), "b2".into()])
                .unwrap(),
            vec![true, false, true]
        );
        assert_eq!(c.exists_many(&[]).unwrap(), Vec::<bool>::new());
        // Key enumeration sees exactly the resident keys.
        let mut listed = c.list_keys().unwrap();
        listed.sort();
        assert_eq!(listed, vec!["b1".to_string(), "b2".to_string()]);
        // Batched eviction: existing and missing keys, idempotent, empty.
        c.put_many(vec![
            ("d1".into(), vec![1]),
            ("d2".into(), vec![2, 2]),
        ])
        .unwrap();
        c.delete_many(&["b1".into(), "d1".into(), "ghost".into()]).unwrap();
        assert!(!c.exists("d1").unwrap());
        assert!(!c.exists("b1").unwrap());
        assert!(c.exists("d2").unwrap());
        c.delete_many(&["d2".into(), "b2".into()]).unwrap();
        assert!(!c.exists("d2").unwrap());
        c.delete_many(&[]).unwrap();

        // Submission API: every channel is a valid submit endpoint
        // (native pipeline or blocking bridge), same semantics either way.
        use crate::ops::Op;
        c.submit(Op::Put { key: "s1".into(), data: vec![7, 7] })
            .wait()
            .unwrap()
            .into_unit()
            .unwrap();
        assert_eq!(
            c.submit(Op::Get { key: "s1".into() })
                .wait()
                .unwrap()
                .into_value()
                .unwrap()
                .map(|b| b.to_vec()),
            Some(vec![7, 7])
        );
        assert!(c
            .submit(Op::Exists { key: "s1".into() })
            .wait()
            .unwrap()
            .into_bool()
            .unwrap());
        c.submit(Op::PutMany {
            items: vec![("s2".into(), vec![1]), ("s3".into(), vec![2])],
        })
        .wait()
        .unwrap()
        .into_unit()
        .unwrap();
        let got = c
            .submit(Op::GetMany {
                keys: vec!["s2".into(), "ghost".into(), "s3".into()],
            })
            .wait()
            .unwrap()
            .into_values()
            .unwrap();
        assert_eq!(
            got.iter().map(|b| b.as_ref().map(|v| v.to_vec())).collect::<Vec<_>>(),
            vec![Some(vec![1]), None, Some(vec![2])]
        );
        assert_eq!(
            c.submit(Op::ExistsMany {
                keys: vec!["s2".into(), "ghost".into()],
            })
            .wait()
            .unwrap()
            .into_bools()
            .unwrap(),
            vec![true, false]
        );
        c.submit(Op::DeleteMany { keys: vec!["s2".into(), "s3".into()] })
            .wait()
            .unwrap()
            .into_unit()
            .unwrap();
        c.submit(Op::Evict { key: "s1".into() })
            .wait()
            .unwrap()
            .into_unit()
            .unwrap();
        assert!(!c.exists("s1").unwrap());
        assert!(!c.exists("s2").unwrap());
    }

    #[test]
    fn memory_connector_semantics() {
        let c = MemoryConnector::new();
        exercise(&*c);
        assert_eq!(c.gauge().unwrap().get(), 0);
    }

    #[test]
    fn memory_desc_roundtrip_shares_state() {
        let c = MemoryConnector::new();
        c.put("shared", vec![7]).unwrap();
        let desc = c.desc();
        let decoded =
            ConnectorDesc::from_bytes(&desc.to_bytes()).unwrap();
        let c2 = decoded.connect().unwrap();
        assert_eq!(c2.get("shared").unwrap().map(|b| b.to_vec()), Some(vec![7]));
    }

    #[test]
    fn file_connector_semantics() {
        let dir = std::env::temp_dir()
            .join(format!("pxs-file-{}", std::process::id()));
        let c = FileConnector::new(dir.clone()).unwrap();
        exercise(&c);
        // Reconnect via desc sees persisted data.
        c.put("persist", vec![5]).unwrap();
        let c2 = c.desc().connect().unwrap();
        assert_eq!(c2.get("persist").unwrap().map(|b| b.to_vec()), Some(vec![5]));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn tcp_kv_connector_semantics() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let c = TcpKvConnector::connect(server.addr).unwrap();
        exercise(&c);
        // wait_get across a second connector.
        let c2 = c.desc().connect().unwrap();
        let h = std::thread::spawn(move || {
            c2.wait_get("later", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        c.put("later", vec![3]).unwrap();
        assert_eq!(h.join().unwrap().map(|b| b.to_vec()), Some(vec![3]));
    }

    #[test]
    fn throttled_adds_wire_time() {
        let c = ThrottledConnector::wrap(
            MemoryConnector::new(),
            Duration::from_millis(5),
            1e9,
        );
        let t0 = std::time::Instant::now();
        c.put("k", vec![0; 1000]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(5));
        let desc = c.desc();
        assert!(matches!(desc, ConnectorDesc::Throttled { .. }));
        let c2 = desc.connect().unwrap();
        assert_eq!(c2.get("k").unwrap().map(|b| b.to_vec()), Some(vec![0; 1000]));
    }

    #[test]
    fn throttled_submit_pays_wire_time_in_flight() {
        let c = ThrottledConnector::wrap(
            MemoryConnector::new(),
            Duration::from_millis(40),
            1e9,
        );
        assert!(c.submits_nonblocking());
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                c.submit(crate::ops::Op::Put {
                    key: format!("t-{i}"),
                    data: vec![1; 10],
                })
            })
            .collect();
        assert!(
            t0.elapsed() < Duration::from_millis(30),
            "submission paid the simulated wire time"
        );
        for h in handles {
            h.wait().unwrap().into_unit().unwrap();
        }
        // 4 x 40ms serialized = 160ms; the uncontended link lets the
        // in-flight ops overlap to ~one latency.
        assert!(
            t0.elapsed() < Duration::from_millis(160),
            "throttled ops serialized"
        );
        assert_eq!(c.len().unwrap(), 4);
    }

    #[test]
    fn default_wait_get_polls() {
        let dir = std::env::temp_dir()
            .join(format!("pxs-poll-{}", std::process::id()));
        let c = Arc::new(FileConnector::new(dir.clone()).unwrap());
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.wait_get("soon", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        c.put("soon", vec![8]).unwrap();
        assert_eq!(h.join().unwrap().map(|b| b.to_vec()), Some(vec![8]));
        assert!(c
            .wait_get("never", Some(Duration::from_millis(30)))
            .unwrap()
            .is_none());
        // The poll-bridge watch behaves like the native ones: wakes on
        // put, and an abandoned handle quietly reaps its poller.
        let armed = c.watch("later");
        assert!(!armed.is_complete());
        c.put("later", vec![9]).unwrap();
        assert_eq!(armed.wait().unwrap().to_vec(), vec![9]);
        drop(c.watch("never-set"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn memory_watch_wakes_without_polling() {
        let c = MemoryConnector::new();
        let handle = c.watch("later");
        assert!(!handle.is_complete());
        c.put("later", vec![1, 2]).unwrap();
        assert_eq!(handle.wait().unwrap().to_vec(), vec![1, 2]);
    }

    #[test]
    fn tuned_tcp_desc_roundtrips_options() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let opts = ClientOptions {
            pipeline_window: 16,
            ..ClientOptions::coalescing()
        };
        let c = TcpKvConnector::connect_with(server.addr, opts).unwrap();
        c.put("tuned", vec![9]).unwrap();
        let desc = c.desc();
        assert!(matches!(desc, ConnectorDesc::TcpKvWith { .. }));
        let decoded = ConnectorDesc::from_bytes(&desc.to_bytes()).unwrap();
        assert_eq!(desc, decoded);
        let c2 = decoded.connect().unwrap();
        assert_eq!(c2.get("tuned").unwrap().map(|b| b.to_vec()), Some(vec![9]));
        // Default options keep the compact legacy descriptor.
        let plain = TcpKvConnector::connect(server.addr).unwrap();
        assert!(matches!(plain.desc(), ConnectorDesc::TcpKv { .. }));
    }

    #[test]
    fn tcp_watch_wakes_across_connectors() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let c = TcpKvConnector::connect(server.addr).unwrap();
        let handle = c.watch("cross");
        // The armed watch shares the pipelined connection: traffic flows.
        c.put("other", vec![1]).unwrap();
        assert!(c.get("other").unwrap().is_some());
        let c2 = c.desc().connect().unwrap();
        c2.put("cross", vec![3, 4]).unwrap();
        assert_eq!(handle.wait().unwrap().to_vec(), vec![3, 4]);
    }

    #[test]
    fn throttled_watch_pays_wire_time_on_delivery() {
        let c = ThrottledConnector::wrap(
            MemoryConnector::new(),
            Duration::from_millis(10),
            1e9,
        );
        let handle = c.watch("w");
        let t0 = std::time::Instant::now();
        c.put("w", vec![0; 100]).unwrap(); // pays one link latency itself
        assert_eq!(handle.wait().unwrap().len(), 100);
        // Put (10ms) + watch delivery (10ms) both crossed the link.
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn multi_watch_fires_from_either_size_class() {
        let multi = Arc::new(MultiConnector::new(
            MemoryConnector::new(),
            MemoryConnector::new(),
            100,
        ));
        let small_side = multi.watch("tiny");
        let large_side = multi.watch("bulk");
        multi.put("tiny", vec![1; 10]).unwrap(); // routes small
        multi.put("bulk", vec![2; 1000]).unwrap(); // routes large
        assert_eq!(small_side.wait().unwrap().len(), 10);
        assert_eq!(large_side.wait().unwrap().len(), 1000);
        // put_nx refuses keys resident on the *other* size class.
        assert!(!multi.put_nx("tiny", vec![3; 5000]).unwrap());
        assert!(!multi.put_nx("bulk", vec![3; 5]).unwrap());
    }

    #[test]
    fn multi_connector_routes_by_size() {
        let small = MemoryConnector::new();
        let large = MemoryConnector::new();
        let multi =
            MultiConnector::new(small.clone(), large.clone(), 1000);
        exercise(&multi);
        multi.put("tiny", vec![1; 10]).unwrap();
        multi.put("bulk", vec![2; 10_000]).unwrap();
        assert!(small.exists("tiny").unwrap());
        assert!(!large.exists("tiny").unwrap());
        assert!(large.exists("bulk").unwrap());
        assert!(!small.exists("bulk").unwrap());
        assert_eq!(multi.len().unwrap(), 2);
        // Reads find both sides.
        assert_eq!(multi.get("tiny").unwrap().unwrap().len(), 10);
        assert_eq!(multi.get("bulk").unwrap().unwrap().len(), 10_000);
    }

    #[test]
    fn multi_connector_desc_roundtrip() {
        let multi = MultiConnector::new(
            MemoryConnector::new(),
            MemoryConnector::new(),
            4096,
        );
        multi.put("k", vec![5; 10_000]).unwrap();
        let desc = ConnectorDesc::from_bytes(&multi.desc().to_bytes()).unwrap();
        let re = desc.connect().unwrap();
        assert_eq!(re.get("k").unwrap().unwrap().len(), 10_000);
    }

    #[test]
    fn multi_connector_wait_get_wakes() {
        let multi = Arc::new(MultiConnector::new(
            MemoryConnector::new(),
            MemoryConnector::new(),
            100,
        ));
        let m2 = multi.clone();
        let h = std::thread::spawn(move || {
            m2.wait_get("later", Some(Duration::from_secs(5))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        multi.put("later", vec![1; 10]).unwrap(); // routes small
        assert_eq!(h.join().unwrap().unwrap().len(), 10);
        assert_eq!(
            multi
                .wait_get("never", Some(Duration::from_millis(40)))
                .unwrap(),
            None
        );
    }
}
