//! Bench harness (criterion is not available offline; this is the
//! replacement used by every `rust/benches/fig*.rs` target).
//!
//! Provides warmup + timed sampling with summary statistics, a
//! paper-vs-measured comparison table renderer, and CSV output under
//! `results/`. Benches are `harness = false` binaries that call into this
//! module, so `cargo bench` runs them all.

use std::time::{Duration, Instant};

use crate::metrics::{telemetry, write_csv, write_text_atomic, Stats};

/// Directory bench artifacts (CSVs, telemetry dumps, scenario listings)
/// are written under. Defaults to `results/` relative to the working
/// directory; override with the `PALLAS_RESULTS_DIR` env var so CI and
/// multi-run sweeps can redirect output without touching bench code.
pub fn results_dir() -> String {
    match std::env::var("PALLAS_RESULTS_DIR") {
        Ok(dir) if !dir.is_empty() => dir,
        _ => "results".to_string(),
    }
}

/// Time `f` over `samples` runs after `warmup` runs; returns per-run
/// seconds.
pub fn sample<T>(
    warmup: usize,
    samples: usize,
    mut f: impl FnMut() -> T,
) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Run-once measurement (for long end-to-end scenarios).
pub fn once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A bench report accumulating rows for stdout + CSV.
pub struct Bench {
    name: String,
    header: String,
    rows: Vec<String>,
    t0: Instant,
}

impl Bench {
    /// Start a bench named after the figure it regenerates.
    pub fn new(name: &str, csv_header: &str) -> Bench {
        println!("\n=== bench: {name} ===");
        Bench {
            name: name.to_string(),
            header: csv_header.to_string(),
            rows: Vec::new(),
            t0: Instant::now(),
        }
    }

    /// Log a measured row (also printed).
    pub fn row(&mut self, csv_row: String) {
        println!("  {}", csv_row.replace(',', "\t"));
        self.rows.push(csv_row);
    }

    /// Print an annotation line (not part of the CSV).
    pub fn note(&self, msg: &str) {
        println!("  # {msg}");
    }

    /// Print a paper-vs-measured comparison line.
    pub fn compare(&self, what: &str, paper: &str, measured: &str, holds: bool) {
        println!(
            "  [{}] {what}: paper={paper} measured={measured}",
            if holds { "OK" } else { "DIVERGES" }
        );
    }

    /// Summarize samples inline.
    pub fn stats(&mut self, label: &str, seconds: &[f64]) -> Stats {
        let s = Stats::from(seconds);
        println!("  {label}: {s}");
        s
    }

    /// Write the CSV (plus a rendered telemetry snapshot alongside it)
    /// and finish.
    pub fn finish(self) {
        let dir = results_dir();
        let path = format!("{dir}/{}.csv", self.name);
        if let Err(e) = write_csv(&path, &self.header, &self.rows) {
            eprintln!("  (csv write failed: {e})");
        } else {
            println!(
                "  wrote {path} ({} rows) in {:.1}s",
                self.rows.len(),
                self.t0.elapsed().as_secs_f64()
            );
        }
        // The process-wide registry has been accumulating while the bench
        // ran; dump it next to the CSV so regressions come with their
        // telemetry attached.
        let snap = telemetry::snapshot();
        let tpath = format!("{dir}/{}.telemetry.txt", self.name);
        if let Err(e) = write_text_atomic(&tpath, &snap.render()) {
            eprintln!("  (telemetry write failed: {e})");
        } else {
            println!("  wrote {tpath}");
        }
    }
}

/// Standard scale knob: benches honour `PROXYSTORE_BENCH_SCALE` ∈
/// {smoke, default, full} so CI smoke runs stay fast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Default,
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("PROXYSTORE_BENCH_SCALE")
            .unwrap_or_default()
            .as_str()
        {
            "smoke" => Scale::Smoke,
            "full" => Scale::Full,
            _ => Scale::Default,
        }
    }

    /// Pick a value by scale.
    pub fn pick<T: Copy>(&self, smoke: T, default: T, full: T) -> T {
        match self {
            Scale::Smoke => smoke,
            Scale::Default => default,
            Scale::Full => full,
        }
    }
}

/// Peak resident set size (VmHWM) of this process in bytes, read from
/// `/proc/self/status`. Returns 0 where the interface is missing
/// (non-Linux) or unparsable, so callers must treat 0 as "unknown"
/// rather than a measurement. The kernel value is a monotonic
/// high-water mark: deltas between two calls attribute growth to
/// whatever ran in between, but never go negative.
pub fn peak_rss_bytes() -> u64 {
    let status = match std::fs::read_to_string("/proc/self/status") {
        Ok(s) => s,
        Err(_) => return 0,
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse::<u64>()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Convenience: seconds → human string.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Convenience: bytes → human string.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1_000_000 {
        format!("{:.0}MB", b as f64 / 1e6)
    } else if b >= 1_000 {
        format!("{:.0}kB", b as f64 / 1e3)
    } else {
        format!("{b}B")
    }
}

/// Busy-wait helper exposed to benches.
pub fn spin(d: Duration) {
    crate::netsim::spin_sleep(d);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_returns_right_count() {
        let xs = sample(2, 5, || 1 + 1);
        assert_eq!(xs.len(), 5);
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn once_measures() {
        let (v, dt) = once(|| {
            spin(Duration::from_millis(10));
            7
        });
        assert_eq!(v, 7);
        assert!(dt >= 0.009);
    }

    #[test]
    fn scale_pick() {
        assert_eq!(Scale::Smoke.pick(1, 2, 3), 1);
        assert_eq!(Scale::Default.pick(1, 2, 3), 2);
        assert_eq!(Scale::Full.pick(1, 2, 3), 3);
    }

    #[test]
    fn results_dir_honours_env_override() {
        // No other test touches this var, so set/unset here is safe.
        std::env::set_var("PALLAS_RESULTS_DIR", "/tmp/pallas-results-test");
        assert_eq!(results_dir(), "/tmp/pallas-results-test");
        std::env::set_var("PALLAS_RESULTS_DIR", "");
        assert_eq!(results_dir(), "results");
        std::env::remove_var("PALLAS_RESULTS_DIR");
        assert_eq!(results_dir(), "results");
    }

    #[test]
    fn peak_rss_reads_high_water() {
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            // Any running process has touched at least a page.
            assert!(rss > 0);
            // Monotonic: a second read never shrinks.
            assert!(peak_rss_bytes() >= rss);
        }
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(0.00005), "50.0us");
        assert_eq!(fmt_bytes(5), "5B");
        assert_eq!(fmt_bytes(5_000), "5kB");
        assert_eq!(fmt_bytes(5_000_000), "5MB");
    }
}
