//! Stage timelines: the measurement behind Figs 5a and 8.
//!
//! Tasks report `(task, stage)` intervals relative to the timeline's epoch;
//! the bench harness renders them as rows (one per task) of labelled spans
//! and computes makespan / per-stage aggregates.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One recorded `(task, stage)` interval.
#[derive(Debug, Clone, PartialEq)]
pub struct StageRecord {
    pub task: String,
    pub stage: String,
    /// Seconds since the timeline epoch.
    pub start: f64,
    pub end: f64,
}

impl StageRecord {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Thread-safe collection of stage records with a shared epoch.
#[derive(Debug)]
pub struct Timeline {
    epoch: Instant,
    records: Mutex<Vec<StageRecord>>,
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Timeline {
    pub fn new() -> Self {
        Timeline {
            epoch: Instant::now(),
            records: Mutex::new(Vec::new()),
        }
    }

    /// Seconds since the epoch.
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record an interval with explicit bounds (seconds since epoch).
    pub fn record(&self, task: &str, stage: &str, start: f64, end: f64) {
        self.records.lock().unwrap().push(StageRecord {
            task: task.to_string(),
            stage: stage.to_string(),
            start,
            end,
        });
    }

    /// Run `f`, recording its duration as a stage interval.
    pub fn timed<T>(&self, task: &str, stage: &str, f: impl FnOnce() -> T) -> T {
        let start = self.now();
        let out = f();
        let end = self.now();
        self.record(task, stage, start, end);
        out
    }

    /// Snapshot of all records, sorted by start time.
    pub fn records(&self) -> Vec<StageRecord> {
        let mut v = self.records.lock().unwrap().clone();
        v.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        v
    }

    /// Latest end time across all records (the makespan if the epoch is t0).
    pub fn makespan(&self) -> f64 {
        self.records
            .lock()
            .unwrap()
            .iter()
            .map(|r| r.end)
            .fold(0.0, f64::max)
    }

    /// `(start, end)` envelope of every record whose stage name matches.
    pub fn stage_envelope(&self, stage: &str) -> Option<(f64, f64)> {
        let recs = self.records.lock().unwrap();
        let matching: Vec<_> = recs.iter().filter(|r| r.stage == stage).collect();
        if matching.is_empty() {
            return None;
        }
        let start = matching.iter().map(|r| r.start).fold(f64::MAX, f64::min);
        let end = matching.iter().map(|r| r.end).fold(0.0, f64::max);
        Some((start, end))
    }

    /// Total time attributed to a stage, summed over tasks.
    pub fn stage_total(&self, stage: &str) -> f64 {
        self.records
            .lock()
            .unwrap()
            .iter()
            .filter(|r| r.stage == stage)
            .map(|r| r.duration())
            .sum()
    }

    /// CSV rows: `task,stage,start,end`.
    pub fn csv_rows(&self) -> Vec<String> {
        self.records()
            .iter()
            .map(|r| format!("{},{},{:.6},{:.6}", r.task, r.stage, r.start, r.end))
            .collect()
    }

    /// Render a coarse ASCII Gantt chart (one row per task) for bench
    /// stdout; `width` columns span `[0, makespan]`.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let recs = self.records();
        let makespan = self.makespan().max(1e-9);
        let mut tasks: Vec<String> = Vec::new();
        for r in &recs {
            if !tasks.contains(&r.task) {
                tasks.push(r.task.clone());
            }
        }
        let mut out = String::new();
        for task in &tasks {
            let mut row = vec![' '; width];
            for r in recs.iter().filter(|r| &r.task == task) {
                let a = ((r.start / makespan) * width as f64) as usize;
                let b = (((r.end / makespan) * width as f64).ceil() as usize)
                    .min(width);
                let ch = r.stage.chars().next().unwrap_or('?');
                for slot in row.iter_mut().take(b).skip(a.min(width)) {
                    *slot = ch;
                }
            }
            out.push_str(&format!(
                "{:>12} |{}|\n",
                &task[..task.len().min(12)],
                row.iter().collect::<String>()
            ));
        }
        out.push_str(&format!("makespan = {:.3}s\n", makespan));
        out
    }

    /// Shift used when simulating: record an interval of a known duration
    /// ending now.
    pub fn record_ending_now(&self, task: &str, stage: &str, dur: Duration) {
        let end = self.now();
        self.record(task, stage, end - dur.as_secs_f64(), end);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_records_interval() {
        let t = Timeline::new();
        let v = t.timed("t0", "compute", || {
            std::thread::sleep(Duration::from_millis(10));
            42
        });
        assert_eq!(v, 42);
        let recs = t.records();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].duration() >= 0.009, "{recs:?}");
        assert!(t.makespan() >= recs[0].end);
    }

    #[test]
    fn stage_envelope_and_totals() {
        let t = Timeline::new();
        t.record("a", "s1", 0.0, 1.0);
        t.record("b", "s1", 0.5, 2.0);
        t.record("c", "s2", 2.0, 3.0);
        assert_eq!(t.stage_envelope("s1"), Some((0.0, 2.0)));
        assert_eq!(t.stage_envelope("s3"), None);
        assert!((t.stage_total("s1") - 2.5).abs() < 1e-12);
        assert!((t.makespan() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn csv_and_gantt_render() {
        let t = Timeline::new();
        t.record("task-a", "overhead", 0.0, 0.2);
        t.record("task-a", "compute", 0.2, 1.0);
        let rows = t.csv_rows();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].starts_with("task-a,overhead,"));
        let g = t.ascii_gantt(40);
        assert!(g.contains("task-a"));
        assert!(g.contains('o') && g.contains('c'));
    }

    #[test]
    fn records_sorted_by_start() {
        let t = Timeline::new();
        t.record("b", "s", 5.0, 6.0);
        t.record("a", "s", 1.0, 2.0);
        let recs = t.records();
        assert_eq!(recs[0].task, "a");
    }
}
