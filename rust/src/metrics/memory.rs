//! Memory measurement: process RSS + store-resident bytes (Figs 7, 10).
//!
//! The paper plots *system* memory on a node; here the analogue is the
//! process RSS (everything runs in one process) plus an exact accounting of
//! bytes resident in mediated stores ([`StoreBytes`] gauges, incremented by
//! connectors on put and decremented on evict). The store gauge is the
//! cleaner signal — it is immune to allocator hysteresis — so the Fig 7/10
//! benches plot both.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Read the process resident set size in bytes from `/proc/self/statm`.
pub fn rss_bytes() -> u64 {
    let page = 4096u64; // Linux x86-64 default; fine for a measurement aid
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| {
            s.split_whitespace()
                .nth(1)
                .and_then(|v| v.parse::<u64>().ok())
        })
        .map(|pages| pages * page)
        .unwrap_or(0)
}

/// Gauge of bytes resident in a mediated store (shared by connectors).
#[derive(Debug, Default)]
pub struct StoreBytes {
    bytes: AtomicI64,
    peak: AtomicI64,
}

impl StoreBytes {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn add(&self, n: usize) {
        let cur = self.bytes.fetch_add(n as i64, Ordering::Relaxed) + n as i64;
        self.peak.fetch_max(cur, Ordering::Relaxed);
    }

    pub fn sub(&self, n: usize) {
        self.bytes.fetch_sub(n as i64, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> i64 {
        self.peak.load(Ordering::Relaxed)
    }
}

/// One sample of the memory series.
#[derive(Debug, Clone, PartialEq)]
pub struct MemSample {
    /// Seconds since sampler start.
    pub t: f64,
    /// Process RSS bytes.
    pub rss: u64,
    /// Store-resident bytes (sum over registered gauges).
    pub store: i64,
}

/// A recorded memory time series.
#[derive(Debug, Clone, Default)]
pub struct MemorySeries {
    pub samples: Vec<MemSample>,
}

impl MemorySeries {
    pub fn peak_store(&self) -> i64 {
        self.samples.iter().map(|s| s.store).max().unwrap_or(0)
    }

    pub fn peak_rss(&self) -> u64 {
        self.samples.iter().map(|s| s.rss).max().unwrap_or(0)
    }

    pub fn final_store(&self) -> i64 {
        self.samples.last().map(|s| s.store).unwrap_or(0)
    }

    /// Mean store bytes over the series (the Fig 7 "average memory usage").
    pub fn mean_store(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|s| s.store as f64).sum::<f64>()
            / self.samples.len() as f64
    }

    pub fn csv_rows(&self) -> Vec<String> {
        self.samples
            .iter()
            .map(|s| format!("{:.3},{},{}", s.t, s.rss, s.store))
            .collect()
    }
}

/// Background sampler thread recording RSS + store gauges on a cadence.
pub struct MemorySampler {
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: Option<std::thread::JoinHandle<MemorySeries>>,
}

impl MemorySampler {
    /// Start sampling every `interval`, reading the given gauges.
    pub fn start(interval: Duration, gauges: Vec<Arc<StoreBytes>>) -> Self {
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("mem-sampler".into())
            .spawn(move || {
                let t0 = Instant::now();
                let mut series = MemorySeries::default();
                loop {
                    let store = gauges.iter().map(|g| g.get()).sum();
                    series.samples.push(MemSample {
                        t: t0.elapsed().as_secs_f64(),
                        rss: rss_bytes(),
                        store,
                    });
                    if stop2.load(Ordering::Relaxed) {
                        return series;
                    }
                    std::thread::sleep(interval);
                }
            })
            .expect("spawn mem-sampler");
        MemorySampler {
            stop,
            handle: Some(handle),
        }
    }

    /// Stop sampling and return the series (includes one final sample).
    pub fn stop(mut self) -> MemorySeries {
        self.stop.store(true, Ordering::Relaxed);
        self.handle
            .take()
            .expect("sampler already stopped")
            .join()
            .expect("sampler thread panicked")
    }
}

/// Shared registry so stores created anywhere can be sampled centrally.
#[derive(Debug, Default, Clone)]
pub struct GaugeRegistry {
    gauges: Arc<Mutex<Vec<Arc<StoreBytes>>>>,
}

impl GaugeRegistry {
    pub fn register(&self, g: Arc<StoreBytes>) {
        self.gauges.lock().unwrap().push(g);
    }

    pub fn all(&self) -> Vec<Arc<StoreBytes>> {
        self.gauges.lock().unwrap().clone()
    }

    pub fn total(&self) -> i64 {
        self.all().iter().map(|g| g.get()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_nonzero_on_linux() {
        assert!(rss_bytes() > 1024 * 1024);
    }

    #[test]
    fn store_bytes_tracks_peak() {
        let g = StoreBytes::new();
        g.add(100);
        g.add(50);
        g.sub(120);
        assert_eq!(g.get(), 30);
        assert_eq!(g.peak(), 150);
    }

    #[test]
    fn sampler_records_series() {
        let g = StoreBytes::new();
        let sampler =
            MemorySampler::start(Duration::from_millis(5), vec![g.clone()]);
        g.add(1_000_000);
        std::thread::sleep(Duration::from_millis(30));
        g.sub(1_000_000);
        std::thread::sleep(Duration::from_millis(15));
        let series = sampler.stop();
        assert!(series.samples.len() >= 3, "{}", series.samples.len());
        assert_eq!(series.peak_store(), 1_000_000);
        assert_eq!(series.final_store(), 0);
        assert!(series.peak_rss() > 0);
        assert!(!series.csv_rows().is_empty());
    }

    #[test]
    fn registry_sums_gauges() {
        let reg = GaugeRegistry::default();
        let a = StoreBytes::new();
        let b = StoreBytes::new();
        reg.register(a.clone());
        reg.register(b.clone());
        a.add(5);
        b.add(7);
        assert_eq!(reg.total(), 12);
    }
}
