//! Cluster-wide observability: fan a telemetry scrape across every
//! member of a fabric, merge the per-node snapshots into one view, and
//! assemble cross-process span trees.
//!
//! [`ClusterSnapshot::scrape`] rides the existing machinery end to end:
//! each remote node answers the `Telemetry` wire op through its normal
//! data-plane connection ([`Connector::scrape_telemetry`]), the requests
//! fan out concurrently on the shared reactor pool, and the merged view
//! is [`TelemetrySnapshot::merge`] — counters sum, gauge high-waters take
//! the max, histograms add bucket-wise, and every node's trace ring and
//! slow-op log concatenate.
//!
//! The concatenated trace events are what make one logical op visible
//! across processes: the pipelined client stamps a `kv.client` span and
//! ships its id inside the `Traced` envelope, the server parents its
//! `kv.server` span on that id, and [`ClusterSnapshot::span_trees_for`]
//! re-links them into a tree spanning client → router → shard.
//! [`chrome_trace_json`] exports the same records as Chrome trace-viewer
//! JSON (loadable in Perfetto / `chrome://tracing`): one process row per
//! node, spans on the shared wall-clock microsecond timeline.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::metrics::telemetry::{self, TelemetrySnapshot, TraceEvent};
use crate::ops::reactor::{Job, fan_out};
use crate::store::Connector;

/// Merged multi-node telemetry: labeled per-node snapshots plus the
/// cluster-total merge. Scrape failures are collected, never fatal — a
/// down shard costs its slice of the view, not the whole scrape.
pub struct ClusterSnapshot {
    /// `(node_label, snapshot)`, the local process first as `"local"`,
    /// remote nodes sorted by label.
    pub nodes: Vec<(String, TelemetrySnapshot)>,
    /// Every node merged ([`TelemetrySnapshot::merge`]).
    pub total: TelemetrySnapshot,
    /// `(node_label, error)` for members that failed to answer.
    pub errors: Vec<(String, String)>,
}

impl ClusterSnapshot {
    /// Scrape every `(label, connector)` target concurrently on the
    /// shared reactor pool and merge. The local process's registry is
    /// always included as node `"local"` — it holds the client-side half
    /// of every traced op. Targets whose channel is in-process
    /// (`scrape_telemetry` → `None`) are skipped: their metrics already
    /// live in the local registry.
    pub fn scrape(
        targets: Vec<(String, Arc<dyn Connector>)>,
    ) -> ClusterSnapshot {
        let jobs: Vec<(String, Job<Option<TelemetrySnapshot>>)> = targets
            .into_iter()
            .map(|(label, conn)| {
                let job: Job<Option<TelemetrySnapshot>> =
                    Box::new(move || conn.scrape_telemetry());
                (label, job)
            })
            .collect();
        Self::from_jobs(jobs)
    }

    /// Scrape every shard of a static fabric, labeled `shard-{ring_id}`.
    pub fn scrape_sharded(
        router: &crate::shard::ShardedConnector,
    ) -> ClusterSnapshot {
        Self::scrape(
            router
                .members()
                .into_iter()
                .map(|(id, c)| (format!("shard-{id}"), c))
                .collect(),
        )
    }

    /// Scrape every current-epoch member of an elastic fabric.
    pub fn scrape_elastic(
        elastic: &crate::shard::rebalance::ElasticShards,
    ) -> ClusterSnapshot {
        Self::scrape(
            elastic
                .members()
                .into_iter()
                .map(|(id, c)| (format!("shard-{id}"), c))
                .collect(),
        )
    }

    /// Scrape every broker instance of a fabric, labeled `broker-{idx}`.
    pub fn scrape_broker_fabric(
        fabric: &crate::broker::BrokerFabric,
    ) -> ClusterSnapshot {
        let jobs: Vec<(String, Job<Option<TelemetrySnapshot>>)> = (0
            ..fabric.instance_count())
            .map(|i| {
                let inst = fabric.instance(i).clone();
                let job: Job<Option<TelemetrySnapshot>> =
                    Box::new(move || inst.scrape_telemetry());
                (format!("broker-{i}"), job)
            })
            .collect();
        Self::from_jobs(jobs)
    }

    fn from_jobs(
        jobs: Vec<(String, Job<Option<TelemetrySnapshot>>)>,
    ) -> ClusterSnapshot {
        let mut remote: Vec<(String, TelemetrySnapshot)> = Vec::new();
        let mut errors: Vec<(String, String)> = Vec::new();
        for (label, res) in fan_out(jobs) {
            match res {
                Ok(Some(snap)) => remote.push((label, snap)),
                Ok(None) => {} // in-process: covered by the local node
                Err(e) => errors.push((label, e.to_string())),
            }
        }
        // fan_out returns in completion order; sort for determinism.
        remote.sort_by(|(a, _), (b, _)| a.cmp(b));
        errors.sort_by(|(a, _), (b, _)| a.cmp(b));
        let mut nodes = vec![("local".to_string(), telemetry::snapshot())];
        nodes.extend(remote);
        let total = TelemetrySnapshot::merge(nodes.iter().map(|(_, s)| s));
        ClusterSnapshot { nodes, total, errors }
    }

    /// Cross-process span trees for one trace id, assembled from every
    /// node's events (roots first, children ordered by start time).
    pub fn span_trees_for(&self, trace_id: u64) -> Vec<SpanNode> {
        span_trees(&self.nodes, Some(trace_id))
    }

    /// All span trees across every trace in the merged view.
    pub fn span_trees(&self) -> Vec<SpanNode> {
        span_trees(&self.nodes, None)
    }

    /// Chrome trace-viewer JSON over every node's events.
    pub fn chrome_trace(&self) -> String {
        chrome_trace_json(&self.nodes)
    }

    /// Human-readable cluster view: per-node op counts, then the merged
    /// snapshot's full rendering.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "== cluster snapshot: {} nodes ==", self.nodes.len());
        for (label, snap) in &self.nodes {
            let _ = writeln!(
                s,
                "  {label:<12} counters={} histograms={} events={} slow={}",
                snap.counters.len(),
                snap.histograms.len(),
                snap.events.len(),
                snap.slow_ops.len(),
            );
        }
        for (label, err) in &self.errors {
            let _ = writeln!(s, "  {label:<12} SCRAPE FAILED: {err}");
        }
        s.push_str("-- merged --\n");
        s.push_str(&self.total.render());
        s
    }
}

/// One span in a cross-process tree: the event, which node recorded it,
/// and its children (spans whose `parent_span` is this span's id).
#[derive(Debug, Clone)]
pub struct SpanNode {
    pub event: TraceEvent,
    /// Label of the node whose trace ring held this span.
    pub node: String,
    pub children: Vec<SpanNode>,
}

impl SpanNode {
    /// Total spans in this subtree (including self).
    pub fn size(&self) -> usize {
        1 + self.children.iter().map(SpanNode::size).sum::<usize>()
    }
}

/// Assemble span trees from labeled per-node snapshots, optionally
/// restricted to one trace id. Roots are spans whose parent is 0 or not
/// present in the merged set (the parent span may have been evicted from
/// its ring); siblings order by start time.
pub fn span_trees(
    nodes: &[(String, TelemetrySnapshot)],
    trace_id: Option<u64>,
) -> Vec<SpanNode> {
    let all: Vec<(&str, &TraceEvent)> = nodes
        .iter()
        .flat_map(|(label, snap)| {
            snap.events
                .iter()
                .filter(|ev| trace_id.is_none_or(|t| ev.trace_id == t))
                .map(move |ev| (label.as_str(), ev))
        })
        .collect();
    let ids: HashSet<u64> = all.iter().map(|(_, ev)| ev.span_id).collect();
    let mut by_parent: HashMap<u64, Vec<usize>> = HashMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for (i, (_, ev)) in all.iter().enumerate() {
        if ev.parent_span != 0 && ids.contains(&ev.parent_span) {
            by_parent.entry(ev.parent_span).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    fn build(
        idx: usize,
        all: &[(&str, &TraceEvent)],
        by_parent: &HashMap<u64, Vec<usize>>,
        visited: &mut HashSet<u64>,
    ) -> SpanNode {
        let (label, ev) = all[idx];
        let mut children = Vec::new();
        // A span id cycle (malformed input) terminates here instead of
        // recursing forever.
        if visited.insert(ev.span_id) {
            if let Some(kids) = by_parent.get(&ev.span_id) {
                for &k in kids {
                    if !visited.contains(&all[k].1.span_id) {
                        children.push(build(k, all, by_parent, visited));
                    }
                }
            }
        }
        children.sort_by_key(|c| c.event.start_us);
        SpanNode {
            event: ev.clone(),
            node: label.to_string(),
            children,
        }
    }
    let mut visited = HashSet::new();
    let mut out: Vec<SpanNode> = roots
        .into_iter()
        .filter(|&i| !visited.contains(&all[i].1.span_id))
        .map(|i| build(i, &all, &by_parent, &mut visited))
        .collect();
    out.sort_by_key(|n| n.event.start_us);
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Export labeled per-node snapshots as Chrome trace-viewer JSON
/// (`{"traceEvents": [...]}`): each node becomes a process row (named by
/// a `process_name` metadata event), each span a complete (`"ph": "X"`)
/// event on the trace-id thread lane, timestamps straight from the
/// wall-clock microsecond timeline the spans were recorded on.
pub fn chrome_trace_json(nodes: &[(String, TelemetrySnapshot)]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut push = |s: String, first: &mut bool| {
        if !*first {
            out.push(',');
        }
        *first = false;
        out.push_str(&s);
        // placate the borrow checker: `out` is captured mutably.
    };
    let mut buf = Vec::new();
    for (pid, (label, snap)) in nodes.iter().enumerate() {
        buf.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\
             \"tid\":0,\"args\":{{\"name\":\"{}\"}}}}",
            json_escape(label)
        ));
        for ev in &snap.events {
            buf.push(format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\
                 \"ts\":{},\"dur\":{},\"pid\":{pid},\"tid\":{},\
                 \"args\":{{\"trace\":\"{:016x}\",\"span\":\"{:x}\",\
                 \"parent\":\"{:x}\"}}}}",
                json_escape(&ev.name),
                json_escape(&ev.subsystem),
                ev.start_us,
                ev.dur_us.max(1),
                ev.trace_id,
                ev.trace_id,
                ev.span_id,
                ev.parent_span,
            ));
        }
    }
    for s in buf {
        push(s, &mut first);
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        trace: u64,
        span: u64,
        parent: u64,
        name: &str,
        start: u64,
        dur: u64,
    ) -> TraceEvent {
        TraceEvent {
            seq: span,
            trace_id: trace,
            span_id: span,
            parent_span: parent,
            subsystem: "test".into(),
            name: name.into(),
            start_us: start,
            dur_us: dur,
        }
    }

    fn snap_with(events: Vec<TraceEvent>) -> TelemetrySnapshot {
        TelemetrySnapshot { events, ..Default::default() }
    }

    #[test]
    fn span_trees_link_across_nodes() {
        // Client root on "local", two server spans parented on it from
        // two different nodes, one grandchild.
        let nodes = vec![
            (
                "local".to_string(),
                snap_with(vec![ev(9, 1, 0, "get", 100, 500)]),
            ),
            (
                "shard-0".to_string(),
                snap_with(vec![
                    ev(9, 2, 1, "get", 150, 100),
                    ev(9, 4, 2, "engine", 160, 50),
                ]),
            ),
            (
                "shard-1".to_string(),
                snap_with(vec![ev(9, 3, 1, "get", 300, 100)]),
            ),
        ];
        let trees = span_trees(&nodes, Some(9));
        assert_eq!(trees.len(), 1, "one root");
        let root = &trees[0];
        assert_eq!(root.event.span_id, 1);
        assert_eq!(root.node, "local");
        assert_eq!(root.size(), 4);
        assert_eq!(root.children.len(), 2);
        // Siblings ordered by start time, nodes attributed correctly.
        assert_eq!(root.children[0].event.span_id, 2);
        assert_eq!(root.children[0].node, "shard-0");
        assert_eq!(root.children[0].children[0].event.span_id, 4);
        assert_eq!(root.children[1].node, "shard-1");
        // Filtering by another trace id yields nothing.
        assert!(span_trees(&nodes, Some(8)).is_empty());
    }

    #[test]
    fn orphaned_spans_become_roots() {
        // Parent span evicted from its ring: the child still shows up.
        let nodes = vec![(
            "local".to_string(),
            snap_with(vec![ev(5, 10, 999, "orphan", 50, 10)]),
        )];
        let trees = span_trees(&nodes, None);
        assert_eq!(trees.len(), 1);
        assert_eq!(trees[0].event.span_id, 10);
    }

    #[test]
    fn chrome_trace_is_wellformed_and_complete() {
        let nodes = vec![
            (
                "local".to_string(),
                snap_with(vec![ev(9, 1, 0, "get", 100, 500)]),
            ),
            (
                "shard \"0\"".to_string(),
                snap_with(vec![ev(9, 2, 1, "get", 150, 0)]),
            ),
        ];
        let json = chrome_trace_json(&nodes);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        // Both process rows named (label quotes escaped), both spans
        // present, zero durations clamped to 1 so viewers show them.
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("shard \\\"0\\\""));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 2);
        assert!(json.contains("\"ts\":100,\"dur\":500"));
        assert!(json.contains("\"ts\":150,\"dur\":1"));
        // Balanced braces/brackets — cheap well-formedness proxy given
        // no JSON parser in the dependency set.
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count()
        );
        assert_eq!(
            json.matches('[').count(),
            json.matches(']').count()
        );
    }

    #[test]
    fn scrape_skips_in_process_and_merges_local() {
        let mem = crate::store::MemoryConnector::new();
        let cs = ClusterSnapshot::scrape(vec![("mem".into(), mem)]);
        assert_eq!(cs.nodes.len(), 1, "memory channel has no remote node");
        assert_eq!(cs.nodes[0].0, "local");
        assert!(cs.errors.is_empty());
    }
}
