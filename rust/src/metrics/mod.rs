//! Measurement substrate: stage timelines, memory sampling, counters, CSV.
//!
//! Every figure in the paper is a view over one of three measurement kinds:
//! stage start/end timelines (Figs 5a, 8), scalar time series sampled on a
//! wall-clock cadence (Figs 7, 10), or throughput counters (Figs 6, 9).
//! This module provides those three primitives plus summary statistics and
//! CSV output used by the bench harness.

pub mod cluster;
mod memory;
mod rebalance;
mod stats;
pub mod telemetry;
mod timeline;

pub use cluster::{ClusterSnapshot, SpanNode, chrome_trace_json, span_trees};
pub use memory::{GaugeRegistry, MemorySampler, MemorySeries, StoreBytes, rss_bytes};
pub use rebalance::{RebalanceMetrics, RebalanceSnapshot};
pub use stats::{Stats, percentile};
pub use telemetry::{
    Counter, Gauge, Histogram, HistogramSnapshot, MirroredCounter, SlowOp,
    TraceCtx, TraceEvent, TraceGuard, TelemetrySnapshot,
};
pub use timeline::{StageRecord, Timeline};

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// Write `contents` to `path` atomically: the bytes land in a same-dir
/// temp file that is renamed into place, so readers (and an interrupted
/// run) see either the old file or the complete new one — never a
/// truncated half-write.
pub fn write_text_atomic<P: AsRef<Path>>(path: P, contents: &str) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    // Same directory as the target: rename must not cross filesystems.
    // Pid + address in the name keeps concurrent writers off each other's
    // temp files; the final rename is last-writer-wins either way.
    let tmp = path.with_extension(format!(
        "tmp.{}.{:x}",
        std::process::id(),
        contents.as_ptr() as usize
    ));
    let write = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    if write.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    write.map_err(Into::into)
}

/// Write rows to a CSV file under `results/`, creating directories. The
/// write is atomic (temp file + rename), so an interrupted bench run can
/// never leave a truncated `results/*.csv` behind.
pub fn write_csv<P: AsRef<Path>>(
    path: P,
    header: &str,
    rows: &[String],
) -> Result<()> {
    let mut text = String::with_capacity(
        header.len() + 1 + rows.iter().map(|r| r.len() + 1).sum::<usize>(),
    );
    text.push_str(header);
    text.push('\n');
    for row in rows {
        text.push_str(row);
        text.push('\n');
    }
    write_text_atomic(path, &text)
}

/// Monotonic throughput counter: events per second over a window.
#[derive(Debug, Default)]
pub struct Throughput {
    count: std::sync::atomic::AtomicU64,
}

impl Throughput {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self) {
        self.count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.count
            .fetch_add(n, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Events/sec given an elapsed duration.
    pub fn rate(&self, elapsed: std::time::Duration) -> f64 {
        self.count() as f64 / elapsed.as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        for _ in 0..10 {
            t.incr();
        }
        t.add(5);
        assert_eq!(t.count(), 15);
        let r = t.rate(std::time::Duration::from_secs(3));
        assert!((r - 5.0).abs() < 1e-9);
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join(format!(
            "pxs-csv-{}",
            std::process::id()
        ));
        let path = dir.join("nested/out.csv");
        write_csv(&path, "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "a,b\n1,2\n3,4\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_csv_replaces_atomically_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!(
            "pxs-csv-atomic-{}",
            std::process::id()
        ));
        let path = dir.join("out.csv");
        write_csv(&path, "h", &["old".into()]).unwrap();
        write_csv(&path, "h", &["new1".into(), "new2".into()]).unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            "h\nnew1\nnew2\n"
        );
        // The temp file must be renamed away, not left beside the target.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .filter(|n| n != "out.csv")
            .collect();
        assert!(leftovers.is_empty(), "stray files: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
