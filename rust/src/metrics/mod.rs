//! Measurement substrate: stage timelines, memory sampling, counters, CSV.
//!
//! Every figure in the paper is a view over one of three measurement kinds:
//! stage start/end timelines (Figs 5a, 8), scalar time series sampled on a
//! wall-clock cadence (Figs 7, 10), or throughput counters (Figs 6, 9).
//! This module provides those three primitives plus summary statistics and
//! CSV output used by the bench harness.

mod memory;
mod rebalance;
mod stats;
mod timeline;

pub use memory::{GaugeRegistry, MemorySampler, MemorySeries, StoreBytes, rss_bytes};
pub use rebalance::{RebalanceMetrics, RebalanceSnapshot};
pub use stats::{Stats, percentile};
pub use timeline::{StageRecord, Timeline};

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// Write rows to a CSV file under `results/`, creating directories.
pub fn write_csv<P: AsRef<Path>>(
    path: P,
    header: &str,
    rows: &[String],
) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{row}")?;
    }
    Ok(())
}

/// Monotonic throughput counter: events per second over a window.
#[derive(Debug, Default)]
pub struct Throughput {
    count: std::sync::atomic::AtomicU64,
}

impl Throughput {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self) {
        self.count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.count
            .fetch_add(n, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Events/sec given an elapsed duration.
    pub fn rate(&self, elapsed: std::time::Duration) -> f64 {
        self.count() as f64 / elapsed.as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        for _ in 0..10 {
            t.incr();
        }
        t.add(5);
        assert_eq!(t.count(), 15);
        let r = t.rate(std::time::Duration::from_secs(3));
        assert!((r - 5.0).abs() < 1e-9);
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join(format!(
            "pxs-csv-{}",
            std::process::id()
        ));
        let path = dir.join("nested/out.csv");
        write_csv(&path, "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "a,b\n1,2\n3,4\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
