//! Summary statistics for bench samples.

/// Summary statistics over a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Stats {
    /// Compute stats; returns a zeroed struct for empty input.
    pub fn from(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 50.0),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
        }
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} std={:.3} min={:.3} p50={:.3} p95={:.3} max={:.3}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.max
        )
    }
}

/// Linear-interpolated percentile of a **sorted** slice; `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn stats_empty_is_zeroed() {
        let s = Stats::from(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 100.0), 10.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let s = Stats::from(&[4.0; 10]);
        assert!(s.std.abs() < 1e-12);
    }
}
