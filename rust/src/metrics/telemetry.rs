//! Unified telemetry plane: a process-global registry of named counters,
//! gauges and log-bucketed latency histograms, a bounded trace-event ring,
//! and wire-level trace-context propagation.
//!
//! Every fabric in the stack reports here — the pipelined KV client
//! (`kv.client.*`), the KV server (`kv.server.*`), the shard router
//! (`shard.*`), the elastic rebalancer (`rebalance.*`), the reactor pool
//! (`reactor.*`), the watch/notify plane (`watch.*`), the broker fabric
//! (`broker.*`) and the typed [`Store`](crate::store::Store)
//! (`store.*`) — so one [`snapshot`] covers the whole process. The
//! primitives are lock-free on the hot path: a counter bump is one relaxed
//! `fetch_add`, a histogram record is three relaxed atomics plus one
//! bucket increment, and nothing ever takes a lock while recording.
//!
//! Latency histograms are **log-bucketed**: four sub-buckets per power of
//! two (≤ ~19% relative bucket width) over the full `u64` range, recorded
//! in microseconds. Quantiles are estimated by expanding the buckets into
//! a bounded sorted sample set and delegating to the same
//! [`percentile`](crate::metrics::percentile) machinery the bench harness
//! uses, so p50/p95/p99 here and in `benchlib` mean the same thing.
//!
//! **Trace propagation**: [`start_trace`] opens a trace on the calling
//! thread (RAII [`TraceGuard`] clears it). While a trace is current, the
//! pipelined KV client wraps each submitted request in a
//! [`Request::Traced`](crate::kv::Request::Traced) envelope; the server
//! unwraps it and stamps a server-side span carrying the same trace id, so
//! one logical op can be followed client → shard router → replica → KV
//! engine → notify push across process and wire boundaries. Span events
//! land in a bounded ring buffer ([`TelemetrySnapshot::events`]) — only
//! traced ops pay the ring's mutex; untraced hot paths never touch it.
//!
//! Recording can be disabled process-wide ([`set_enabled`]) — the
//! overhead gate in `benches/telemetry.rs` measures the instrumented hot
//! path against that baseline.
//!
//! **Exposition & aggregation**: [`TelemetrySnapshot::render`] is the
//! human-readable text view; [`TelemetrySnapshot::render_prometheus`] is
//! the scrape format served by the HTTP admin endpoint (`/metrics`),
//! sanitizing dotted names into `snake_case{label}` form. Snapshots from
//! N nodes merge into one cluster view with [`TelemetrySnapshot::merge`]
//! (counters sum, gauge high-waters take the max, histograms add
//! bucket-wise). Ops slower than a configurable threshold
//! ([`set_slow_threshold`]) additionally land in a bounded **slow-op
//! log** ([`TelemetrySnapshot::slow_ops`], the `/slow` admin route).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Duration;

use crate::codec::{Decode, Encode, Reader};
use crate::error::Result;

use super::stats::percentile;

// --------------------------------------------------------------------------
// Primitives
// --------------------------------------------------------------------------

/// Whether telemetry recording is active (default: yes). One relaxed load
/// on every record; flipping it off turns every primitive into a no-op —
/// the uninstrumented baseline the overhead bench compares against.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable or disable all recording process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether recording is active.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotonic named counter: one relaxed `fetch_add` per bump.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Signed gauge with a high-water mark (e.g. queue depth, in-flight ops).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
    hwm: AtomicI64,
}

impl Gauge {
    /// Move the gauge by `delta`, raising the high-water mark.
    pub fn add(&self, delta: i64) {
        if !enabled() {
            return;
        }
        let now = self.v.fetch_add(delta, Ordering::Relaxed) + delta;
        self.hwm.fetch_max(now, Ordering::Relaxed);
    }

    /// Set the gauge to an observed level, raising the high-water mark.
    pub fn set(&self, v: i64) {
        if !enabled() {
            return;
        }
        self.v.store(v, Ordering::Relaxed);
        self.hwm.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn high_water(&self) -> i64 {
        self.hwm.load(Ordering::Relaxed)
    }
}

/// Sub-buckets per power of two: 4 → bucket width ≤ ~19% of its value.
const SUB_BITS: u32 = 2;
const SUB: usize = 1 << SUB_BITS;
/// 64 octaves × 4 sub-buckets covers the full `u64` range.
const BUCKETS: usize = 64 * SUB;

/// Index of the log bucket holding `v`.
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let lz = 63 - v.leading_zeros();
    let sub = ((v >> (lz - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    (lz as usize) * SUB + sub
}

/// Lower bound of bucket `i` (its representative range is `[lo, hi)`).
fn bucket_lo(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let lz = (i / SUB) as u32;
    let sub = (i % SUB) as u64;
    (1u64 << lz) + sub * (1u64 << (lz - SUB_BITS))
}

/// Upper bound of bucket `i` (saturating: the top octave's bound would
/// overflow `u64`, so it closes at `u64::MAX` inclusive).
fn bucket_hi(i: usize) -> u64 {
    if i < SUB {
        return i as u64 + 1;
    }
    let lz = (i / SUB) as u32;
    bucket_lo(i).saturating_add(1u64 << (lz - SUB_BITS))
}

/// Lock-free log-bucketed histogram of `u64` observations (latencies in
/// microseconds by convention). Recording is four relaxed atomic ops; no
/// lock is ever taken. Concurrent recorders conserve both the total count
/// and the total sum exactly.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: Box<[AtomicU64; BUCKETS]>,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: Box::new([0u64; BUCKETS].map(AtomicU64::new)),
        }
    }
}

impl Histogram {
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration in microseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Plain-value copy at one instant. Taken while recorders are live the
    /// fields may be mutually slightly torn (count vs sum), like every
    /// relaxed-counter snapshot in the stack.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<(u64, u64)> = (0..BUCKETS)
            .filter_map(|i| {
                let n = self.buckets[i].load(Ordering::Relaxed);
                (n > 0).then(|| (bucket_lo(i), n))
            })
            .collect();
        let count = self.count();
        HistogramSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Histogram({} samples)", self.count())
    }
}

/// Plain-value copy of a [`Histogram`]: totals plus the non-empty buckets
/// as `(bucket_lower_bound, count)` pairs. Wire-encodable.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub buckets: Vec<(u64, u64)>,
}

/// Cap on the expanded sample set quantiles are computed over; buckets
/// with more observations than fit are scaled down proportionally.
const QUANTILE_SAMPLES: usize = 4096;

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Estimate the `q`-th percentile (`q` in `[0, 100]`) by expanding the
    /// log buckets into a bounded sorted sample set (bucket midpoints,
    /// weighted by count) and delegating to the shared
    /// [`percentile`](crate::metrics::percentile) interpolation. Accuracy
    /// is bounded by the bucket width (≤ ~19%); the exact `min`/`max`
    /// fields bound the tails.
    pub fn percentile(&self, q: f64) -> f64 {
        let samples = self.quantile_samples();
        percentile(&samples, q)
    }

    /// Fold `other` into `self` bucket-wise: counts and sums add, min/max
    /// widen, and buckets with the same lower bound merge. This is the
    /// cluster-aggregation primitive — merging N per-node snapshots gives
    /// percentile estimates over the union of all observations.
    pub fn absorb(&mut self, other: &HistogramSnapshot) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
        let mut merged: BTreeMap<u64, u64> =
            self.buckets.iter().copied().collect();
        for &(lo, n) in &other.buckets {
            *merged.entry(lo).or_insert(0) += n;
        }
        self.buckets = merged.into_iter().collect();
    }

    fn quantile_samples(&self) -> Vec<f64> {
        if self.count == 0 {
            return Vec::new();
        }
        // Scale so the expansion stays bounded no matter how many
        // observations landed; small histograms expand exactly.
        let scale = (self.count as f64 / QUANTILE_SAMPLES as f64).max(1.0);
        let mut out = Vec::new();
        for &(lo, n) in &self.buckets {
            let hi = bucket_hi(bucket_index(lo));
            let mid = (lo as f64 + hi as f64) / 2.0;
            let reps = ((n as f64 / scale).round() as usize).max(1);
            out.extend(std::iter::repeat(mid).take(reps));
        }
        // Buckets are emitted in index order, midpoints ascend with it.
        out
    }
}

impl Encode for HistogramSnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.count.encode(buf);
        self.sum.encode(buf);
        self.min.encode(buf);
        self.max.encode(buf);
        self.buckets.encode(buf);
    }
}

impl Decode for HistogramSnapshot {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(HistogramSnapshot {
            count: Decode::decode(r)?,
            sum: Decode::decode(r)?,
            min: Decode::decode(r)?,
            max: Decode::decode(r)?,
            buckets: Decode::decode(r)?,
        })
    }
}

/// A per-instance counter that mirrors every bump into a process-global
/// registry counter: instance accessors keep their exact local values
/// (tests and per-fabric diagnostics) while the registry aggregates
/// across all instances for the fleet-wide snapshot.
#[derive(Debug)]
pub struct MirroredCounter {
    local: AtomicU64,
    global: Arc<Counter>,
}

impl MirroredCounter {
    /// `global_name` is the registry counter every bump aggregates into.
    pub fn new(global_name: &str) -> MirroredCounter {
        MirroredCounter {
            local: AtomicU64::new(0),
            global: counter(global_name),
        }
    }

    pub fn incr(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.local.fetch_add(n, Ordering::Relaxed);
        self.global.add(n);
    }

    /// The instance-local total (unaffected by other instances).
    pub fn get(&self) -> u64 {
        self.local.load(Ordering::Relaxed)
    }
}

// --------------------------------------------------------------------------
// Trace context
// --------------------------------------------------------------------------

/// Identity of the current trace on this thread: which logical operation
/// (`trace_id`) and which hop within it (`span_id`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

thread_local! {
    static CURRENT_TRACE: std::cell::Cell<Option<TraceCtx>> =
        const { std::cell::Cell::new(None) };
}

fn ids() -> &'static AtomicU64 {
    static NEXT: OnceLock<AtomicU64> = OnceLock::new();
    NEXT.get_or_init(|| {
        // Seed from wall clock + pid so ids from different processes on a
        // shared fabric are distinguishable; uniqueness within a process
        // comes from the increment.
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1)
            ^ (u64::from(std::process::id()) << 32);
        AtomicU64::new(seed | 1)
    })
}

/// A fresh span id (unique within the process).
pub fn next_span_id() -> u64 {
    ids().fetch_add(1, Ordering::Relaxed)
}

/// The trace context current on this thread, if any.
pub fn current_trace() -> Option<TraceCtx> {
    CURRENT_TRACE.with(|c| c.get())
}

/// Open a new trace on the calling thread and return the guard that
/// scopes it: while the guard lives, ops submitted from this thread are
/// wrapped in `Request::Traced` envelopes on the wire. Dropping the guard
/// restores whatever trace (or none) was current before.
pub fn start_trace(name: &str) -> TraceGuard {
    let ctx = TraceCtx { trace_id: next_span_id(), span_id: next_span_id() };
    trace_event(ctx.trace_id, ctx.span_id, 0, "trace", name);
    let prev = CURRENT_TRACE.with(|c| c.replace(Some(ctx)));
    TraceGuard { prev, ctx }
}

/// Make `ctx` current for the guard's lifetime (server-side span adoption,
/// or carrying a context across a pool-worker hop).
pub fn enter_trace(ctx: TraceCtx) -> TraceGuard {
    let prev = CURRENT_TRACE.with(|c| c.replace(Some(ctx)));
    TraceGuard { prev, ctx }
}

/// RAII scope of a current trace; restores the previous context on drop.
#[derive(Debug)]
pub struct TraceGuard {
    prev: Option<TraceCtx>,
    ctx: TraceCtx,
}

impl TraceGuard {
    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        CURRENT_TRACE.with(|c| c.set(self.prev));
    }
}

/// Microseconds since the UNIX epoch (wall clock). Span start timestamps
/// use this so events from different processes merge onto one timeline.
pub fn now_us() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// One structured span record in the trace ring: parent-linked and
/// carrying a wall-clock start plus duration, so merged snapshots from N
/// processes assemble into cross-process span trees and export as Chrome
/// trace-viewer JSON (see [`crate::metrics::cluster`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Monotonic sequence within this process (ring ordering).
    pub seq: u64,
    pub trace_id: u64,
    pub span_id: u64,
    /// Span this one descends from (0 = root).
    pub parent_span: u64,
    /// Which fabric recorded it (`kv.client`, `kv.server`, ...).
    pub subsystem: String,
    /// Operation label (`get`, `set`, `notify`, ...).
    pub name: String,
    /// Wall-clock start, microseconds since the UNIX epoch.
    pub start_us: u64,
    /// Span duration in microseconds (0 for instant events).
    pub dur_us: u64,
}

impl Encode for TraceEvent {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.seq.encode(buf);
        self.trace_id.encode(buf);
        self.span_id.encode(buf);
        self.parent_span.encode(buf);
        self.subsystem.encode(buf);
        self.name.encode(buf);
        self.start_us.encode(buf);
        self.dur_us.encode(buf);
    }
}

impl Decode for TraceEvent {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(TraceEvent {
            seq: Decode::decode(r)?,
            trace_id: Decode::decode(r)?,
            span_id: Decode::decode(r)?,
            parent_span: Decode::decode(r)?,
            subsystem: Decode::decode(r)?,
            name: Decode::decode(r)?,
            start_us: Decode::decode(r)?,
            dur_us: Decode::decode(r)?,
        })
    }
}

/// Bounded ring of recent trace events. Only traced ops push here, so the
/// mutex is off the untraced hot path entirely. Overflow is counted, not
/// silent: `dropped` surfaces as the `telemetry.trace.dropped` counter.
///
/// The drop counter is a plain atomic rather than a registry [`Counter`]
/// because the ring is constructed *inside* the registry's `OnceLock`
/// init — calling `counter()` there would re-enter the lock and deadlock.
struct TraceRing {
    events: Mutex<std::collections::VecDeque<TraceEvent>>,
    seq: AtomicU64,
    dropped: AtomicU64,
    cap: usize,
}

impl TraceRing {
    fn new(cap: usize) -> TraceRing {
        TraceRing {
            events: Mutex::new(std::collections::VecDeque::with_capacity(cap)),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            cap,
        }
    }

    fn push(&self, mut ev: TraceEvent) {
        ev.seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.events.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    /// Events evicted by overflow since process start.
    fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    fn snapshot(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().iter().cloned().collect()
    }
}

/// Record a parent-linked span with an explicit wall-clock start and
/// duration into the global trace ring.
pub fn span_event(
    trace_id: u64,
    span_id: u64,
    parent_span: u64,
    subsystem: &str,
    name: &str,
    start_us: u64,
    dur_us: u64,
) {
    if !enabled() {
        return;
    }
    registry().ring.push(TraceEvent {
        seq: 0,
        trace_id,
        span_id,
        parent_span,
        subsystem: subsystem.to_string(),
        name: name.to_string(),
        start_us,
        dur_us,
    });
}

/// Record an instant span event (start = now, zero duration).
pub fn trace_event(
    trace_id: u64,
    span_id: u64,
    parent_span: u64,
    subsystem: &str,
    name: &str,
) {
    span_event(trace_id, span_id, parent_span, subsystem, name, now_us(), 0);
}

// --------------------------------------------------------------------------
// Slow-op log
// --------------------------------------------------------------------------

/// One entry in the slow-op log: an op whose latency met the threshold.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlowOp {
    /// Monotonic sequence within this process.
    pub seq: u64,
    /// Wall-clock start, microseconds since the UNIX epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Operation label (`get`, `set`, `produce`, ...).
    pub op: String,
    /// Trace identity when the op was traced (0 otherwise).
    pub trace_id: u64,
    pub span_id: u64,
    /// Which endpoint served it (`kv`, `broker`, a peer address, ...).
    pub peer: String,
}

impl Encode for SlowOp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.seq.encode(buf);
        self.start_us.encode(buf);
        self.dur_us.encode(buf);
        self.op.encode(buf);
        self.trace_id.encode(buf);
        self.span_id.encode(buf);
        self.peer.encode(buf);
    }
}

impl Decode for SlowOp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(SlowOp {
            seq: Decode::decode(r)?,
            start_us: Decode::decode(r)?,
            dur_us: Decode::decode(r)?,
            op: Decode::decode(r)?,
            trace_id: Decode::decode(r)?,
            span_id: Decode::decode(r)?,
            peer: Decode::decode(r)?,
        })
    }
}

// --------------------------------------------------------------------------
// Registry
// --------------------------------------------------------------------------

/// Trace events retained (older ones are dropped and counted).
const RING_CAP: usize = 1024;

/// Slow ops retained (older ones are dropped).
const SLOW_CAP: usize = 256;

/// Default slow-op threshold in microseconds.
const DEFAULT_SLOW_THRESHOLD_US: u64 = 1000;

/// The process-global metric registry: named counters, gauges and
/// histograms plus the trace ring and the slow-op log. Lookup is a
/// read-lock + map probe; hot paths cache the returned `Arc` handles and
/// never look up again.
pub struct Registry {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
    ring: TraceRing,
    slow: Mutex<std::collections::VecDeque<SlowOp>>,
    slow_seq: AtomicU64,
    slow_threshold_us: AtomicU64,
}

fn get_or_create<T: Default>(
    map: &RwLock<BTreeMap<String, Arc<T>>>,
    name: &str,
) -> Arc<T> {
    if let Some(v) = map.read().unwrap().get(name) {
        return v.clone();
    }
    map.write()
        .unwrap()
        .entry(name.to_string())
        .or_default()
        .clone()
}

impl Registry {
    fn new() -> Registry {
        Registry {
            counters: RwLock::new(BTreeMap::new()),
            gauges: RwLock::new(BTreeMap::new()),
            histograms: RwLock::new(BTreeMap::new()),
            ring: TraceRing::new(RING_CAP),
            slow: Mutex::new(std::collections::VecDeque::with_capacity(
                SLOW_CAP,
            )),
            slow_seq: AtomicU64::new(0),
            slow_threshold_us: AtomicU64::new(DEFAULT_SLOW_THRESHOLD_US),
        }
    }

    /// Log an op into the slow-op ring if it met the threshold. `dur` is
    /// the observed latency; the start timestamp is reconstructed from the
    /// wall clock. Trace ids are 0 for untraced ops.
    pub fn record_slow_op(
        &self,
        op: &str,
        dur: Duration,
        trace_id: u64,
        span_id: u64,
        peer: &str,
    ) {
        if !enabled() {
            return;
        }
        let dur_us = dur.as_micros() as u64;
        if dur_us < self.slow_threshold_us.load(Ordering::Relaxed) {
            return;
        }
        let entry = SlowOp {
            seq: self.slow_seq.fetch_add(1, Ordering::Relaxed),
            start_us: now_us().saturating_sub(dur_us),
            dur_us,
            op: op.to_string(),
            trace_id,
            span_id,
            peer: peer.to_string(),
        };
        let mut ring = self.slow.lock().unwrap();
        if ring.len() == SLOW_CAP {
            ring.pop_front();
        }
        ring.push_back(entry);
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        get_or_create(&self.counters, name)
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        get_or_create(&self.gauges, name)
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        get_or_create(&self.histograms, name)
    }

    /// Plain-value copy of every metric plus the trace ring and slow-op
    /// log. The trace ring's overflow counter is folded in as the
    /// `telemetry.trace.dropped` counter (BTreeMap iteration is sorted, so
    /// the insert keeps the counters vec ordered by name).
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let mut counters: Vec<(String, u64)> = self
            .counters
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let dropped = self.ring.dropped();
        if dropped > 0 {
            let name = "telemetry.trace.dropped".to_string();
            match counters.binary_search_by(|(n, _)| n.as_str().cmp(&name)) {
                Ok(i) => counters[i].1 += dropped,
                Err(i) => counters.insert(i, (name, dropped)),
            }
        }
        TelemetrySnapshot {
            counters,
            gauges: self
                .gauges
                .read()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), (v.get(), v.high_water())))
                .collect(),
            histograms: self
                .histograms
                .read()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
            events: self.ring.snapshot(),
            slow_ops: self.slow.lock().unwrap().iter().cloned().collect(),
        }
    }
}

/// The process-global registry.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// Get or create the global counter `name`.
pub fn counter(name: &str) -> Arc<Counter> {
    registry().counter(name)
}

/// Get or create the global gauge `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    registry().gauge(name)
}

/// Get or create the global histogram `name`.
pub fn histogram(name: &str) -> Arc<Histogram> {
    registry().histogram(name)
}

/// Snapshot the global registry.
pub fn snapshot() -> TelemetrySnapshot {
    registry().snapshot()
}

/// Cached registry handles for the zero-copy data plane: how many value
/// bytes the server actually memcpy'd versus moved by reference, plus
/// raw value volume in each direction. Copy regressions show up in
/// `/metrics` without a profiler.
pub struct DataMetrics {
    /// Value-payload bytes copied on the server data path (serving a
    /// large object zero-copy adds only its header here).
    pub bytes_copied: Arc<Counter>,
    /// Value-payload bytes received in write-side ops (SET et al.).
    pub value_bytes_in: Arc<Counter>,
    /// Value-payload bytes served in read-side replies and pushes.
    pub value_bytes_out: Arc<Counter>,
}

/// Cached [`DataMetrics`] accessor for hot paths (one registry lookup
/// per process, not per op).
pub fn data_metrics() -> &'static DataMetrics {
    static M: OnceLock<DataMetrics> = OnceLock::new();
    M.get_or_init(|| DataMetrics {
        bytes_copied: counter("data.bytes_copied"),
        value_bytes_in: counter("data.value_bytes_in"),
        value_bytes_out: counter("data.value_bytes_out"),
    })
}

/// Set the global slow-op threshold: ops at or above it land in the
/// slow-op log. Default 1ms.
pub fn set_slow_threshold(d: Duration) {
    registry()
        .slow_threshold_us
        .store(d.as_micros() as u64, Ordering::Relaxed);
}

/// The current global slow-op threshold.
pub fn slow_threshold() -> Duration {
    Duration::from_micros(
        registry().slow_threshold_us.load(Ordering::Relaxed),
    )
}

/// Log an op into the global slow-op ring if it met the threshold.
pub fn record_slow_op(
    op: &str,
    dur: Duration,
    trace_id: u64,
    span_id: u64,
    peer: &str,
) {
    registry().record_slow_op(op, dur, trace_id, span_id, peer);
}

// --------------------------------------------------------------------------
// Snapshot + exposition
// --------------------------------------------------------------------------

/// Sanitize a dotted metric name into Prometheus exposition form:
/// segments join with `_`, and an all-digit segment (an embedded instance
/// id like `shard.3.op_us`) is lifted out as a label keyed on the segment
/// before it — `shard.3.op_us` → `shard_op_us{shard="3"}`. Any character
/// outside `[a-zA-Z0-9_]` maps to `_`, and a leading digit is prefixed
/// with `_` per the exposition grammar.
pub fn sanitize_metric_name(name: &str) -> (String, Vec<(String, String)>) {
    let mut parts: Vec<&str> = Vec::new();
    let mut labels: Vec<(String, String)> = Vec::new();
    for seg in name.split('.') {
        let all_digit =
            !seg.is_empty() && seg.bytes().all(|b| b.is_ascii_digit());
        if all_digit && !parts.is_empty() {
            let key = sanitize_flat(parts[parts.len() - 1]);
            labels.push((key, seg.to_string()));
        } else {
            parts.push(seg);
        }
    }
    (sanitize_flat(&parts.join("_")), labels)
}

fn sanitize_flat(s: &str) -> String {
    let mut out: String = s
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// Escape a label value per the Prometheus text exposition grammar:
/// backslash, double-quote and newline.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Render a label set (plus an optional `le` bucket bound) as
/// `{k="v",le="x"}`, or the empty string when there are no labels.
fn format_labels(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Plain-value copy of the whole registry at one instant. Wire-encodable:
/// the KV protocol's `Telemetry` op ships one of these, and
/// [`render`](TelemetrySnapshot::render) is the text exposition the CLI
/// `stats` scenario and `benchlib` print.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TelemetrySnapshot {
    pub counters: Vec<(String, u64)>,
    /// `(name, (value, high_water))`.
    pub gauges: Vec<(String, (i64, i64))>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
    pub events: Vec<TraceEvent>,
    /// Ops that exceeded the slow threshold, oldest first.
    pub slow_ops: Vec<SlowOp>,
}

impl TelemetrySnapshot {
    /// Counter value by exact name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0, |(_, v)| *v)
    }

    /// Histogram by exact name.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Merge N per-node snapshots into one cluster view: counters sum,
    /// gauge values sum while high-waters take the per-node max,
    /// histograms add bucket-wise ([`HistogramSnapshot::absorb`]), and
    /// trace events / slow ops concatenate (the span-tree assembly in
    /// [`crate::metrics::cluster`] re-links them by span id).
    pub fn merge<'a, I>(snaps: I) -> TelemetrySnapshot
    where
        I: IntoIterator<Item = &'a TelemetrySnapshot>,
    {
        let mut counters: BTreeMap<String, u64> = BTreeMap::new();
        let mut gauges: BTreeMap<String, (i64, i64)> = BTreeMap::new();
        let mut histograms: BTreeMap<String, HistogramSnapshot> =
            BTreeMap::new();
        let mut events: Vec<TraceEvent> = Vec::new();
        let mut slow_ops: Vec<SlowOp> = Vec::new();
        for snap in snaps {
            for (name, v) in &snap.counters {
                *counters.entry(name.clone()).or_insert(0) += v;
            }
            for (name, (v, hwm)) in &snap.gauges {
                let e = gauges.entry(name.clone()).or_insert((0, i64::MIN));
                e.0 += v;
                e.1 = e.1.max(*hwm);
            }
            for (name, h) in &snap.histograms {
                histograms.entry(name.clone()).or_default().absorb(h);
            }
            events.extend(snap.events.iter().cloned());
            slow_ops.extend(snap.slow_ops.iter().cloned());
        }
        TelemetrySnapshot {
            counters: counters.into_iter().collect(),
            gauges: gauges.into_iter().collect(),
            histograms: histograms.into_iter().collect(),
            events,
            slow_ops,
        }
    }

    /// Dotted prefixes (`kv.client`, `shard`, ...) that have at least one
    /// non-zero counter, gauge high-water, or histogram observation — the
    /// "which subsystems are alive" view the acceptance gate checks.
    pub fn active_subsystems(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        let mut push = |name: &str| {
            let prefix = match name.split('.').next() {
                Some("kv") => {
                    name.splitn(3, '.').take(2).collect::<Vec<_>>().join(".")
                }
                Some(first) => first.to_string(),
                None => return,
            };
            if !out.contains(&prefix) {
                out.push(prefix);
            }
        };
        for (name, v) in &self.counters {
            if *v > 0 {
                push(name);
            }
        }
        for (name, (_, hwm)) in &self.gauges {
            if *hwm > 0 {
                push(name);
            }
        }
        for (name, h) in &self.histograms {
            if h.count > 0 {
                push(name);
            }
        }
        out.sort();
        out
    }

    /// Human-readable exposition: counters, gauges, histogram quantiles
    /// and the tail of the trace ring.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "== telemetry snapshot ==");
        if !self.counters.is_empty() {
            let _ = writeln!(s, "counters:");
            for (name, v) in &self.counters {
                let _ = writeln!(s, "  {name:<42} {v}");
            }
        }
        if !self.gauges.is_empty() {
            let _ = writeln!(s, "gauges (value / high-water):");
            for (name, (v, hwm)) in &self.gauges {
                let _ = writeln!(s, "  {name:<42} {v} / {hwm}");
            }
        }
        if !self.histograms.is_empty() {
            let _ = writeln!(
                s,
                "histograms (us): {:<26} {:>8} {:>9} {:>9} {:>9} {:>9} {:>9}",
                "", "count", "mean", "p50", "p95", "p99", "max"
            );
            for (name, h) in &self.histograms {
                let _ = writeln!(
                    s,
                    "  {name:<40} {:>8} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9}",
                    h.count,
                    h.mean(),
                    h.percentile(50.0),
                    h.percentile(95.0),
                    h.percentile(99.0),
                    h.max,
                );
            }
        }
        if !self.events.is_empty() {
            let tail = 16.min(self.events.len());
            let _ = writeln!(
                s,
                "trace events (last {tail} of {}):",
                self.events.len()
            );
            for ev in &self.events[self.events.len() - tail..] {
                let _ = writeln!(
                    s,
                    "  [trace {:016x} span {:x} < {:x}] {} {} ({}us)",
                    ev.trace_id, ev.span_id, ev.parent_span, ev.subsystem,
                    ev.name, ev.dur_us,
                );
            }
        }
        if !self.slow_ops.is_empty() {
            let tail = 16.min(self.slow_ops.len());
            let _ = writeln!(
                s,
                "slow ops (last {tail} of {}):",
                self.slow_ops.len()
            );
            for op in &self.slow_ops[self.slow_ops.len() - tail..] {
                let _ = writeln!(
                    s,
                    "  {:<16} {:>9}us  peer={} trace={:016x}",
                    op.op, op.dur_us, op.peer, op.trace_id,
                );
            }
        }
        s
    }

    /// Prometheus text exposition of the snapshot: sanitized names
    /// ([`sanitize_metric_name`]), one `# TYPE` line per family (several
    /// dotted names can collapse into one labeled family, e.g.
    /// `shard.0.op_us` + `shard.1.op_us` → `shard_op_us{shard="..."}`),
    /// gauges also exposing a `_high_water` family, histograms in
    /// cumulative-bucket form with `+Inf`, `_sum` and `_count`.
    pub fn render_prometheus(&self) -> String {
        // family name -> (type, sample lines); BTreeMap keeps the output
        // deterministic and groups label variants under one TYPE header.
        let mut families: BTreeMap<String, (&'static str, Vec<String>)> =
            BTreeMap::new();
        let mut push =
            |family: String, kind: &'static str, line: String| {
                families
                    .entry(family)
                    .or_insert_with(|| (kind, Vec::new()))
                    .1
                    .push(line);
            };
        for (name, v) in &self.counters {
            let (flat, labels) = sanitize_metric_name(name);
            let l = format_labels(&labels, None);
            push(flat.clone(), "counter", format!("{flat}{l} {v}"));
        }
        for (name, (v, hwm)) in &self.gauges {
            let (flat, labels) = sanitize_metric_name(name);
            let l = format_labels(&labels, None);
            push(flat.clone(), "gauge", format!("{flat}{l} {v}"));
            let hw = format!("{flat}_high_water");
            push(hw.clone(), "gauge", format!("{hw}{l} {hwm}"));
        }
        for (name, h) in &self.histograms {
            let (flat, labels) = sanitize_metric_name(name);
            let mut cum = 0u64;
            for &(lo, n) in &h.buckets {
                cum += n;
                let hi = bucket_hi(bucket_index(lo));
                let l = format_labels(&labels, Some(&hi.to_string()));
                push(
                    flat.clone(),
                    "histogram",
                    format!("{flat}_bucket{l} {cum}"),
                );
            }
            let l = format_labels(&labels, Some("+Inf"));
            push(
                flat.clone(),
                "histogram",
                format!("{flat}_bucket{l} {}", h.count),
            );
            let l = format_labels(&labels, None);
            push(flat.clone(), "histogram", format!("{flat}_sum{l} {}", h.sum));
            push(
                flat.clone(),
                "histogram",
                format!("{flat}_count{l} {}", h.count),
            );
        }
        let mut s = String::new();
        for (family, (kind, lines)) in &families {
            s.push_str(&format!("# TYPE {family} {kind}\n"));
            for line in lines {
                s.push_str(line);
                s.push('\n');
            }
        }
        s
    }
}

impl Encode for TelemetrySnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.counters.encode(buf);
        self.gauges.encode(buf);
        self.histograms.encode(buf);
        self.events.encode(buf);
        self.slow_ops.encode(buf);
    }
}

impl Decode for TelemetrySnapshot {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(TelemetrySnapshot {
            counters: Decode::decode(r)?,
            gauges: Decode::decode(r)?,
            histograms: Decode::decode(r)?,
            events: Decode::decode(r)?,
            slow_ops: Decode::decode(r)?,
        })
    }
}

/// Serializes unit tests that toggle [`set_enabled`] against tests that
/// assert recorded values (the whole lib test binary shares one process,
/// so a concurrent disable would silently drop a sibling's records).
#[cfg(test)]
pub(crate) fn test_enabled_guard() -> std::sync::MutexGuard<'static, ()> {
    static ENABLED_LOCK: Mutex<()> = Mutex::new(());
    ENABLED_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::test_enabled_guard as enabled_guard;

    #[test]
    fn bucket_index_bounds_are_consistent() {
        for v in [0u64, 1, 2, 3, 4, 5, 7, 8, 100, 1000, 1 << 20, u64::MAX] {
            let i = bucket_index(v);
            assert!(i < BUCKETS, "index {i} out of range for {v}");
            // Half-open [lo, hi), except the saturated top bucket which
            // closes at u64::MAX inclusive.
            assert!(
                bucket_lo(i) <= v
                    && (v < bucket_hi(i) || bucket_hi(i) == u64::MAX),
                "{v} outside [{}, {}) (bucket {i})",
                bucket_lo(i),
                bucket_hi(i)
            );
        }
        // Bucket bounds ascend with the index over the used range.
        let mut prev = 0;
        for i in (SUB * 2)..BUCKETS {
            assert!(bucket_lo(i) > prev, "bucket {i} not ascending");
            prev = bucket_lo(i);
        }
    }

    #[test]
    fn histogram_records_and_estimates() {
        let _g = enabled_guard();
        let h = Histogram::default();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.sum, 500_500);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 1000);
        // Log-bucket estimates are within one bucket width (~19%).
        let p50 = s.percentile(50.0);
        assert!((400.0..=650.0).contains(&p50), "p50 {p50}");
        let p99 = s.percentile(99.0);
        assert!((800.0..=1200.0).contains(&p99), "p99 {p99}");
        assert!(s.mean() > 400.0 && s.mean() < 600.0);
    }

    #[test]
    fn histogram_concurrent_recording_conserves_count_and_sum() {
        let _g = enabled_guard();
        let h = Arc::new(Histogram::default());
        let threads = 8;
        let per = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        h.record(t * per + i);
                    }
                })
            })
            .collect();
        for handle in handles {
            handle.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, threads * per);
        let expect: u64 = (0..threads * per).sum();
        assert_eq!(s.sum, expect);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, threads * per - 1);
        let bucket_total: u64 = s.buckets.iter().map(|(_, n)| n).sum();
        assert_eq!(bucket_total, threads * per);
    }

    #[test]
    fn percentiles_are_monotone() {
        let _g = enabled_guard();
        let h = Histogram::default();
        // A skewed distribution across many octaves.
        for i in 0..5000u64 {
            h.record(i * i % 100_000);
        }
        let s = h.snapshot();
        let qs = [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0];
        let vals: Vec<f64> = qs.iter().map(|&q| s.percentile(q)).collect();
        for w in vals.windows(2) {
            assert!(w[0] <= w[1], "percentiles not monotone: {vals:?}");
        }
    }

    #[test]
    fn gauge_tracks_high_water() {
        let _g = enabled_guard();
        let g = Gauge::default();
        g.add(5);
        g.add(3);
        g.add(-6);
        assert_eq!(g.get(), 2);
        assert_eq!(g.high_water(), 8);
        g.set(1);
        assert_eq!(g.high_water(), 8);
    }

    #[test]
    fn registry_returns_same_handle() {
        let _g = enabled_guard();
        let c1 = counter("test.telemetry.reuse");
        let c2 = counter("test.telemetry.reuse");
        c1.add(2);
        c2.add(3);
        assert_eq!(c2.get(), c1.get());
        assert!(c1.get() >= 5);
    }

    #[test]
    fn mirrored_counter_keeps_local_view() {
        let _g = enabled_guard();
        let a = MirroredCounter::new("test.telemetry.mirror");
        let b = MirroredCounter::new("test.telemetry.mirror");
        a.add(2);
        b.add(5);
        assert_eq!(a.get(), 2);
        assert_eq!(b.get(), 5);
        assert!(counter("test.telemetry.mirror").get() >= 7);
    }

    #[test]
    fn trace_guard_scopes_and_restores() {
        assert_eq!(current_trace(), None);
        let g = start_trace("outer");
        let outer = current_trace().unwrap();
        assert_eq!(outer, g.ctx());
        {
            let inner = TraceCtx { trace_id: 42, span_id: 7 };
            let _g2 = enter_trace(inner);
            assert_eq!(current_trace(), Some(inner));
        }
        assert_eq!(current_trace(), Some(outer));
        drop(g);
        assert_eq!(current_trace(), None);
    }

    #[test]
    fn ring_bounds_and_orders_events() {
        let ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.push(TraceEvent {
                seq: 0,
                trace_id: i,
                span_id: i,
                parent_span: 0,
                subsystem: "test".into(),
                name: "ev".into(),
                start_us: i,
                dur_us: 0,
            });
        }
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0].trace_id, 6);
        assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn snapshot_roundtrips_through_codec() {
        let _g = enabled_guard();
        let h = Histogram::default();
        for v in [1u64, 10, 100, 1000, 10_000] {
            h.record(v);
        }
        let snap = TelemetrySnapshot {
            counters: vec![("a.b".into(), 7)],
            gauges: vec![("c.d".into(), (3, 9))],
            histograms: vec![("e.f".into(), h.snapshot())],
            events: vec![TraceEvent {
                seq: 1,
                trace_id: 2,
                span_id: 3,
                parent_span: 4,
                subsystem: "kv.client".into(),
                name: "get".into(),
                start_us: 1_000_000,
                dur_us: 250,
            }],
            slow_ops: vec![SlowOp {
                seq: 0,
                start_us: 1_000_000,
                dur_us: 5000,
                op: "get".into(),
                trace_id: 2,
                span_id: 3,
                peer: "kv".into(),
            }],
        };
        let back = TelemetrySnapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(snap, back);
        let text = back.render();
        assert!(text.contains("a.b"));
        assert!(text.contains("kv.client"));
    }

    #[test]
    fn active_subsystems_groups_by_prefix() {
        let snap = TelemetrySnapshot {
            counters: vec![
                ("kv.client.ops".into(), 1),
                ("kv.server.frames_in".into(), 2),
                ("shard.router.fallbacks".into(), 0),
                ("reactor.jobs".into(), 3),
            ],
            gauges: vec![("watch.armed".into(), (0, 5))],
            histograms: Vec::new(),
            events: Vec::new(),
            slow_ops: Vec::new(),
        };
        let subs = snap.active_subsystems();
        assert_eq!(
            subs,
            vec!["kv.client", "kv.server", "reactor", "watch"]
        );
    }

    #[test]
    fn merged_counters_sum_and_gauge_high_water_takes_max() {
        let a = TelemetrySnapshot {
            counters: vec![("ops".into(), 7), ("x.only_a".into(), 2)],
            gauges: vec![("depth".into(), (3, 10))],
            ..Default::default()
        };
        let b = TelemetrySnapshot {
            counters: vec![("ops".into(), 5)],
            gauges: vec![("depth".into(), (4, 6))],
            ..Default::default()
        };
        let m = TelemetrySnapshot::merge([&a, &b]);
        assert_eq!(m.counter("ops"), 12);
        assert_eq!(m.counter("x.only_a"), 2);
        let (_, (v, hwm)) = m
            .gauges
            .iter()
            .find(|(n, _)| n == "depth")
            .cloned()
            .unwrap();
        assert_eq!(v, 7, "gauge values sum");
        assert_eq!(hwm, 10, "high-water takes the max");
    }

    #[test]
    fn merged_histogram_percentiles_bracket_per_node() {
        let _g = enabled_guard();
        let ha = Histogram::default();
        let hb = Histogram::default();
        for v in 1..=1000u64 {
            ha.record(v);
        }
        for v in 500..=2500u64 {
            hb.record(v);
        }
        let (sa, sb) = (ha.snapshot(), hb.snapshot());
        let mut merged = sa.clone();
        merged.absorb(&sb);
        assert_eq!(merged.count, sa.count + sb.count);
        assert_eq!(merged.sum, sa.sum + sb.sum);
        assert_eq!(merged.min, sa.min.min(sb.min));
        assert_eq!(merged.max, sa.max.max(sb.max));
        let total: u64 = merged.buckets.iter().map(|(_, n)| n).sum();
        assert_eq!(total, merged.count, "bucket counts conserved");
        // The q-th percentile of a union lies between the per-node q-th
        // percentiles; allow one log-bucket width (~19%) of slack for the
        // estimate.
        for q in [25.0, 50.0, 90.0, 95.0, 99.0] {
            let (pa, pb) = (sa.percentile(q), sb.percentile(q));
            let pm = merged.percentile(q);
            let (lo, hi) = (pa.min(pb), pa.max(pb));
            assert!(
                pm >= lo * 0.8 && pm <= hi * 1.2,
                "p{q}: merged {pm} outside [{lo}, {hi}]"
            );
        }
    }

    #[test]
    fn merge_is_order_insensitive_for_metrics() {
        let _g = enabled_guard();
        let h = Histogram::default();
        for v in [1u64, 50, 900, 7000] {
            h.record(v);
        }
        let a = TelemetrySnapshot {
            counters: vec![("c".into(), 1)],
            gauges: vec![("g".into(), (1, 2))],
            histograms: vec![("h".into(), h.snapshot())],
            ..Default::default()
        };
        let b = TelemetrySnapshot {
            counters: vec![("c".into(), 10)],
            gauges: vec![("g".into(), (5, 9))],
            histograms: vec![("h".into(), h.snapshot())],
            ..Default::default()
        };
        let ab = TelemetrySnapshot::merge([&a, &b]);
        let ba = TelemetrySnapshot::merge([&b, &a]);
        assert_eq!(ab.counters, ba.counters);
        assert_eq!(ab.gauges, ba.gauges);
        assert_eq!(ab.histograms, ba.histograms);
    }

    #[test]
    fn sanitize_lifts_ids_into_labels() {
        assert_eq!(
            sanitize_metric_name("kv.client.ops"),
            ("kv_client_ops".to_string(), vec![])
        );
        let (name, labels) = sanitize_metric_name("shard.3.op_us");
        assert_eq!(name, "shard_op_us");
        assert_eq!(labels, vec![("shard".to_string(), "3".to_string())]);
        let (name, labels) = sanitize_metric_name("broker.12.produce");
        assert_eq!(name, "broker_produce");
        assert_eq!(labels, vec![("broker".to_string(), "12".to_string())]);
        // Leading digit and odd characters are neutralized.
        assert_eq!(sanitize_metric_name("9lives-x").0, "_9lives_x");
    }

    #[test]
    fn label_values_escape_for_exposition() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(
            escape_label_value("a\"b\\c\nd"),
            "a\\\"b\\\\c\\nd"
        );
        let labeled = format_labels(
            &[("peer".to_string(), "10.0.0.1:\"x\"".to_string())],
            None,
        );
        assert_eq!(labeled, "{peer=\"10.0.0.1:\\\"x\\\"\"}");
    }

    #[test]
    fn prometheus_exposition_groups_families_and_labels_shards() {
        let _g = enabled_guard();
        let h0 = Histogram::default();
        let h3 = Histogram::default();
        h0.record(10);
        h3.record(100);
        let snap = TelemetrySnapshot {
            counters: vec![("kv.client.ops".into(), 42)],
            gauges: vec![("kv.client.inflight".into(), (2, 8))],
            histograms: vec![
                ("shard.0.op_us".into(), h0.snapshot()),
                ("shard.3.op_us".into(), h3.snapshot()),
            ],
            ..Default::default()
        };
        let text = snap.render_prometheus();
        assert!(text.contains("# TYPE kv_client_ops counter"));
        assert!(text.contains("kv_client_ops 42"));
        assert!(text.contains("kv_client_inflight 2"));
        assert!(text.contains("kv_client_inflight_high_water 8"));
        // Both shard histograms collapse into ONE labeled family with a
        // single TYPE header.
        assert_eq!(
            text.matches("# TYPE shard_op_us histogram").count(),
            1
        );
        assert!(text.contains("shard_op_us_bucket{shard=\"0\",le="));
        assert!(text.contains("shard_op_us_bucket{shard=\"3\",le="));
        assert!(text.contains("shard_op_us_bucket{shard=\"3\",le=\"+Inf\"} 1"));
        assert!(text.contains("shard_op_us_sum{shard=\"3\"} 100"));
        assert!(text.contains("shard_op_us_count{shard=\"3\"} 1"));
    }

    #[test]
    fn trace_ring_overflow_is_counted_in_snapshot() {
        let _g = enabled_guard();
        let reg = Registry::new();
        for i in 0..(RING_CAP as u64 + 5) {
            reg.ring.push(TraceEvent {
                seq: 0,
                trace_id: i,
                span_id: i,
                parent_span: 0,
                subsystem: "test".into(),
                name: "ev".into(),
                start_us: i,
                dur_us: 0,
            });
        }
        let snap = reg.snapshot();
        assert_eq!(snap.counter("telemetry.trace.dropped"), 5);
        assert!(
            snap.active_subsystems().contains(&"telemetry".to_string()),
            "dropped counter surfaces the telemetry subsystem"
        );
        assert!(snap.render().contains("telemetry.trace.dropped"));
    }

    #[test]
    fn slow_op_log_applies_threshold_and_bounds() {
        let _g = enabled_guard();
        let reg = Registry::new();
        // Default threshold is 1ms: fast ops never land.
        reg.record_slow_op("fast", Duration::from_micros(200), 0, 0, "kv");
        assert!(reg.snapshot().slow_ops.is_empty());
        reg.record_slow_op("slow", Duration::from_millis(5), 7, 9, "kv");
        let snap = reg.snapshot();
        assert_eq!(snap.slow_ops.len(), 1);
        let op = &snap.slow_ops[0];
        assert_eq!(op.op, "slow");
        assert_eq!(op.dur_us, 5000);
        assert_eq!((op.trace_id, op.span_id), (7, 9));
        assert_eq!(op.peer, "kv");
        assert!(snap.render().contains("slow ops"));
        // The ring is bounded at SLOW_CAP, oldest evicted first.
        for i in 0..(SLOW_CAP as u64 + 10) {
            reg.record_slow_op(
                "bulk",
                Duration::from_millis(2),
                i,
                0,
                "kv",
            );
        }
        let snap = reg.snapshot();
        assert_eq!(snap.slow_ops.len(), SLOW_CAP);
        assert!(
            snap.slow_ops.windows(2).all(|w| w[0].seq < w[1].seq),
            "slow ops ordered by seq"
        );
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = enabled_guard();
        let h = Histogram::default();
        let c = Counter::default();
        set_enabled(false);
        h.record(5);
        c.incr();
        set_enabled(true);
        assert_eq!(h.count(), 0);
        assert_eq!(c.get(), 0);
        h.record(5);
        c.incr();
        assert_eq!(h.count(), 1);
        assert_eq!(c.get(), 1);
    }
}
