//! Unified telemetry plane: a process-global registry of named counters,
//! gauges and log-bucketed latency histograms, a bounded trace-event ring,
//! and wire-level trace-context propagation.
//!
//! Every fabric in the stack reports here — the pipelined KV client
//! (`kv.client.*`), the KV server (`kv.server.*`), the shard router
//! (`shard.*`), the elastic rebalancer (`rebalance.*`), the reactor pool
//! (`reactor.*`), the watch/notify plane (`watch.*`), the broker fabric
//! (`broker.*`) and the typed [`Store`](crate::store::Store)
//! (`store.*`) — so one [`snapshot`] covers the whole process. The
//! primitives are lock-free on the hot path: a counter bump is one relaxed
//! `fetch_add`, a histogram record is three relaxed atomics plus one
//! bucket increment, and nothing ever takes a lock while recording.
//!
//! Latency histograms are **log-bucketed**: four sub-buckets per power of
//! two (≤ ~19% relative bucket width) over the full `u64` range, recorded
//! in microseconds. Quantiles are estimated by expanding the buckets into
//! a bounded sorted sample set and delegating to the same
//! [`percentile`](crate::metrics::percentile) machinery the bench harness
//! uses, so p50/p95/p99 here and in `benchlib` mean the same thing.
//!
//! **Trace propagation**: [`start_trace`] opens a trace on the calling
//! thread (RAII [`TraceGuard`] clears it). While a trace is current, the
//! pipelined KV client wraps each submitted request in a
//! [`Request::Traced`](crate::kv::Request::Traced) envelope; the server
//! unwraps it and stamps a server-side span carrying the same trace id, so
//! one logical op can be followed client → shard router → replica → KV
//! engine → notify push across process and wire boundaries. Span events
//! land in a bounded ring buffer ([`TelemetrySnapshot::events`]) — only
//! traced ops pay the ring's mutex; untraced hot paths never touch it.
//!
//! Recording can be disabled process-wide ([`set_enabled`]) — the
//! overhead gate in `benches/telemetry.rs` measures the instrumented hot
//! path against that baseline.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Duration;

use crate::codec::{Decode, Encode, Reader};
use crate::error::Result;

use super::stats::percentile;

// --------------------------------------------------------------------------
// Primitives
// --------------------------------------------------------------------------

/// Whether telemetry recording is active (default: yes). One relaxed load
/// on every record; flipping it off turns every primitive into a no-op —
/// the uninstrumented baseline the overhead bench compares against.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable or disable all recording process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether recording is active.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotonic named counter: one relaxed `fetch_add` per bump.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Signed gauge with a high-water mark (e.g. queue depth, in-flight ops).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
    hwm: AtomicI64,
}

impl Gauge {
    /// Move the gauge by `delta`, raising the high-water mark.
    pub fn add(&self, delta: i64) {
        if !enabled() {
            return;
        }
        let now = self.v.fetch_add(delta, Ordering::Relaxed) + delta;
        self.hwm.fetch_max(now, Ordering::Relaxed);
    }

    /// Set the gauge to an observed level, raising the high-water mark.
    pub fn set(&self, v: i64) {
        if !enabled() {
            return;
        }
        self.v.store(v, Ordering::Relaxed);
        self.hwm.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn high_water(&self) -> i64 {
        self.hwm.load(Ordering::Relaxed)
    }
}

/// Sub-buckets per power of two: 4 → bucket width ≤ ~19% of its value.
const SUB_BITS: u32 = 2;
const SUB: usize = 1 << SUB_BITS;
/// 64 octaves × 4 sub-buckets covers the full `u64` range.
const BUCKETS: usize = 64 * SUB;

/// Index of the log bucket holding `v`.
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let lz = 63 - v.leading_zeros();
    let sub = ((v >> (lz - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    (lz as usize) * SUB + sub
}

/// Lower bound of bucket `i` (its representative range is `[lo, hi)`).
fn bucket_lo(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let lz = (i / SUB) as u32;
    let sub = (i % SUB) as u64;
    (1u64 << lz) + sub * (1u64 << (lz - SUB_BITS))
}

/// Upper bound of bucket `i` (saturating: the top octave's bound would
/// overflow `u64`, so it closes at `u64::MAX` inclusive).
fn bucket_hi(i: usize) -> u64 {
    if i < SUB {
        return i as u64 + 1;
    }
    let lz = (i / SUB) as u32;
    bucket_lo(i).saturating_add(1u64 << (lz - SUB_BITS))
}

/// Lock-free log-bucketed histogram of `u64` observations (latencies in
/// microseconds by convention). Recording is four relaxed atomic ops; no
/// lock is ever taken. Concurrent recorders conserve both the total count
/// and the total sum exactly.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: Box<[AtomicU64; BUCKETS]>,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: Box::new([0u64; BUCKETS].map(AtomicU64::new)),
        }
    }
}

impl Histogram {
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration in microseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Plain-value copy at one instant. Taken while recorders are live the
    /// fields may be mutually slightly torn (count vs sum), like every
    /// relaxed-counter snapshot in the stack.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<(u64, u64)> = (0..BUCKETS)
            .filter_map(|i| {
                let n = self.buckets[i].load(Ordering::Relaxed);
                (n > 0).then(|| (bucket_lo(i), n))
            })
            .collect();
        let count = self.count();
        HistogramSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Histogram({} samples)", self.count())
    }
}

/// Plain-value copy of a [`Histogram`]: totals plus the non-empty buckets
/// as `(bucket_lower_bound, count)` pairs. Wire-encodable.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub buckets: Vec<(u64, u64)>,
}

/// Cap on the expanded sample set quantiles are computed over; buckets
/// with more observations than fit are scaled down proportionally.
const QUANTILE_SAMPLES: usize = 4096;

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Estimate the `q`-th percentile (`q` in `[0, 100]`) by expanding the
    /// log buckets into a bounded sorted sample set (bucket midpoints,
    /// weighted by count) and delegating to the shared
    /// [`percentile`](crate::metrics::percentile) interpolation. Accuracy
    /// is bounded by the bucket width (≤ ~19%); the exact `min`/`max`
    /// fields bound the tails.
    pub fn percentile(&self, q: f64) -> f64 {
        let samples = self.quantile_samples();
        percentile(&samples, q)
    }

    fn quantile_samples(&self) -> Vec<f64> {
        if self.count == 0 {
            return Vec::new();
        }
        // Scale so the expansion stays bounded no matter how many
        // observations landed; small histograms expand exactly.
        let scale = (self.count as f64 / QUANTILE_SAMPLES as f64).max(1.0);
        let mut out = Vec::new();
        for &(lo, n) in &self.buckets {
            let hi = bucket_hi(bucket_index(lo));
            let mid = (lo as f64 + hi as f64) / 2.0;
            let reps = ((n as f64 / scale).round() as usize).max(1);
            out.extend(std::iter::repeat(mid).take(reps));
        }
        // Buckets are emitted in index order, midpoints ascend with it.
        out
    }
}

impl Encode for HistogramSnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.count.encode(buf);
        self.sum.encode(buf);
        self.min.encode(buf);
        self.max.encode(buf);
        self.buckets.encode(buf);
    }
}

impl Decode for HistogramSnapshot {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(HistogramSnapshot {
            count: Decode::decode(r)?,
            sum: Decode::decode(r)?,
            min: Decode::decode(r)?,
            max: Decode::decode(r)?,
            buckets: Decode::decode(r)?,
        })
    }
}

/// A per-instance counter that mirrors every bump into a process-global
/// registry counter: instance accessors keep their exact local values
/// (tests and per-fabric diagnostics) while the registry aggregates
/// across all instances for the fleet-wide snapshot.
#[derive(Debug)]
pub struct MirroredCounter {
    local: AtomicU64,
    global: Arc<Counter>,
}

impl MirroredCounter {
    /// `global_name` is the registry counter every bump aggregates into.
    pub fn new(global_name: &str) -> MirroredCounter {
        MirroredCounter {
            local: AtomicU64::new(0),
            global: counter(global_name),
        }
    }

    pub fn incr(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.local.fetch_add(n, Ordering::Relaxed);
        self.global.add(n);
    }

    /// The instance-local total (unaffected by other instances).
    pub fn get(&self) -> u64 {
        self.local.load(Ordering::Relaxed)
    }
}

// --------------------------------------------------------------------------
// Trace context
// --------------------------------------------------------------------------

/// Identity of the current trace on this thread: which logical operation
/// (`trace_id`) and which hop within it (`span_id`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

thread_local! {
    static CURRENT_TRACE: std::cell::Cell<Option<TraceCtx>> =
        const { std::cell::Cell::new(None) };
}

fn ids() -> &'static AtomicU64 {
    static NEXT: OnceLock<AtomicU64> = OnceLock::new();
    NEXT.get_or_init(|| {
        // Seed from wall clock + pid so ids from different processes on a
        // shared fabric are distinguishable; uniqueness within a process
        // comes from the increment.
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1)
            ^ (u64::from(std::process::id()) << 32);
        AtomicU64::new(seed | 1)
    })
}

/// A fresh span id (unique within the process).
pub fn next_span_id() -> u64 {
    ids().fetch_add(1, Ordering::Relaxed)
}

/// The trace context current on this thread, if any.
pub fn current_trace() -> Option<TraceCtx> {
    CURRENT_TRACE.with(|c| c.get())
}

/// Open a new trace on the calling thread and return the guard that
/// scopes it: while the guard lives, ops submitted from this thread are
/// wrapped in `Request::Traced` envelopes on the wire. Dropping the guard
/// restores whatever trace (or none) was current before.
pub fn start_trace(name: &str) -> TraceGuard {
    let ctx = TraceCtx { trace_id: next_span_id(), span_id: next_span_id() };
    trace_event(ctx.trace_id, ctx.span_id, 0, "trace", name);
    let prev = CURRENT_TRACE.with(|c| c.replace(Some(ctx)));
    TraceGuard { prev, ctx }
}

/// Make `ctx` current for the guard's lifetime (server-side span adoption,
/// or carrying a context across a pool-worker hop).
pub fn enter_trace(ctx: TraceCtx) -> TraceGuard {
    let prev = CURRENT_TRACE.with(|c| c.replace(Some(ctx)));
    TraceGuard { prev, ctx }
}

/// RAII scope of a current trace; restores the previous context on drop.
#[derive(Debug)]
pub struct TraceGuard {
    prev: Option<TraceCtx>,
    ctx: TraceCtx,
}

impl TraceGuard {
    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        CURRENT_TRACE.with(|c| c.set(self.prev));
    }
}

/// One structured span event in the trace ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Monotonic sequence within this process (ring ordering).
    pub seq: u64,
    pub trace_id: u64,
    pub span_id: u64,
    /// Span this one descends from (0 = root).
    pub parent_span: u64,
    /// Which fabric recorded it (`kv.client`, `kv.server`, ...).
    pub subsystem: String,
    /// Operation label (`get`, `set`, `notify`, ...).
    pub name: String,
}

impl Encode for TraceEvent {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.seq.encode(buf);
        self.trace_id.encode(buf);
        self.span_id.encode(buf);
        self.parent_span.encode(buf);
        self.subsystem.encode(buf);
        self.name.encode(buf);
    }
}

impl Decode for TraceEvent {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(TraceEvent {
            seq: Decode::decode(r)?,
            trace_id: Decode::decode(r)?,
            span_id: Decode::decode(r)?,
            parent_span: Decode::decode(r)?,
            subsystem: Decode::decode(r)?,
            name: Decode::decode(r)?,
        })
    }
}

/// Bounded ring of recent trace events. Only traced ops push here, so the
/// mutex is off the untraced hot path entirely.
struct TraceRing {
    events: Mutex<std::collections::VecDeque<TraceEvent>>,
    seq: AtomicU64,
    cap: usize,
}

impl TraceRing {
    fn new(cap: usize) -> TraceRing {
        TraceRing {
            events: Mutex::new(std::collections::VecDeque::with_capacity(cap)),
            seq: AtomicU64::new(0),
            cap,
        }
    }

    fn push(&self, mut ev: TraceEvent) {
        ev.seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.events.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(ev);
    }

    fn snapshot(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().iter().cloned().collect()
    }
}

/// Record a span event into the global trace ring.
pub fn trace_event(
    trace_id: u64,
    span_id: u64,
    parent_span: u64,
    subsystem: &str,
    name: &str,
) {
    if !enabled() {
        return;
    }
    registry().ring.push(TraceEvent {
        seq: 0,
        trace_id,
        span_id,
        parent_span,
        subsystem: subsystem.to_string(),
        name: name.to_string(),
    });
}

// --------------------------------------------------------------------------
// Registry
// --------------------------------------------------------------------------

/// Trace events retained (older ones are dropped).
const RING_CAP: usize = 1024;

/// The process-global metric registry: named counters, gauges and
/// histograms plus the trace ring. Lookup is a read-lock + map probe;
/// hot paths cache the returned `Arc` handles and never look up again.
pub struct Registry {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
    ring: TraceRing,
}

fn get_or_create<T: Default>(
    map: &RwLock<BTreeMap<String, Arc<T>>>,
    name: &str,
) -> Arc<T> {
    if let Some(v) = map.read().unwrap().get(name) {
        return v.clone();
    }
    map.write()
        .unwrap()
        .entry(name.to_string())
        .or_default()
        .clone()
}

impl Registry {
    fn new() -> Registry {
        Registry {
            counters: RwLock::new(BTreeMap::new()),
            gauges: RwLock::new(BTreeMap::new()),
            histograms: RwLock::new(BTreeMap::new()),
            ring: TraceRing::new(RING_CAP),
        }
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        get_or_create(&self.counters, name)
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        get_or_create(&self.gauges, name)
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        get_or_create(&self.histograms, name)
    }

    /// Plain-value copy of every metric plus the trace ring.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            counters: self
                .counters
                .read()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .read()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), (v.get(), v.high_water())))
                .collect(),
            histograms: self
                .histograms
                .read()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
            events: self.ring.snapshot(),
        }
    }
}

/// The process-global registry.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// Get or create the global counter `name`.
pub fn counter(name: &str) -> Arc<Counter> {
    registry().counter(name)
}

/// Get or create the global gauge `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    registry().gauge(name)
}

/// Get or create the global histogram `name`.
pub fn histogram(name: &str) -> Arc<Histogram> {
    registry().histogram(name)
}

/// Snapshot the global registry.
pub fn snapshot() -> TelemetrySnapshot {
    registry().snapshot()
}

// --------------------------------------------------------------------------
// Snapshot + exposition
// --------------------------------------------------------------------------

/// Plain-value copy of the whole registry at one instant. Wire-encodable:
/// the KV protocol's `Telemetry` op ships one of these, and
/// [`render`](TelemetrySnapshot::render) is the text exposition the CLI
/// `stats` scenario and `benchlib` print.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TelemetrySnapshot {
    pub counters: Vec<(String, u64)>,
    /// `(name, (value, high_water))`.
    pub gauges: Vec<(String, (i64, i64))>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
    pub events: Vec<TraceEvent>,
}

impl TelemetrySnapshot {
    /// Counter value by exact name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0, |(_, v)| *v)
    }

    /// Histogram by exact name.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Dotted prefixes (`kv.client`, `shard`, ...) that have at least one
    /// non-zero counter, gauge high-water, or histogram observation — the
    /// "which subsystems are alive" view the acceptance gate checks.
    pub fn active_subsystems(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        let mut push = |name: &str| {
            let prefix = match name.split('.').next() {
                Some("kv") => {
                    name.splitn(3, '.').take(2).collect::<Vec<_>>().join(".")
                }
                Some(first) => first.to_string(),
                None => return,
            };
            if !out.contains(&prefix) {
                out.push(prefix);
            }
        };
        for (name, v) in &self.counters {
            if *v > 0 {
                push(name);
            }
        }
        for (name, (_, hwm)) in &self.gauges {
            if *hwm > 0 {
                push(name);
            }
        }
        for (name, h) in &self.histograms {
            if h.count > 0 {
                push(name);
            }
        }
        out.sort();
        out
    }

    /// Human-readable exposition: counters, gauges, histogram quantiles
    /// and the tail of the trace ring.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "== telemetry snapshot ==");
        if !self.counters.is_empty() {
            let _ = writeln!(s, "counters:");
            for (name, v) in &self.counters {
                let _ = writeln!(s, "  {name:<42} {v}");
            }
        }
        if !self.gauges.is_empty() {
            let _ = writeln!(s, "gauges (value / high-water):");
            for (name, (v, hwm)) in &self.gauges {
                let _ = writeln!(s, "  {name:<42} {v} / {hwm}");
            }
        }
        if !self.histograms.is_empty() {
            let _ = writeln!(
                s,
                "histograms (us): {:<26} {:>8} {:>9} {:>9} {:>9} {:>9} {:>9}",
                "", "count", "mean", "p50", "p95", "p99", "max"
            );
            for (name, h) in &self.histograms {
                let _ = writeln!(
                    s,
                    "  {name:<40} {:>8} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9}",
                    h.count,
                    h.mean(),
                    h.percentile(50.0),
                    h.percentile(95.0),
                    h.percentile(99.0),
                    h.max,
                );
            }
        }
        if !self.events.is_empty() {
            let tail = 16.min(self.events.len());
            let _ = writeln!(
                s,
                "trace events (last {tail} of {}):",
                self.events.len()
            );
            for ev in &self.events[self.events.len() - tail..] {
                let _ = writeln!(
                    s,
                    "  [trace {:016x} span {:x} < {:x}] {} {}",
                    ev.trace_id, ev.span_id, ev.parent_span, ev.subsystem,
                    ev.name,
                );
            }
        }
        s
    }
}

impl Encode for TelemetrySnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.counters.encode(buf);
        self.gauges.encode(buf);
        self.histograms.encode(buf);
        self.events.encode(buf);
    }
}

impl Decode for TelemetrySnapshot {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(TelemetrySnapshot {
            counters: Decode::decode(r)?,
            gauges: Decode::decode(r)?,
            histograms: Decode::decode(r)?,
            events: Decode::decode(r)?,
        })
    }
}

/// Serializes unit tests that toggle [`set_enabled`] against tests that
/// assert recorded values (the whole lib test binary shares one process,
/// so a concurrent disable would silently drop a sibling's records).
#[cfg(test)]
pub(crate) fn test_enabled_guard() -> std::sync::MutexGuard<'static, ()> {
    static ENABLED_LOCK: Mutex<()> = Mutex::new(());
    ENABLED_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::test_enabled_guard as enabled_guard;

    #[test]
    fn bucket_index_bounds_are_consistent() {
        for v in [0u64, 1, 2, 3, 4, 5, 7, 8, 100, 1000, 1 << 20, u64::MAX] {
            let i = bucket_index(v);
            assert!(i < BUCKETS, "index {i} out of range for {v}");
            // Half-open [lo, hi), except the saturated top bucket which
            // closes at u64::MAX inclusive.
            assert!(
                bucket_lo(i) <= v
                    && (v < bucket_hi(i) || bucket_hi(i) == u64::MAX),
                "{v} outside [{}, {}) (bucket {i})",
                bucket_lo(i),
                bucket_hi(i)
            );
        }
        // Bucket bounds ascend with the index over the used range.
        let mut prev = 0;
        for i in (SUB * 2)..BUCKETS {
            assert!(bucket_lo(i) > prev, "bucket {i} not ascending");
            prev = bucket_lo(i);
        }
    }

    #[test]
    fn histogram_records_and_estimates() {
        let _g = enabled_guard();
        let h = Histogram::default();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.sum, 500_500);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 1000);
        // Log-bucket estimates are within one bucket width (~19%).
        let p50 = s.percentile(50.0);
        assert!((400.0..=650.0).contains(&p50), "p50 {p50}");
        let p99 = s.percentile(99.0);
        assert!((800.0..=1200.0).contains(&p99), "p99 {p99}");
        assert!(s.mean() > 400.0 && s.mean() < 600.0);
    }

    #[test]
    fn histogram_concurrent_recording_conserves_count_and_sum() {
        let _g = enabled_guard();
        let h = Arc::new(Histogram::default());
        let threads = 8;
        let per = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        h.record(t * per + i);
                    }
                })
            })
            .collect();
        for handle in handles {
            handle.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, threads * per);
        let expect: u64 = (0..threads * per).sum();
        assert_eq!(s.sum, expect);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, threads * per - 1);
        let bucket_total: u64 = s.buckets.iter().map(|(_, n)| n).sum();
        assert_eq!(bucket_total, threads * per);
    }

    #[test]
    fn percentiles_are_monotone() {
        let _g = enabled_guard();
        let h = Histogram::default();
        // A skewed distribution across many octaves.
        for i in 0..5000u64 {
            h.record(i * i % 100_000);
        }
        let s = h.snapshot();
        let qs = [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0];
        let vals: Vec<f64> = qs.iter().map(|&q| s.percentile(q)).collect();
        for w in vals.windows(2) {
            assert!(w[0] <= w[1], "percentiles not monotone: {vals:?}");
        }
    }

    #[test]
    fn gauge_tracks_high_water() {
        let _g = enabled_guard();
        let g = Gauge::default();
        g.add(5);
        g.add(3);
        g.add(-6);
        assert_eq!(g.get(), 2);
        assert_eq!(g.high_water(), 8);
        g.set(1);
        assert_eq!(g.high_water(), 8);
    }

    #[test]
    fn registry_returns_same_handle() {
        let _g = enabled_guard();
        let c1 = counter("test.telemetry.reuse");
        let c2 = counter("test.telemetry.reuse");
        c1.add(2);
        c2.add(3);
        assert_eq!(c2.get(), c1.get());
        assert!(c1.get() >= 5);
    }

    #[test]
    fn mirrored_counter_keeps_local_view() {
        let _g = enabled_guard();
        let a = MirroredCounter::new("test.telemetry.mirror");
        let b = MirroredCounter::new("test.telemetry.mirror");
        a.add(2);
        b.add(5);
        assert_eq!(a.get(), 2);
        assert_eq!(b.get(), 5);
        assert!(counter("test.telemetry.mirror").get() >= 7);
    }

    #[test]
    fn trace_guard_scopes_and_restores() {
        assert_eq!(current_trace(), None);
        let g = start_trace("outer");
        let outer = current_trace().unwrap();
        assert_eq!(outer, g.ctx());
        {
            let inner = TraceCtx { trace_id: 42, span_id: 7 };
            let _g2 = enter_trace(inner);
            assert_eq!(current_trace(), Some(inner));
        }
        assert_eq!(current_trace(), Some(outer));
        drop(g);
        assert_eq!(current_trace(), None);
    }

    #[test]
    fn ring_bounds_and_orders_events() {
        let ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.push(TraceEvent {
                seq: 0,
                trace_id: i,
                span_id: i,
                parent_span: 0,
                subsystem: "test".into(),
                name: "ev".into(),
            });
        }
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0].trace_id, 6);
        assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn snapshot_roundtrips_through_codec() {
        let _g = enabled_guard();
        let h = Histogram::default();
        for v in [1u64, 10, 100, 1000, 10_000] {
            h.record(v);
        }
        let snap = TelemetrySnapshot {
            counters: vec![("a.b".into(), 7)],
            gauges: vec![("c.d".into(), (3, 9))],
            histograms: vec![("e.f".into(), h.snapshot())],
            events: vec![TraceEvent {
                seq: 1,
                trace_id: 2,
                span_id: 3,
                parent_span: 4,
                subsystem: "kv.client".into(),
                name: "get".into(),
            }],
        };
        let back = TelemetrySnapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(snap, back);
        let text = back.render();
        assert!(text.contains("a.b"));
        assert!(text.contains("kv.client"));
    }

    #[test]
    fn active_subsystems_groups_by_prefix() {
        let snap = TelemetrySnapshot {
            counters: vec![
                ("kv.client.ops".into(), 1),
                ("kv.server.frames_in".into(), 2),
                ("shard.router.fallbacks".into(), 0),
                ("reactor.jobs".into(), 3),
            ],
            gauges: vec![("watch.armed".into(), (0, 5))],
            histograms: Vec::new(),
            events: Vec::new(),
        };
        let subs = snap.active_subsystems();
        assert_eq!(
            subs,
            vec!["kv.client", "kv.server", "reactor", "watch"]
        );
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = enabled_guard();
        let h = Histogram::default();
        let c = Counter::default();
        set_enabled(false);
        h.record(5);
        c.incr();
        set_enabled(true);
        assert_eq!(h.count(), 0);
        assert_eq!(c.get(), 0);
        h.record(5);
        c.incr();
        assert_eq!(h.count(), 1);
        assert_eq!(c.get(), 1);
    }
}
