//! Counters for the elastic shard fabric's live rebalancing
//! ([`crate::shard::rebalance`]).
//!
//! Everything is a relaxed atomic: the migration daemon and the foreground
//! read/write paths bump these from many threads, and operators only ever
//! read eventually-consistent totals. [`RebalanceMetrics::snapshot`] gives
//! a plain-value copy for logging / CSV rows.
//!
//! Every bump also mirrors into the process-global telemetry registry
//! ([`crate::metrics::telemetry`]) under `rebalance.*` names, so one
//! fleet-wide snapshot covers every elastic fabric in the process while
//! each instance's [`RebalanceMetrics::snapshot`] stays an exact
//! per-instance view.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::telemetry::{self, Counter};

/// Live counters shared between the control plane, the migration workers,
/// and the read-through router.
#[derive(Debug)]
pub struct RebalanceMetrics {
    /// Keys whose placement changed and were enqueued for migration.
    pub keys_planned: AtomicU64,
    /// Keys actually copied to their new placement.
    pub keys_migrated: AtomicU64,
    /// Planned keys that vanished before the worker copied them (evicted
    /// concurrently, or already resident at the new placement).
    pub keys_skipped: AtomicU64,
    /// Keys dropped after exhausting batch retries. Their bytes survive on
    /// the old backends but stop being routed to once the epoch retires —
    /// a non-zero value after a rebalance means data needs operator
    /// attention (re-add the backend, or re-run the membership change).
    pub keys_failed: AtomicU64,
    /// Payload bytes copied old placement -> new placement.
    pub bytes_moved: AtomicU64,
    /// Reads that consulted the previous epoch after a current-epoch miss
    /// (the dual-read cost of read-through migration).
    pub dual_reads: AtomicU64,
    /// Dual reads that were served by the previous epoch (the key had not
    /// been migrated yet).
    pub dual_read_hits: AtomicU64,
    /// Migration batches re-enqueued after a transient failure.
    pub batch_retries: AtomicU64,
    /// Membership changes fully drained (epoch retired).
    pub rebalances: AtomicU64,
    /// Registry mirrors, positionally aligned with [`FIELD_NAMES`]: the
    /// global `rebalance.*` counters each local field aggregates into.
    globals: Vec<Arc<Counter>>,
}

/// Registry names of the mirrored counters, in field order.
const FIELD_NAMES: [&str; 9] = [
    "rebalance.keys_planned",
    "rebalance.keys_migrated",
    "rebalance.keys_skipped",
    "rebalance.keys_failed",
    "rebalance.bytes_moved",
    "rebalance.dual_reads",
    "rebalance.dual_read_hits",
    "rebalance.batch_retries",
    "rebalance.rebalances",
];

/// Plain-value copy of [`RebalanceMetrics`] at one instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RebalanceSnapshot {
    pub keys_planned: u64,
    pub keys_migrated: u64,
    pub keys_skipped: u64,
    pub keys_failed: u64,
    pub bytes_moved: u64,
    pub dual_reads: u64,
    pub dual_read_hits: u64,
    pub batch_retries: u64,
    pub rebalances: u64,
}

impl Default for RebalanceMetrics {
    fn default() -> RebalanceMetrics {
        RebalanceMetrics {
            keys_planned: AtomicU64::new(0),
            keys_migrated: AtomicU64::new(0),
            keys_skipped: AtomicU64::new(0),
            keys_failed: AtomicU64::new(0),
            bytes_moved: AtomicU64::new(0),
            dual_reads: AtomicU64::new(0),
            dual_read_hits: AtomicU64::new(0),
            batch_retries: AtomicU64::new(0),
            rebalances: AtomicU64::new(0),
            globals: FIELD_NAMES
                .iter()
                .map(|name| telemetry::counter(name))
                .collect(),
        }
    }
}

impl RebalanceMetrics {
    pub fn new() -> Arc<RebalanceMetrics> {
        Arc::new(RebalanceMetrics::default())
    }

    /// Local fields in [`FIELD_NAMES`] order (what `add` matches against).
    fn fields(&self) -> [&AtomicU64; 9] {
        [
            &self.keys_planned,
            &self.keys_migrated,
            &self.keys_skipped,
            &self.keys_failed,
            &self.bytes_moved,
            &self.dual_reads,
            &self.dual_read_hits,
            &self.batch_retries,
            &self.rebalances,
        ]
    }

    /// Bump a field (pass a reference to one of the public counters) and
    /// mirror the increment into its global `rebalance.*` registry twin.
    pub fn add(&self, counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
        if let Some(i) =
            self.fields().iter().position(|f| std::ptr::eq(*f, counter))
        {
            self.globals[i].add(n);
        }
    }

    pub fn snapshot(&self) -> RebalanceSnapshot {
        RebalanceSnapshot {
            keys_planned: self.keys_planned.load(Ordering::Relaxed),
            keys_migrated: self.keys_migrated.load(Ordering::Relaxed),
            keys_skipped: self.keys_skipped.load(Ordering::Relaxed),
            keys_failed: self.keys_failed.load(Ordering::Relaxed),
            bytes_moved: self.bytes_moved.load(Ordering::Relaxed),
            dual_reads: self.dual_reads.load(Ordering::Relaxed),
            dual_read_hits: self.dual_read_hits.load(Ordering::Relaxed),
            batch_retries: self.batch_retries.load(Ordering::Relaxed),
            rebalances: self.rebalances.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Display for RebalanceSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "planned={} migrated={} skipped={} failed={} bytes={} \
             dual_reads={} dual_hits={} retries={} rebalances={}",
            self.keys_planned,
            self.keys_migrated,
            self.keys_skipped,
            self.keys_failed,
            self.bytes_moved,
            self.dual_reads,
            self.dual_read_hits,
            self.batch_retries,
            self.rebalances
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_copies_counters() {
        let m = RebalanceMetrics::new();
        m.add(&m.keys_planned, 10);
        m.add(&m.keys_migrated, 8);
        m.add(&m.keys_skipped, 2);
        m.add(&m.bytes_moved, 4096);
        m.add(&m.dual_reads, 3);
        m.add(&m.dual_read_hits, 1);
        m.add(&m.rebalances, 1);
        let s = m.snapshot();
        assert_eq!(s.keys_planned, 10);
        assert_eq!(s.keys_migrated, 8);
        assert_eq!(s.keys_skipped, 2);
        assert_eq!(s.bytes_moved, 4096);
        assert_eq!(s.dual_reads, 3);
        assert_eq!(s.dual_read_hits, 1);
        assert_eq!(s.rebalances, 1);
        // Counters keep moving after a snapshot; the snapshot does not.
        m.add(&m.keys_migrated, 1);
        assert_eq!(s.keys_migrated, 8);
        assert_eq!(m.snapshot().keys_migrated, 9);
        let line = s.to_string();
        assert!(line.contains("migrated=8"));
        assert!(line.contains("dual_reads=3"));
    }
}
