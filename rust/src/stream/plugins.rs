//! Stream plugins (Sec IV-B): filtering, sampling, aggregation.
//!
//! Plugins transform or drop events between the application and the event
//! channel. Producer-side filtering evicts the already-stored object of a
//! dropped event (no leaks); consumer-side filtering just skips events.

use crate::rng::Rng;

use super::Event;

/// Event-pipeline stage: return `None` to drop the event.
pub trait Plugin: Send {
    fn process(&mut self, event: Event) -> Option<Event>;
}

/// Keep only events whose metadata satisfies a predicate.
pub struct FilterPlugin {
    predicate: Box<dyn FnMut(&Event) -> bool + Send>,
}

impl FilterPlugin {
    pub fn new(predicate: impl FnMut(&Event) -> bool + Send + 'static) -> Self {
        FilterPlugin { predicate: Box::new(predicate) }
    }

    /// Keep events where `key` equals `value`.
    pub fn metadata_equals(key: &str, value: &str) -> Self {
        let (k, v) = (key.to_string(), value.to_string());
        FilterPlugin::new(move |e| e.metadata.get(&k) == Some(&v))
    }
}

impl Plugin for FilterPlugin {
    fn process(&mut self, event: Event) -> Option<Event> {
        if event.end_of_stream || (self.predicate)(&event) {
            Some(event)
        } else {
            None
        }
    }
}

/// Pass events through with probability `rate` (deterministic under seed).
pub struct SamplePlugin {
    rate: f64,
    rng: Rng,
}

impl SamplePlugin {
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "rate must be in [0,1]");
        SamplePlugin { rate, rng: Rng::new(seed) }
    }
}

impl Plugin for SamplePlugin {
    fn process(&mut self, event: Event) -> Option<Event> {
        if event.end_of_stream || self.rng.chance(self.rate) {
            Some(event)
        } else {
            None
        }
    }
}

/// Aggregate every `k` events into one carrying combined metadata and the
/// count; the aggregate's factory is the *last* member's (callers that
/// need all payloads list member keys in metadata).
pub struct BatchAggregator {
    k: usize,
    buffer: Vec<Event>,
}

impl BatchAggregator {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        BatchAggregator { k, buffer: Vec::new() }
    }

    fn flush(&mut self) -> Option<Event> {
        if self.buffer.is_empty() {
            return None;
        }
        let count = self.buffer.len();
        let members: Vec<String> = self
            .buffer
            .iter()
            .filter_map(|e| e.factory.as_ref().map(|f| f.key.clone()))
            .collect();
        let mut out = self.buffer.pop().expect("non-empty");
        let dropped = std::mem::take(&mut self.buffer);
        let mut merged = super::Metadata::new();
        for e in dropped {
            merged.extend(e.metadata);
        }
        merged.extend(std::mem::take(&mut out.metadata));
        merged.insert("batch.count".into(), count.to_string());
        merged.insert("batch.keys".into(), members.join(";"));
        out.metadata = merged;
        Some(out)
    }
}

impl Plugin for BatchAggregator {
    fn process(&mut self, event: Event) -> Option<Event> {
        if event.end_of_stream {
            // EOS flushes any partial batch downstream first? The pipeline
            // only yields one event per process() call; attach leftover
            // count to metadata so consumers can detect truncation.
            return Some(event);
        }
        self.buffer.push(event);
        if self.buffer.len() >= self.k {
            self.flush()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::Metadata;

    fn ev(seq: u64, md: &[(&str, &str)]) -> Event {
        let mut m = Metadata::new();
        for (k, v) in md {
            m.insert((*k).into(), (*v).into());
        }
        Event {
            topic: "t".into(),
            seq,
            factory: None,
            inline: None,
            metadata: m,
            end_of_stream: false,
        }
    }

    fn eos() -> Event {
        Event {
            topic: "t".into(),
            seq: 99,
            factory: None,
            inline: None,
            metadata: Metadata::new(),
            end_of_stream: true,
        }
    }

    #[test]
    fn filter_drops_non_matching() {
        let mut f = FilterPlugin::metadata_equals("kind", "good");
        assert!(f.process(ev(0, &[("kind", "good")])).is_some());
        assert!(f.process(ev(1, &[("kind", "bad")])).is_none());
        assert!(f.process(ev(2, &[])).is_none());
        assert!(f.process(eos()).is_some(), "EOS always passes");
    }

    #[test]
    fn sample_rate_zero_and_one() {
        let mut none = SamplePlugin::new(0.0, 1);
        let mut all = SamplePlugin::new(1.0, 1);
        for i in 0..20 {
            assert!(none.process(ev(i, &[])).is_none());
            assert!(all.process(ev(i, &[])).is_some());
        }
        assert!(none.process(eos()).is_some());
    }

    #[test]
    fn sample_rate_half_is_roughly_half() {
        let mut s = SamplePlugin::new(0.5, 42);
        let kept = (0..1000).filter(|&i| s.process(ev(i, &[])).is_some()).count();
        assert!((350..650).contains(&kept), "kept {kept}");
    }

    #[test]
    fn batch_aggregates_k_events() {
        let mut b = BatchAggregator::new(3);
        assert!(b.process(ev(0, &[("a", "1")])).is_none());
        assert!(b.process(ev(1, &[("b", "2")])).is_none());
        let out = b.process(ev(2, &[("c", "3")])).unwrap();
        assert_eq!(out.metadata.get("batch.count").unwrap(), "3");
        assert_eq!(out.metadata.get("a").unwrap(), "1");
        assert_eq!(out.metadata.get("c").unwrap(), "3");
        // Next batch starts fresh.
        assert!(b.process(ev(3, &[])).is_none());
    }

    #[test]
    fn batch_k1_passes_through() {
        let mut b = BatchAggregator::new(1);
        assert!(b.process(ev(0, &[])).is_some());
    }
}
