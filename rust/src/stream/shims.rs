//! Publisher/Subscriber shims over the event-channel substrates.
//!
//! The paper ships shims for Kafka, Redis pub/sub, Redis queues and
//! ZeroMQ; ours cover the equivalent set available in-tree:
//!
//! | paper channel   | shim here                                   |
//! |-----------------|---------------------------------------------|
//! | Kafka           | [`LogPublisher`]/[`LogSubscriber`] (TCP) and [`EmbeddedLogPublisher`]/[`EmbeddedLogSubscriber`] |
//! | Redis pub/sub   | [`KvPubSubPublisher`]/[`KvPubSubSubscriber`] |
//! | Redis queues    | [`KvQueuePublisher`]/[`KvQueueSubscriber`]   |

use std::collections::HashSet;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

use crate::broker::{
    BrokerClient, BrokerFabric, BrokerState, PartitionedConsumer,
};
use crate::codec::{Bytes, Decode, Encode};
use crate::error::{Error, Result};
use crate::kv::{KvClient, KvSubscriber};

use super::{Event, Publisher, Subscriber};

// --------------------------------------------------------------------------
// Kafka-like log broker shims
// --------------------------------------------------------------------------

/// Publish events onto an embedded broker log.
pub struct EmbeddedLogPublisher {
    state: BrokerState,
}

impl EmbeddedLogPublisher {
    pub fn new(state: BrokerState) -> Self {
        EmbeddedLogPublisher { state }
    }
}

impl Publisher for EmbeddedLogPublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.state.produce(topic, Bytes(event.to_bytes()));
        Ok(())
    }
}

/// Consume events from an embedded broker log (offset cursor per instance).
pub struct EmbeddedLogSubscriber {
    state: BrokerState,
    topic: String,
    offset: u64,
}

impl EmbeddedLogSubscriber {
    pub fn new(state: BrokerState, topic: &str) -> Self {
        EmbeddedLogSubscriber { state, topic: topic.to_string(), offset: 0 }
    }

    /// Start from a specific offset (consumer-group resume).
    pub fn from_offset(state: BrokerState, topic: &str, offset: u64) -> Self {
        EmbeddedLogSubscriber { state, topic: topic.to_string(), offset }
    }
}

impl Subscriber for EmbeddedLogSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        let t = timeout.unwrap_or(Duration::from_secs(3600));
        let entries = self.state.fetch(&self.topic, self.offset, 1, t);
        match entries.into_iter().next() {
            Some(e) => {
                self.offset = e.offset + 1;
                Ok(Some(Event::from_bytes(&e.payload.0)?))
            }
            None => Ok(None),
        }
    }
}

/// TCP broker publisher (cross-process Kafka analogue).
pub struct LogPublisher {
    client: BrokerClient,
}

impl LogPublisher {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Ok(LogPublisher { client: BrokerClient::connect(addr)? })
    }
}

impl Publisher for LogPublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.client.produce(topic, Bytes(event.to_bytes()))?;
        Ok(())
    }
}

/// TCP broker subscriber with optional consumer-group commits.
pub struct LogSubscriber {
    client: BrokerClient,
    topic: String,
    offset: u64,
    group: Option<String>,
}

impl LogSubscriber {
    pub fn connect(addr: SocketAddr, topic: &str) -> Result<Self> {
        Ok(LogSubscriber {
            client: BrokerClient::connect(addr)?,
            topic: topic.to_string(),
            offset: 0,
            group: None,
        })
    }

    /// Resume from the group's committed offset; commits as it consumes.
    pub fn with_group(
        addr: SocketAddr,
        topic: &str,
        group: &str,
    ) -> Result<Self> {
        let client = BrokerClient::connect(addr)?;
        let offset = client.committed(group, topic)?;
        Ok(LogSubscriber {
            client,
            topic: topic.to_string(),
            offset,
            group: Some(group.to_string()),
        })
    }
}

impl Subscriber for LogSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        let t = timeout.unwrap_or(Duration::from_secs(3600));
        let entries = self.client.fetch(&self.topic, self.offset, 1, t)?;
        match entries.into_iter().next() {
            Some(e) => {
                self.offset = e.offset + 1;
                if let Some(g) = &self.group {
                    self.client.commit(g, &self.topic, self.offset)?;
                }
                Ok(Some(Event::from_bytes(&e.payload.0)?))
            }
            None => Ok(None),
        }
    }
}

// --------------------------------------------------------------------------
// Partitioned broker-fabric shims (topic partitions spread over N brokers)
// --------------------------------------------------------------------------

/// Publish events onto a partitioned broker fabric.
///
/// Data events are routed to one partition — by the hash of the metadata
/// key named at construction (per-key ordering), falling back to
/// round-robin — while end-of-stream markers are **broadcast to every
/// partition**, so each partition's consumers observe termination
/// regardless of which slice of the stream they own.
pub struct PartitionedLogPublisher {
    fabric: BrokerFabric,
    /// Metadata key whose value routes the event (None = round-robin).
    key_meta: Option<String>,
    cursor: AtomicU32,
}

impl PartitionedLogPublisher {
    /// Round-robin over the fabric's partitions.
    pub fn new(fabric: BrokerFabric) -> Self {
        PartitionedLogPublisher { fabric, key_meta: None, cursor: AtomicU32::new(0) }
    }

    /// Route by the value of `meta_key` in each event's metadata (events
    /// sharing that value keep their relative order); events without the
    /// key fall back to round-robin.
    pub fn by_metadata_key(fabric: BrokerFabric, meta_key: &str) -> Self {
        PartitionedLogPublisher {
            fabric,
            key_meta: Some(meta_key.to_string()),
            cursor: AtomicU32::new(0),
        }
    }

    fn partition_for(&self, event: &Event) -> u32 {
        if let Some(meta_key) = &self.key_meta {
            if let Some(v) = event.metadata.get(meta_key) {
                return self.fabric.partition_for_key(v);
            }
        }
        // Lock-free topic-global cursor — `publish` is `&self`, so this is
        // the atomic variant of PartitionedProducer's per-topic cursor.
        self.cursor.fetch_add(1, Ordering::Relaxed) % self.fabric.partitions()
    }
}

impl Publisher for PartitionedLogPublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        let payload = Bytes(event.to_bytes());
        if event.end_of_stream {
            // Every partition's consumers must observe termination.
            self.fabric.broadcast(topic, payload)?;
            return Ok(());
        }
        let p = self.partition_for(event);
        let inst = self.fabric.instance_for(topic, p);
        self.fabric.instance(inst).produce_to(topic, p, payload)?;
        Ok(())
    }
}

/// Consume events from a partitioned broker fabric as one group member.
///
/// Owns `assign_partitions(partitions, members, member)` of the topic and
/// fans in fetches across instances ([`PartitionedConsumer`]). Per-
/// partition end-of-stream markers are swallowed until every assigned
/// partition has terminated, then a single end-of-stream event is
/// surfaced — so a [`StreamConsumer`](crate::stream::StreamConsumer)
/// wrapping this shim closes exactly once, after draining its whole
/// assignment.
pub struct PartitionedLogSubscriber {
    consumer: PartitionedConsumer,
    topic: String,
    group: Option<String>,
    /// Assigned partitions that have delivered their end-of-stream marker.
    finished: HashSet<u32>,
    /// The single merged end-of-stream event has been surfaced; later
    /// calls time out (`Ok(None)`) instead of re-announcing termination.
    eos_delivered: bool,
}

impl PartitionedLogSubscriber {
    /// Member `member` of `members` anonymous consumers (offsets start at
    /// 0). A single consumer spanning the whole topic is `(0, 1)`.
    pub fn new(
        fabric: BrokerFabric,
        topic: &str,
        member: usize,
        members: usize,
    ) -> Result<Self> {
        Ok(PartitionedLogSubscriber {
            consumer: PartitionedConsumer::new(fabric, topic, member, members)?,
            topic: topic.to_string(),
            group: None,
            finished: HashSet::new(),
            eos_delivered: false,
        })
    }

    /// Group member: resumes each partition from the group's committed
    /// offset. Commits lag delivery by one event per partition — an
    /// event's offset is only committed when the *next* event of its
    /// partition is handed out (i.e. after the application came back for
    /// more) — so a crash replays the in-flight event instead of losing
    /// it: at-least-once delivery.
    pub fn with_group(
        fabric: BrokerFabric,
        topic: &str,
        group: &str,
        member: usize,
        members: usize,
    ) -> Result<Self> {
        Ok(PartitionedLogSubscriber {
            consumer: PartitionedConsumer::with_group(
                fabric, topic, group, member, members,
            )?,
            topic: topic.to_string(),
            group: Some(group.to_string()),
            finished: HashSet::new(),
            eos_delivered: false,
        })
    }

    /// The partitions this member consumes.
    pub fn assigned(&self) -> &[u32] {
        self.consumer.assigned()
    }
}

impl Subscriber for PartitionedLogSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        if self.eos_delivered {
            return Ok(None);
        }
        // An empty assignment (more members than partitions) has nothing
        // to consume: report end-of-stream once, immediately.
        if self.consumer.assigned().is_empty() {
            self.eos_delivered = true;
            return Ok(Some(Event::eos(&self.topic, 0)));
        }
        let deadline = timeout.map(|t| std::time::Instant::now() + t);
        loop {
            let slice = match deadline {
                None => Duration::from_secs(3600),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    d - now
                }
            };
            let Some((partition, entry)) = self.consumer.next(slice)? else {
                return Ok(None);
            };
            if let Some(g) = &self.group {
                // Lazy commit: mark everything *before* this entry as
                // consumed. The entry itself is committed when its
                // successor is delivered, so a crash mid-processing
                // replays it (at-least-once) rather than dropping it.
                self.consumer.commit_position(g, partition, entry.offset)?;
            }
            let event = Event::from_bytes(&entry.payload.0)?;
            if event.end_of_stream {
                self.finished.insert(partition);
                if self.finished.len() == self.consumer.assigned().len() {
                    self.eos_delivered = true;
                    return Ok(Some(event));
                }
                continue; // other partitions still live
            }
            return Ok(Some(event));
        }
    }
}

// --------------------------------------------------------------------------
// redis-sim pub/sub shims (fire-and-forget, per-subscriber fan-out)
// --------------------------------------------------------------------------

/// Publish over redis-sim pub/sub channels.
pub struct KvPubSubPublisher {
    client: KvClient,
}

impl KvPubSubPublisher {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Ok(KvPubSubPublisher { client: KvClient::connect(addr)? })
    }
}

impl Publisher for KvPubSubPublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.client.publish(topic, Bytes(event.to_bytes()))?;
        Ok(())
    }
}

/// Subscriber over a dedicated redis-sim push connection.
pub struct KvPubSubSubscriber {
    sub: KvSubscriber,
}

impl KvPubSubSubscriber {
    pub fn connect(addr: SocketAddr, topics: &[String]) -> Result<Self> {
        Ok(KvPubSubSubscriber { sub: KvSubscriber::connect(addr, topics)? })
    }
}

impl Subscriber for KvPubSubSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        match self.sub.next(timeout)? {
            Some(msg) => Ok(Some(Event::from_bytes(&msg.payload.0)?)),
            None => Ok(None),
        }
    }
}

// --------------------------------------------------------------------------
// redis-sim queue shims (work-queue semantics: each event to ONE consumer)
// --------------------------------------------------------------------------

/// Publish onto a redis-sim list used as a work queue.
pub struct KvQueuePublisher {
    client: KvClient,
}

impl KvQueuePublisher {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Ok(KvQueuePublisher { client: KvClient::connect(addr)? })
    }
}

impl Publisher for KvQueuePublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.client.lpush(topic, Bytes(event.to_bytes()))
    }
}

/// Blocking-pop consumer over a redis-sim list.
pub struct KvQueueSubscriber {
    client: KvClient,
    topic: String,
}

impl KvQueueSubscriber {
    pub fn connect(addr: SocketAddr, topic: &str) -> Result<Self> {
        Ok(KvQueueSubscriber {
            client: KvClient::connect(addr)?,
            topic: topic.to_string(),
        })
    }
}

impl Subscriber for KvQueueSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        match self.client.brpop(&self.topic, timeout)? {
            Some(b) => Ok(Some(Event::from_bytes(&b.0)?)),
            None => Ok(None),
        }
    }
}

/// Helper: an `Err` for shim construction against a dead endpoint,
/// normalized to `Error::Connector` for callers that probe.
pub fn probe(addr: SocketAddr) -> Result<()> {
    KvClient::connect(addr)
        .and_then(|c| c.ping())
        .map_err(|e| Error::Connector(format!("probe {addr}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::ServerBuilder;
    use crate::store::Store;
    use crate::stream::{Metadata, StreamConsumer, StreamProducer};

    #[test]
    fn tcp_log_shim_end_to_end() {
        let server = ServerBuilder::new().spawn_broker().unwrap();
        let store = Store::memory("s");
        let mut producer = StreamProducer::new(
            LogPublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        let mut consumer = StreamConsumer::new(
            LogSubscriber::connect(server.addr, "t").unwrap(),
        );
        producer.send("t", &41u32, Metadata::new()).unwrap();
        producer.close_topic("t").unwrap();
        let (p, _) = consumer
            .next_proxy::<u32>(Some(Duration::from_secs(2)))
            .unwrap()
            .unwrap();
        assert_eq!(*p.resolve().unwrap(), 41);
    }

    #[test]
    fn consumer_group_resume() {
        let server = ServerBuilder::new().spawn_broker().unwrap();
        let store = Store::memory("s");
        let mut producer = StreamProducer::new(
            LogPublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        for i in 0..4u32 {
            producer.send("t", &i, Metadata::new()).unwrap();
        }
        // First consumer in group "g" takes two events, then "crashes".
        {
            let mut c1 = StreamConsumer::new(
                LogSubscriber::with_group(server.addr, "t", "g").unwrap(),
            );
            for _ in 0..2 {
                c1.next_proxy::<u32>(Some(Duration::from_secs(2)))
                    .unwrap()
                    .unwrap();
            }
        }
        // Second consumer resumes at the committed offset.
        let mut c2 = StreamConsumer::new(
            LogSubscriber::with_group(server.addr, "t", "g").unwrap(),
        );
        let (p, _) = c2
            .next_proxy::<u32>(Some(Duration::from_secs(2)))
            .unwrap()
            .unwrap();
        assert_eq!(*p.resolve().unwrap(), 2);
    }

    #[test]
    fn partitioned_shim_end_to_end_with_single_eos() {
        let (fabric, states) = BrokerFabric::embedded(2, 4).unwrap();
        let store = Store::memory("pstream");
        let mut producer = StreamProducer::new(
            PartitionedLogPublisher::new(fabric.clone()),
            Some(store.clone()),
        );
        for i in 0..12u32 {
            producer.send("t", &i, Metadata::new()).unwrap();
        }
        producer.close_topic("t").unwrap();

        let mut consumer = StreamConsumer::new(
            PartitionedLogSubscriber::new(fabric, "t", 0, 1).unwrap(),
        );
        let mut got = Vec::new();
        while let Some((p, _)) = consumer
            .next_proxy::<u32>(Some(Duration::from_secs(5)))
            .unwrap()
        {
            got.push(*p.resolve().unwrap());
        }
        got.sort_unstable();
        assert_eq!(got, (0..12).collect::<Vec<_>>());
        // Closed exactly once; stays closed.
        assert!(consumer
            .next_proxy::<u32>(Some(Duration::from_millis(10)))
            .unwrap()
            .is_none());
        // Proxy mode: only small events crossed the brokers.
        let broker_bytes: i64 =
            states.iter().map(|s| s.gauge.get()).sum();
        assert!(broker_bytes < 16 * 1024, "bulk leaked into the brokers");
    }

    #[test]
    fn partitioned_group_members_split_stream() {
        let (fabric, _) = BrokerFabric::embedded(2, 4).unwrap();
        let store = Store::memory("pstream-group");
        let mut producer = StreamProducer::new(
            PartitionedLogPublisher::new(fabric.clone()),
            Some(store),
        );
        for i in 0..16u32 {
            producer.send("t", &i, Metadata::new()).unwrap();
        }
        producer.close_topic("t").unwrap();

        let mut seen = Vec::new();
        for m in 0..2 {
            let mut c = StreamConsumer::new(
                PartitionedLogSubscriber::with_group(
                    fabric.clone(),
                    "t",
                    "g",
                    m,
                    2,
                )
                .unwrap(),
            );
            while let Some((p, _)) = c
                .next_proxy::<u32>(Some(Duration::from_secs(5)))
                .unwrap()
            {
                seen.push(*p.resolve().unwrap());
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn partitioned_keyed_events_keep_order() {
        let (fabric, _) = BrokerFabric::embedded(3, 8).unwrap();
        let store = Store::memory("pstream-keyed");
        let mut producer = StreamProducer::new(
            PartitionedLogPublisher::by_metadata_key(fabric.clone(), "actor"),
            Some(store),
        );
        // Two interleaved actors; each actor's events must stay ordered.
        for i in 0..10u32 {
            let mut md = Metadata::new();
            md.insert("actor".into(), format!("a{}", i % 2));
            producer.send("t", &i, md).unwrap();
        }
        producer.close_topic("t").unwrap();

        let mut consumer = StreamConsumer::new(
            PartitionedLogSubscriber::new(fabric, "t", 0, 1).unwrap(),
        );
        let mut per_actor: std::collections::HashMap<String, Vec<u32>> =
            std::collections::HashMap::new();
        while let Some((p, md)) = consumer
            .next_proxy::<u32>(Some(Duration::from_secs(5)))
            .unwrap()
        {
            per_actor
                .entry(md["actor"].clone())
                .or_default()
                .push(*p.resolve().unwrap());
        }
        assert_eq!(per_actor["a0"], vec![0, 2, 4, 6, 8]);
        assert_eq!(per_actor["a1"], vec![1, 3, 5, 7, 9]);
    }

    #[test]
    fn kv_pubsub_shim_end_to_end() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let store = Store::memory("s");
        let mut consumer = StreamConsumer::new(
            KvPubSubSubscriber::connect(server.addr, &["t".into()]).unwrap(),
        );
        std::thread::sleep(Duration::from_millis(30)); // sub registration
        let mut producer = StreamProducer::new(
            KvPubSubPublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        producer.send("t", &9u8, Metadata::new()).unwrap();
        let (p, _) = consumer
            .next_proxy::<u8>(Some(Duration::from_secs(2)))
            .unwrap()
            .unwrap();
        assert_eq!(*p.resolve().unwrap(), 9);
    }

    #[test]
    fn kv_queue_shim_single_delivery() {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let store = Store::memory("s");
        let mut producer = StreamProducer::new(
            KvQueuePublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        for i in 0..6u32 {
            producer.send("q", &i, Metadata::new()).unwrap();
        }
        // Two competing queue consumers: each event delivered exactly once.
        let mut c1 = StreamConsumer::new(
            KvQueueSubscriber::connect(server.addr, "q").unwrap(),
        );
        let mut c2 = StreamConsumer::new(
            KvQueueSubscriber::connect(server.addr, "q").unwrap(),
        );
        let mut seen = Vec::new();
        for _ in 0..3 {
            let (p, _) = c1
                .next_proxy::<u32>(Some(Duration::from_secs(1)))
                .unwrap()
                .unwrap();
            seen.push(*p.resolve().unwrap());
            let (p, _) = c2
                .next_proxy::<u32>(Some(Duration::from_secs(1)))
                .unwrap()
                .unwrap();
            seen.push(*p.resolve().unwrap());
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }
}
