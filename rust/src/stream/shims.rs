//! Publisher/Subscriber shims over the event-channel substrates.
//!
//! The paper ships shims for Kafka, Redis pub/sub, Redis queues and
//! ZeroMQ; ours cover the equivalent set available in-tree:
//!
//! | paper channel   | shim here                                   |
//! |-----------------|---------------------------------------------|
//! | Kafka           | [`LogPublisher`]/[`LogSubscriber`] (TCP) and [`EmbeddedLogPublisher`]/[`EmbeddedLogSubscriber`] |
//! | Redis pub/sub   | [`KvPubSubPublisher`]/[`KvPubSubSubscriber`] |
//! | Redis queues    | [`KvQueuePublisher`]/[`KvQueueSubscriber`]   |

use std::net::SocketAddr;
use std::time::Duration;

use crate::broker::{BrokerClient, BrokerState};
use crate::codec::{Bytes, Decode, Encode};
use crate::error::{Error, Result};
use crate::kv::{KvClient, KvSubscriber};

use super::{Event, Publisher, Subscriber};

// --------------------------------------------------------------------------
// Kafka-like log broker shims
// --------------------------------------------------------------------------

/// Publish events onto an embedded broker log.
pub struct EmbeddedLogPublisher {
    state: BrokerState,
}

impl EmbeddedLogPublisher {
    pub fn new(state: BrokerState) -> Self {
        EmbeddedLogPublisher { state }
    }
}

impl Publisher for EmbeddedLogPublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.state.produce(topic, Bytes(event.to_bytes()));
        Ok(())
    }
}

/// Consume events from an embedded broker log (offset cursor per instance).
pub struct EmbeddedLogSubscriber {
    state: BrokerState,
    topic: String,
    offset: u64,
}

impl EmbeddedLogSubscriber {
    pub fn new(state: BrokerState, topic: &str) -> Self {
        EmbeddedLogSubscriber { state, topic: topic.to_string(), offset: 0 }
    }

    /// Start from a specific offset (consumer-group resume).
    pub fn from_offset(state: BrokerState, topic: &str, offset: u64) -> Self {
        EmbeddedLogSubscriber { state, topic: topic.to_string(), offset }
    }
}

impl Subscriber for EmbeddedLogSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        let t = timeout.unwrap_or(Duration::from_secs(3600));
        let entries = self.state.fetch(&self.topic, self.offset, 1, t);
        match entries.into_iter().next() {
            Some(e) => {
                self.offset = e.offset + 1;
                Ok(Some(Event::from_bytes(&e.payload.0)?))
            }
            None => Ok(None),
        }
    }
}

/// TCP broker publisher (cross-process Kafka analogue).
pub struct LogPublisher {
    client: BrokerClient,
}

impl LogPublisher {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Ok(LogPublisher { client: BrokerClient::connect(addr)? })
    }
}

impl Publisher for LogPublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.client.produce(topic, Bytes(event.to_bytes()))?;
        Ok(())
    }
}

/// TCP broker subscriber with optional consumer-group commits.
pub struct LogSubscriber {
    client: BrokerClient,
    topic: String,
    offset: u64,
    group: Option<String>,
}

impl LogSubscriber {
    pub fn connect(addr: SocketAddr, topic: &str) -> Result<Self> {
        Ok(LogSubscriber {
            client: BrokerClient::connect(addr)?,
            topic: topic.to_string(),
            offset: 0,
            group: None,
        })
    }

    /// Resume from the group's committed offset; commits as it consumes.
    pub fn with_group(
        addr: SocketAddr,
        topic: &str,
        group: &str,
    ) -> Result<Self> {
        let client = BrokerClient::connect(addr)?;
        let offset = client.committed(group, topic)?;
        Ok(LogSubscriber {
            client,
            topic: topic.to_string(),
            offset,
            group: Some(group.to_string()),
        })
    }
}

impl Subscriber for LogSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        let t = timeout.unwrap_or(Duration::from_secs(3600));
        let entries = self.client.fetch(&self.topic, self.offset, 1, t)?;
        match entries.into_iter().next() {
            Some(e) => {
                self.offset = e.offset + 1;
                if let Some(g) = &self.group {
                    self.client.commit(g, &self.topic, self.offset)?;
                }
                Ok(Some(Event::from_bytes(&e.payload.0)?))
            }
            None => Ok(None),
        }
    }
}

// --------------------------------------------------------------------------
// redis-sim pub/sub shims (fire-and-forget, per-subscriber fan-out)
// --------------------------------------------------------------------------

/// Publish over redis-sim pub/sub channels.
pub struct KvPubSubPublisher {
    client: KvClient,
}

impl KvPubSubPublisher {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Ok(KvPubSubPublisher { client: KvClient::connect(addr)? })
    }
}

impl Publisher for KvPubSubPublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.client.publish(topic, Bytes(event.to_bytes()))?;
        Ok(())
    }
}

/// Subscriber over a dedicated redis-sim push connection.
pub struct KvPubSubSubscriber {
    sub: KvSubscriber,
}

impl KvPubSubSubscriber {
    pub fn connect(addr: SocketAddr, topics: &[String]) -> Result<Self> {
        Ok(KvPubSubSubscriber { sub: KvSubscriber::connect(addr, topics)? })
    }
}

impl Subscriber for KvPubSubSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        match self.sub.next(timeout)? {
            Some(msg) => Ok(Some(Event::from_bytes(&msg.payload.0)?)),
            None => Ok(None),
        }
    }
}

// --------------------------------------------------------------------------
// redis-sim queue shims (work-queue semantics: each event to ONE consumer)
// --------------------------------------------------------------------------

/// Publish onto a redis-sim list used as a work queue.
pub struct KvQueuePublisher {
    client: KvClient,
}

impl KvQueuePublisher {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Ok(KvQueuePublisher { client: KvClient::connect(addr)? })
    }
}

impl Publisher for KvQueuePublisher {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        self.client.lpush(topic, Bytes(event.to_bytes()))
    }
}

/// Blocking-pop consumer over a redis-sim list.
pub struct KvQueueSubscriber {
    client: KvClient,
    topic: String,
}

impl KvQueueSubscriber {
    pub fn connect(addr: SocketAddr, topic: &str) -> Result<Self> {
        Ok(KvQueueSubscriber {
            client: KvClient::connect(addr)?,
            topic: topic.to_string(),
        })
    }
}

impl Subscriber for KvQueueSubscriber {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        match self.client.brpop(&self.topic, timeout)? {
            Some(b) => Ok(Some(Event::from_bytes(&b.0)?)),
            None => Ok(None),
        }
    }
}

/// Helper: an `Err` for shim construction against a dead endpoint,
/// normalized to `Error::Connector` for callers that probe.
pub fn probe(addr: SocketAddr) -> Result<()> {
    KvClient::connect(addr)
        .and_then(|c| c.ping())
        .map_err(|e| Error::Connector(format!("probe {addr}: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::BrokerServer;
    use crate::kv::KvServer;
    use crate::store::Store;
    use crate::stream::{Metadata, StreamConsumer, StreamProducer};

    #[test]
    fn tcp_log_shim_end_to_end() {
        let server = BrokerServer::spawn().unwrap();
        let store = Store::memory("s");
        let mut producer = StreamProducer::new(
            LogPublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        let mut consumer = StreamConsumer::new(
            LogSubscriber::connect(server.addr, "t").unwrap(),
        );
        producer.send("t", &41u32, Metadata::new()).unwrap();
        producer.close_topic("t").unwrap();
        let (p, _) = consumer
            .next_proxy::<u32>(Some(Duration::from_secs(2)))
            .unwrap()
            .unwrap();
        assert_eq!(*p.resolve().unwrap(), 41);
    }

    #[test]
    fn consumer_group_resume() {
        let server = BrokerServer::spawn().unwrap();
        let store = Store::memory("s");
        let mut producer = StreamProducer::new(
            LogPublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        for i in 0..4u32 {
            producer.send("t", &i, Metadata::new()).unwrap();
        }
        // First consumer in group "g" takes two events, then "crashes".
        {
            let mut c1 = StreamConsumer::new(
                LogSubscriber::with_group(server.addr, "t", "g").unwrap(),
            );
            for _ in 0..2 {
                c1.next_proxy::<u32>(Some(Duration::from_secs(2)))
                    .unwrap()
                    .unwrap();
            }
        }
        // Second consumer resumes at the committed offset.
        let mut c2 = StreamConsumer::new(
            LogSubscriber::with_group(server.addr, "t", "g").unwrap(),
        );
        let (p, _) = c2
            .next_proxy::<u32>(Some(Duration::from_secs(2)))
            .unwrap()
            .unwrap();
        assert_eq!(*p.resolve().unwrap(), 2);
    }

    #[test]
    fn kv_pubsub_shim_end_to_end() {
        let server = KvServer::spawn().unwrap();
        let store = Store::memory("s");
        let mut consumer = StreamConsumer::new(
            KvPubSubSubscriber::connect(server.addr, &["t".into()]).unwrap(),
        );
        std::thread::sleep(Duration::from_millis(30)); // sub registration
        let mut producer = StreamProducer::new(
            KvPubSubPublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        producer.send("t", &9u8, Metadata::new()).unwrap();
        let (p, _) = consumer
            .next_proxy::<u8>(Some(Duration::from_secs(2)))
            .unwrap()
            .unwrap();
        assert_eq!(*p.resolve().unwrap(), 9);
    }

    #[test]
    fn kv_queue_shim_single_delivery() {
        let server = KvServer::spawn().unwrap();
        let store = Store::memory("s");
        let mut producer = StreamProducer::new(
            KvQueuePublisher::connect(server.addr).unwrap(),
            Some(store),
        );
        for i in 0..6u32 {
            producer.send("q", &i, Metadata::new()).unwrap();
        }
        // Two competing queue consumers: each event delivered exactly once.
        let mut c1 = StreamConsumer::new(
            KvQueueSubscriber::connect(server.addr, "q").unwrap(),
        );
        let mut c2 = StreamConsumer::new(
            KvQueueSubscriber::connect(server.addr, "q").unwrap(),
        );
        let mut seen = Vec::new();
        for _ in 0..3 {
            let (p, _) = c1
                .next_proxy::<u32>(Some(Duration::from_secs(1)))
                .unwrap()
                .unwrap();
            seen.push(*p.resolve().unwrap());
            let (p, _) = c2
                .next_proxy::<u32>(Some(Duration::from_secs(1)))
                .unwrap()
                .unwrap();
            seen.push(*p.resolve().unwrap());
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }
}
