//! ProxyStream (Sec IV-B): object streaming with event metadata decoupled
//! from bulk data.
//!
//! A [`StreamProducer`] pairs a [`Publisher`] (low-latency event channel:
//! redis-sim pub/sub or queues, or the Kafka-like broker log) with a
//! [`Store`] per topic (bulk channel). `send` puts the object in the store
//! and publishes a small [`Event`] carrying the proxy factory; a
//! [`StreamConsumer`] iterates those events and yields **proxies**, so
//! bulk bytes flow producer → store → final consumer and bypass every
//! intermediate hop (the Fig 4/6 dispatcher).
//!
//! For the Fig 6 baseline, [`StreamProducer::send_inline`] pushes the bulk
//! bytes *through* the event channel instead, reproducing the
//! data-through-dispatcher configuration the paper compares against.
//!
//! **Partitioned event channel.** Because producer and consumer are
//! generic over [`Publisher`]/[`Subscriber`], the event channel scales
//! out without touching either side: [`PartitionedLogPublisher`] routes
//! each event to one partition of a
//! [`BrokerFabric`](crate::broker::BrokerFabric) (key-hash or
//! round-robin) and broadcasts end-of-stream to every partition, while
//! [`PartitionedLogSubscriber`] consumes one group member's partition
//! slice, fanning in fetches across broker instances and surfacing a
//! single end-of-stream only after every assigned partition has
//! terminated. Ordering is per partition — events sharing a routing key
//! arrive in production order; cross-partition interleaving is
//! unspecified, exactly as in Kafka.

mod plugins;
mod shims;

pub use plugins::{BatchAggregator, FilterPlugin, Plugin, SamplePlugin};
pub use shims::{
    probe, EmbeddedLogPublisher, EmbeddedLogSubscriber, KvPubSubPublisher,
    KvPubSubSubscriber, KvQueuePublisher, KvQueueSubscriber, LogPublisher,
    LogSubscriber, PartitionedLogPublisher, PartitionedLogSubscriber,
};

use std::collections::BTreeMap;
use std::time::Duration;

use crate::codec::{Bytes, Decode, Encode, Reader};
use crate::error::{Error, Result};
use crate::proxy::{Factory, Proxy};
use crate::store::Store;

/// Event metadata map.
pub type Metadata = BTreeMap<String, String>;

/// A stream event: everything a consumer needs to build a proxy of the
/// associated object (or, in inline mode, the object bytes themselves).
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Topic this event belongs to.
    pub topic: String,
    /// Producer-assigned sequence number (per topic).
    pub seq: u64,
    /// Factory for the stored object (proxy mode).
    pub factory: Option<Factory>,
    /// Inline payload (baseline mode: bulk data through the broker).
    pub inline: Option<Bytes>,
    /// User metadata, available without resolving the object.
    pub metadata: Metadata,
    /// Producer closed the topic.
    pub end_of_stream: bool,
}

impl Event {
    fn data_event(
        topic: &str,
        seq: u64,
        factory: Option<Factory>,
        inline: Option<Bytes>,
        metadata: Metadata,
    ) -> Event {
        Event {
            topic: topic.to_string(),
            seq,
            factory,
            inline,
            metadata,
            end_of_stream: false,
        }
    }

    fn eos(topic: &str, seq: u64) -> Event {
        Event {
            topic: topic.to_string(),
            seq,
            factory: None,
            inline: None,
            metadata: Metadata::new(),
            end_of_stream: true,
        }
    }
}

impl Encode for Event {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.topic.encode(buf);
        self.seq.encode(buf);
        self.factory.encode(buf);
        self.inline.encode(buf);
        self.metadata.encode(buf);
        self.end_of_stream.encode(buf);
    }
}

impl Decode for Event {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(Event {
            topic: Decode::decode(r)?,
            seq: Decode::decode(r)?,
            factory: Decode::decode(r)?,
            inline: Decode::decode(r)?,
            metadata: Decode::decode(r)?,
            end_of_stream: Decode::decode(r)?,
        })
    }
}

/// Event-channel send side (Kafka/Redis/ZeroMQ shim protocol).
pub trait Publisher: Send + Sync {
    fn publish(&self, topic: &str, event: &Event) -> Result<()>;
}

/// Event-channel receive side.
pub trait Subscriber: Send {
    /// Next event; `Ok(None)` on timeout.
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>>;
}

// Boxed shims so callers can pick the event-channel topology at runtime
// (e.g. streambench switching between a single embedded log and the
// partitioned broker fabric).
impl Publisher for Box<dyn Publisher> {
    fn publish(&self, topic: &str, event: &Event) -> Result<()> {
        (**self).publish(topic, event)
    }
}

impl Subscriber for Box<dyn Subscriber> {
    fn next_event(&mut self, timeout: Option<Duration>) -> Result<Option<Event>> {
        (**self).next_event(timeout)
    }
}

// --------------------------------------------------------------------------
// StreamProducer
// --------------------------------------------------------------------------

/// Producer half of ProxyStream.
pub struct StreamProducer<P: Publisher> {
    publisher: P,
    /// Topic → bulk store mapping (different topics may use different
    /// channels, the paper's per-topic optimization).
    stores: BTreeMap<String, Store>,
    default_store: Option<Store>,
    seqs: BTreeMap<String, u64>,
    plugins: Vec<Box<dyn Plugin>>,
}

impl<P: Publisher> StreamProducer<P> {
    pub fn new(publisher: P, default_store: Option<Store>) -> Self {
        StreamProducer {
            publisher,
            stores: BTreeMap::new(),
            default_store,
            seqs: BTreeMap::new(),
            plugins: Vec::new(),
        }
    }

    /// Route a topic to a specific store.
    pub fn map_topic(&mut self, topic: &str, store: Store) {
        self.stores.insert(topic.to_string(), store);
    }

    /// Install a producer-side plugin (filter/sample/aggregate).
    pub fn add_plugin(&mut self, plugin: Box<dyn Plugin>) {
        self.plugins.push(plugin);
    }

    fn store_for(&self, topic: &str) -> Result<&Store> {
        self.stores
            .get(topic)
            .or(self.default_store.as_ref())
            .ok_or_else(|| {
                Error::Config(format!("no store mapped for topic {topic}"))
            })
    }

    fn next_seq(&mut self, topic: &str) -> u64 {
        let seq = self.seqs.entry(topic.to_string()).or_insert(0);
        let s = *seq;
        *seq += 1;
        s
    }

    fn run_plugins(&mut self, event: Event) -> Option<Event> {
        let mut ev = Some(event);
        for p in &mut self.plugins {
            ev = match ev {
                Some(e) => p.process(e),
                None => return None,
            };
        }
        ev
    }

    /// Proxy mode: store the object, publish factory + metadata.
    pub fn send<T: Encode>(
        &mut self,
        topic: &str,
        obj: &T,
        metadata: Metadata,
    ) -> Result<()> {
        let store = self.store_for(topic)?.clone();
        let key = store.put(obj)?;
        let factory = store.factory_for(&key, false, 0);
        let seq = self.next_seq(topic);
        let event =
            Event::data_event(topic, seq, Some(factory), None, metadata);
        match self.run_plugins(event) {
            Some(ev) => self.publisher.publish(topic, &ev),
            None => {
                // Filtered out: the stored object is orphaned; evict it.
                store.evict(&key)
            }
        }
    }

    /// Baseline mode: bulk bytes ride the event channel (Fig 6's
    /// "Redis Pub/Sub" configuration).
    pub fn send_inline<T: Encode>(
        &mut self,
        topic: &str,
        obj: &T,
        metadata: Metadata,
    ) -> Result<()> {
        let seq = self.next_seq(topic);
        let event = Event::data_event(
            topic,
            seq,
            None,
            Some(Bytes(obj.to_bytes())),
            metadata,
        );
        match self.run_plugins(event) {
            Some(ev) => self.publisher.publish(topic, &ev),
            None => Ok(()),
        }
    }

    /// Metadata-only event (the ADIOS-like step-announcement mode: the
    /// object is stored out-of-band under a key both sides know).
    pub fn send_marker(&mut self, topic: &str, metadata: Metadata) -> Result<()> {
        let seq = self.next_seq(topic);
        let event = Event::data_event(topic, seq, None, None, metadata);
        match self.run_plugins(event) {
            Some(ev) => self.publisher.publish(topic, &ev),
            None => Ok(()),
        }
    }

    /// Close a topic: consumers' iteration ends after draining.
    pub fn close_topic(&mut self, topic: &str) -> Result<()> {
        let seq = self.next_seq(topic);
        self.publisher.publish(topic, &Event::eos(topic, seq))
    }
}

// --------------------------------------------------------------------------
// StreamConsumer
// --------------------------------------------------------------------------

/// Consumer half of ProxyStream: iterates proxies of streamed objects.
pub struct StreamConsumer<S: Subscriber> {
    subscriber: S,
    plugins: Vec<Box<dyn Plugin>>,
    closed: bool,
}

impl<S: Subscriber> StreamConsumer<S> {
    pub fn new(subscriber: S) -> Self {
        StreamConsumer { subscriber, plugins: Vec::new(), closed: false }
    }

    /// Install a consumer-side plugin (filter/sample).
    pub fn add_plugin(&mut self, plugin: Box<dyn Plugin>) {
        self.plugins.push(plugin);
    }

    /// Next raw event after plugins; `Ok(None)` when the stream closes.
    pub fn next_event(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<Option<Event>> {
        loop {
            if self.closed {
                return Ok(None);
            }
            let Some(event) = self.subscriber.next_event(timeout)? else {
                return Err(Error::Timeout(
                    timeout.unwrap_or_default(),
                    "stream consumer".into(),
                ));
            };
            if event.end_of_stream {
                self.closed = true;
                return Ok(None);
            }
            let mut ev = Some(event);
            for p in &mut self.plugins {
                ev = match ev {
                    Some(e) => p.process(e),
                    None => break,
                };
            }
            if let Some(ev) = ev {
                return Ok(Some(ev));
            }
            // Filtered: keep polling.
        }
    }

    /// Next object as a lazy proxy (the core ProxyStream interface).
    /// `Ok(None)` = stream closed. Inline events yield pre-resolved
    /// proxies (the bytes already crossed the event channel).
    pub fn next_proxy<T: Decode>(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<Option<(Proxy<T>, Metadata)>> {
        loop {
            let Some(event) = self.next_event(timeout)? else {
                return Ok(None);
            };
            match (event.factory, event.inline) {
                (Some(factory), _) => {
                    return Ok(Some((
                        Proxy::from_factory(factory),
                        event.metadata,
                    )))
                }
                (None, Some(inline)) => {
                    let value = T::from_bytes(&inline.0)?;
                    // Fabricate a local factory; the value is already here.
                    let factory = Factory {
                        desc: crate::store::ConnectorDesc::Memory {
                            id: format!("inline-{}", event.topic),
                        },
                        key: format!("inline-{}-{}", event.topic, event.seq),
                        wait: false,
                        timeout_ms: 0,
                        store_name: "inline".into(),
                    };
                    return Ok(Some((
                        Proxy::preresolved(factory, value),
                        event.metadata,
                    )));
                }
                (None, None) => {
                    // Marker event: nothing to proxy; skip (callers that
                    // care about markers use next_event directly).
                    continue;
                }
            }
        }
    }

    /// Blocking iterator over proxies until end-of-stream.
    pub fn iter_proxies<T: Decode>(
        &mut self,
    ) -> impl Iterator<Item = Result<(Proxy<T>, Metadata)>> + '_ {
        std::iter::from_fn(move || self.next_proxy::<T>(None).transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::BrokerState;

    fn meta(k: &str, v: &str) -> Metadata {
        let mut m = Metadata::new();
        m.insert(k.into(), v.into());
        m
    }

    #[test]
    fn event_roundtrip() {
        let store = Store::memory("ev");
        let ev = Event::data_event(
            "t",
            3,
            Some(store.factory_for("k", false, 0)),
            None,
            meta("a", "b"),
        );
        let back = Event::from_bytes(&ev.to_bytes()).unwrap();
        assert_eq!(ev, back);
    }

    #[test]
    fn produce_consume_proxy_mode() {
        let broker = BrokerState::new();
        let store = Store::memory("stream");
        let mut producer = StreamProducer::new(
            EmbeddedLogPublisher::new(broker.clone()),
            Some(store.clone()),
        );
        let mut consumer = StreamConsumer::new(EmbeddedLogSubscriber::new(
            broker.clone(),
            "t",
        ));

        for i in 0..5u64 {
            producer.send("t", &i, meta("i", &i.to_string())).unwrap();
        }
        producer.close_topic("t").unwrap();

        let mut got = Vec::new();
        while let Some((p, md)) = consumer
            .next_proxy::<u64>(Some(Duration::from_secs(2)))
            .unwrap()
        {
            assert!(md.contains_key("i"));
            got.push(*p.resolve().unwrap());
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        // Bulk data should NOT have crossed the broker.
        assert!(broker.gauge.get() < 1024, "events must stay small");
    }

    #[test]
    fn produce_consume_inline_mode_moves_bulk_through_broker() {
        let broker = BrokerState::new();
        let mut producer: StreamProducer<EmbeddedLogPublisher> =
            StreamProducer::new(EmbeddedLogPublisher::new(broker.clone()), None);
        let mut consumer = StreamConsumer::new(EmbeddedLogSubscriber::new(
            broker.clone(),
            "t",
        ));
        let payload = Bytes(vec![7u8; 100_000]);
        producer.send_inline("t", &payload, Metadata::new()).unwrap();
        producer.close_topic("t").unwrap();
        let (p, _) = consumer
            .next_proxy::<Bytes>(Some(Duration::from_secs(2)))
            .unwrap()
            .unwrap();
        assert!(p.is_resolved(), "inline proxies are pre-resolved");
        assert_eq!(p.resolve().unwrap().0.len(), 100_000);
        assert!(broker.gauge.get() > 100_000, "bulk rode the broker");
    }

    #[test]
    fn eos_terminates_iteration() {
        let broker = BrokerState::new();
        let store = Store::memory("stream");
        let mut producer = StreamProducer::new(
            EmbeddedLogPublisher::new(broker.clone()),
            Some(store),
        );
        producer.send("t", &1u8, Metadata::new()).unwrap();
        producer.close_topic("t").unwrap();
        let mut consumer =
            StreamConsumer::new(EmbeddedLogSubscriber::new(broker, "t"));
        let items: Vec<_> = consumer
            .iter_proxies::<u8>()
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_eq!(items.len(), 1);
        // Subsequent calls keep returning None.
        assert!(consumer
            .next_proxy::<u8>(Some(Duration::from_millis(10)))
            .unwrap()
            .is_none());
    }

    #[test]
    fn unmapped_topic_errors() {
        let broker = BrokerState::new();
        let mut producer: StreamProducer<EmbeddedLogPublisher> =
            StreamProducer::new(EmbeddedLogPublisher::new(broker), None);
        assert!(matches!(
            producer.send("t", &1u8, Metadata::new()),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn per_topic_store_mapping() {
        let broker = BrokerState::new();
        let store_a = Store::memory("a");
        let store_b = Store::memory("b");
        let mut producer = StreamProducer::new(
            EmbeddedLogPublisher::new(broker.clone()),
            None,
        );
        producer.map_topic("ta", store_a.clone());
        producer.map_topic("tb", store_b.clone());
        producer.send("ta", &1u8, Metadata::new()).unwrap();
        producer.send("tb", &2u8, Metadata::new()).unwrap();
        assert_eq!(store_a.gauge().unwrap().get(), 1);
        assert_eq!(store_b.gauge().unwrap().get(), 1);
    }

    #[test]
    fn consumer_timeout_is_error() {
        let broker = BrokerState::new();
        let mut consumer =
            StreamConsumer::new(EmbeddedLogSubscriber::new(broker, "empty"));
        assert!(matches!(
            consumer.next_proxy::<u8>(Some(Duration::from_millis(20))),
            Err(Error::Timeout(..))
        ));
    }
}
