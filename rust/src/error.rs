//! Crate-wide error type.
//!
//! Every layer of the stack funnels into [`Error`]: codec failures,
//! connector/store I/O, protocol violations from the KV server or broker,
//! ownership-rule violations (the runtime analogue of rustc's borrow-check
//! diagnostics), engine task failures, and PJRT runtime errors.

use std::sync::Arc;

/// Unified error for all proxystore operations.
#[derive(Debug, Clone, thiserror::Error)]
pub enum Error {
    /// Serialization / deserialization failure.
    #[error("codec error: {0}")]
    Codec(String),

    /// Underlying connector / transport failure.
    #[error("connector error: {0}")]
    Connector(String),

    /// Key not present in the mediated channel.
    #[error("key not found: {0}")]
    NotFound(String),

    /// KV / broker wire-protocol violation.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Ownership or borrowing rule violation (runtime borrow-check).
    #[error("ownership violation: {0}")]
    Ownership(String),

    /// A task submitted to the execution engine failed.
    #[error("task failed: {0}")]
    Task(String),

    /// Stream closed or broker subscription ended.
    #[error("stream closed: {0}")]
    StreamClosed(String),

    /// Timed out waiting (future resolution, blocking get, ...).
    #[error("timeout after {0:?}: {1}")]
    Timeout(std::time::Duration, String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Invalid configuration or argument.
    #[error("config error: {0}")]
    Config(String),

    /// Wrapped I/O error (Arc'd so `Error` stays `Clone`).
    #[error("io error: {0}")]
    Io(#[from] Arc<std::io::Error>),
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(Arc::new(e))
    }
}

impl Error {
    /// True when the error is a missing key (used by polling resolvers).
    pub fn is_not_found(&self) -> bool {
        matches!(self, Error::NotFound(_))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_is_informative() {
        let e = Error::NotFound("key-7".into());
        assert_eq!(e.to_string(), "key not found: key-7");
        assert!(e.is_not_found());
        assert!(!Error::Codec("x".into()).is_not_found());
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::BrokenPipe, "pipe");
        let e: Error = io.into();
        assert!(e.to_string().contains("pipe"));
    }

    #[test]
    fn errors_are_cloneable() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "x").into();
        let _ = e.clone();
    }
}
