//! Crate-wide error type.
//!
//! Every layer of the stack funnels into [`Error`]: codec failures,
//! connector/store I/O, protocol violations from the KV server or broker,
//! ownership-rule violations (the runtime analogue of rustc's borrow-check
//! diagnostics), engine task failures, and PJRT runtime errors.

use std::sync::Arc;

/// Unified error for all proxystore operations.
///
/// `Display` and `std::error::Error` are implemented by hand: the crate is
/// dependency-free (no `thiserror`), matching the in-tree philosophy.
#[derive(Debug, Clone)]
pub enum Error {
    /// Serialization / deserialization failure.
    Codec(String),

    /// Underlying connector / transport failure.
    Connector(String),

    /// Key not present in the mediated channel.
    NotFound(String),

    /// KV / broker wire-protocol violation.
    Protocol(String),

    /// Ownership or borrowing rule violation (runtime borrow-check).
    Ownership(String),

    /// A task submitted to the execution engine failed.
    Task(String),

    /// Stream closed or broker subscription ended.
    StreamClosed(String),

    /// Timed out waiting (future resolution, blocking get, ...).
    Timeout(std::time::Duration, String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Invalid configuration or argument.
    Config(String),

    /// Wrapped I/O error (Arc'd so `Error` stays `Clone`).
    Io(Arc<std::io::Error>),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Connector(m) => write!(f, "connector error: {m}"),
            Error::NotFound(k) => write!(f, "key not found: {k}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Ownership(m) => write!(f, "ownership violation: {m}"),
            Error::Task(m) => write!(f, "task failed: {m}"),
            Error::StreamClosed(m) => write!(f, "stream closed: {m}"),
            Error::Timeout(d, m) => write!(f, "timeout after {d:?}: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(Arc::new(e))
    }
}

impl From<Arc<std::io::Error>> for Error {
    fn from(e: Arc<std::io::Error>) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True when the error is a missing key (used by polling resolvers).
    pub fn is_not_found(&self) -> bool {
        matches!(self, Error::NotFound(_))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_is_informative() {
        let e = Error::NotFound("key-7".into());
        assert_eq!(e.to_string(), "key not found: key-7");
        assert!(e.is_not_found());
        assert!(!Error::Codec("x".into()).is_not_found());
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::BrokenPipe, "pipe");
        let e: Error = io.into();
        assert!(e.to_string().contains("pipe"));
    }

    #[test]
    fn errors_are_cloneable() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "x").into();
        let _ = e.clone();
    }
}
